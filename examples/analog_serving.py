"""Serve a small LM with FFN projections executed on simulated analog
crossbars (cfg.analog_mvm) — the paper's IMAC-as-inference-accelerator
use-case (ref [1]) on the LM substrate.

Compares greedy decodes between the digital model and its analog twin
(PCM, 8-bit DAC, 16 conductance levels) and reports the deployment cost
from the planner.

Run:  PYTHONPATH=src python examples/analog_serving.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core.planner import plan_arch
from repro.models import build_model
from repro.serving import Request, ServeConfig, ServingEngine


def main():
    base = dataclasses.replace(
        get_config("yi-9b").reduced(), n_layers=2,
        param_dtype="float32", compute_dtype="float32",
    )
    digital = build_model(base, remat=False)
    analog = build_model(
        dataclasses.replace(base, analog_mvm=True, analog_tech="PCM"),
        remat=False,
    )
    params = digital.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, base.vocab, size=(6 + i,)) for i in range(3)]

    outs = {}
    for name, model in [("digital", digital), ("analog", analog)]:
        eng = ServingEngine(model, params, ServeConfig(slots=2, cache_len=64))
        reqs = [
            Request(rid=i, prompt=p, max_tokens=8)
            for i, p in enumerate(prompts)
        ]
        eng.run(reqs, max_ticks=100)
        outs[name] = [r.output for r in reqs]
        print(f"{name:8s}: {[r.output for r in reqs]}")

    agree = sum(
        a == b for a, b in zip(outs["digital"], outs["analog"])
    )
    total = len(prompts)
    print(f"\nanalog/digital greedy agreement: {agree}/{total} sequences")
    print("(disagreement = quantisation/conductance effects, the object "
          "of study — rerun with analog_tech='MRAM' to see the low-ON/OFF "
          "technology degrade further)")

    rep = plan_arch(base, tech="PCM", array_rows=512, array_cols=512)
    r = rep.as_row()
    print(f"\ndeployment plan (PCM, 512x512): tiles={r['tiles']} "
          f"devices={r['devices']:.2e} power={r['est_power_w']}W "
          f"area={r['area_mm2']}mm^2")


if __name__ == "__main__":
    main()
