"""Monte-Carlo device-variation analysis — IMAC-Sim's design-space
exploration under programming variation (DeviceTech.sigma_rel).

Each trial redraws every memristor's conductance from a lognormal
around its programmed level (device-to-device variation), re-simulates
the full circuit, and reports the accuracy distribution — the
yield-style question a designer actually asks before committing to a
technology.

Run:  PYTHONPATH=src python examples/monte_carlo.py [--trials 8]
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs.imac_mnist import TOPOLOGY
from repro.core import IMACConfig
from repro.core.devices import get_tech
from repro.core.digital import train_mlp
from repro.core.evaluate import test_imac
from repro.data.digits import train_test_split


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=6)
    ap.add_argument("--samples", type=int, default=48)
    ap.add_argument("--sigma", type=float, default=0.10)
    args = ap.parse_args()

    xtr, ytr, xte, yte = train_test_split(4000, 500, seed=0, noise=0.4)
    params = train_mlp(jax.random.PRNGKey(0), TOPOLOGY, xtr, ytr, steps=500)

    for tech_name in ("PCM", "MRAM"):
        tech = dataclasses.replace(get_tech(tech_name), sigma_rel=args.sigma)
        cfg = IMACConfig(tech=tech, array_rows=32, array_cols=32)
        accs = []
        for t in range(args.trials):
            res = test_imac(
                params, xte, yte, cfg,
                n_samples=args.samples, chunk=24,
                variation_key=jax.random.PRNGKey(100 + t),
            )
            accs.append(res.accuracy)
        accs = np.array(accs)
        print(
            f"{tech_name} (sigma={args.sigma:.2f}): "
            f"acc mean={accs.mean():.4f} min={accs.min():.4f} "
            f"max={accs.max():.4f} std={accs.std():.4f} "
            f"({args.trials} trials x {args.samples} samples)"
        )
    print("\nvariation tolerance is itself technology-dependent — the "
          "high-ON/OFF technologies keep margin under sigma_rel "
          "programming noise; this is Table IV's story extended to yield.")


if __name__ == "__main__":
    main()
