"""Monte-Carlo device-variation analysis — IMAC-Sim's design-space
exploration under programming variation (DeviceTech.sigma_rel).

Each trial redraws every memristor's conductance from a lognormal
around its programmed level (device-to-device variation) and re-simulates
the full circuit. The batched reliability engine (repro.variability)
draws all trials as a stacked leading axis and runs them through ONE
jitted circuit solve — the variation draws are bitwise-identical to the
old re-simulate-per-trial loop for the same keys, minus T-1 retraces.

Run:  PYTHONPATH=src python examples/monte_carlo.py [--trials 8]
"""
import argparse
import dataclasses

import jax

from repro.configs.imac_mnist import TOPOLOGY
from repro.core import IMACConfig
from repro.core.devices import get_tech
from repro.core.digital import train_mlp
from repro.data.digits import train_test_split
from repro.variability import VariabilitySpec, run_variability


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=6)
    ap.add_argument("--samples", type=int, default=48)
    ap.add_argument("--sigma", type=float, default=0.10)
    ap.add_argument("--threshold", type=float, default=0.85,
                    help="accuracy bar for the yield metric")
    args = ap.parse_args()

    xtr, ytr, xte, yte = train_test_split(4000, 500, seed=0, noise=0.4)
    params = train_mlp(jax.random.PRNGKey(0), TOPOLOGY, xtr, ytr, steps=500)

    spec = VariabilitySpec(
        trials=args.trials, sigma_rel=args.sigma, acc_threshold=args.threshold
    )
    for tech_name in ("PCM", "MRAM"):
        tech = dataclasses.replace(get_tech(tech_name), sigma_rel=args.sigma)
        cfg = IMACConfig(tech=tech, array_rows=32, array_cols=32)
        rep = run_variability(
            params, xte, yte, cfg, spec,
            n_samples=args.samples, chunk=24,
        )
        print(
            f"{tech_name} (sigma={args.sigma:.2f}): "
            f"acc mean={rep.acc_mean:.4f} min={rep.acc_min:.4f} "
            f"max={rep.acc_max:.4f} std={rep.acc_std:.4f} "
            f"q05={rep.acc_q05:.4f} "
            f"P(acc>={args.threshold:.2f})={rep.yield_frac:.2f} "
            f"worst-case power={rep.power_worst * 1e3:.2f}mW "
            f"({rep.n_trials} trials x {rep.n_samples} samples, one solve)"
        )
    print("\nvariation tolerance is itself technology-dependent — the "
          "high-ON/OFF technologies keep margin under sigma_rel "
          "programming noise; this is Table IV's story extended to yield.")


if __name__ == "__main__":
    main()
