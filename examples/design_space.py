"""IMAC design-space exploration: the paper's core use-case.

Sweeps subarray size x device technology for the MNIST MLP through the
batched exploration engine (repro.explore) and prints the accuracy/power
grid — the cross product of Tables III and IV (the multi-objective
trade-off surface IMAC-Sim exists to expose) — plus the Pareto front
over (accuracy, power, latency).

The engine groups the grid by array size (each size is one traced
structure), solves all technologies of a size as a single stacked
circuit simulation, and memoizes results on disk: re-running this script
is instant. Pass --no-cache to force re-simulation.

Run:  PYTHONPATH=src python examples/design_space.py [--samples 64]
"""
import argparse

import jax

from repro.configs.imac_mnist import TOPOLOGY
from repro.core import IMACConfig
from repro.core.digital import train_mlp
from repro.data.digits import train_test_split
from repro.explore import SweepSpec, pareto_front, run_sweep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=48)
    ap.add_argument("--sizes", default="32,64,128")
    ap.add_argument("--techs", default="MRAM,RRAM,CBRAM,PCM")
    ap.add_argument("--cache", default="artifacts/design_space_cache")
    ap.add_argument("--no-cache", action="store_true")
    args = ap.parse_args()

    xtr, ytr, xte, yte = train_test_split(4000, 500, seed=0, noise=0.4)
    params = train_mlp(jax.random.PRNGKey(0), TOPOLOGY, xtr, ytr, steps=500)

    sizes = [int(s) for s in args.sizes.split(",")]
    techs = args.techs.split(",")
    spec = SweepSpec.grid(IMACConfig(), array_size=sizes, tech=techs)
    results = run_sweep(
        params,
        xte,
        yte,
        spec,
        n_samples=args.samples,
        chunk=24,
        cache=None if args.no_cache else args.cache,
    )
    by_point = {r.name: r.result for r in results}

    print(f"{'':>8s}" + "".join(f"{t:>22s}" for t in techs))
    for size in sizes:
        row = [f"{size:>4d}x{size:<3d}"]
        for tech in techs:
            res = by_point[f"array_size={size},tech={tech}"]
            row.append(f"acc={res.accuracy:.2f} p={res.avg_power:5.2f}W")
        print(row[0] + "".join(f"{c:>22s}" for c in row[1:]))
    print("\nrows: subarray size; accuracy falls / power falls as arrays "
          "grow (IR drop); PCM stays accurate at the lowest power "
          "(paper Tables III-IV).")

    front = pareto_front(results)
    print("\nPareto front over (accuracy max, power min, latency min):")
    for i in front:
        r = results[i]
        print(
            f"  {r.name:30s} acc={r.accuracy:.2f} "
            f"p={r.avg_power:5.2f}W lat={r.latency * 1e9:6.1f}ns"
        )


if __name__ == "__main__":
    main()
