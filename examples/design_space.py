"""IMAC design-space exploration: the paper's core use-case.

Sweeps subarray size x device technology for the MNIST MLP and prints
the accuracy/power grid — the cross product of Tables III and IV (the
multi-objective trade-off surface IMAC-Sim exists to expose).

Run:  PYTHONPATH=src python examples/design_space.py [--samples 64]
"""
import argparse

import jax

from repro.configs.imac_mnist import TOPOLOGY
from repro.core import IMACConfig
from repro.core.digital import train_mlp
from repro.core.evaluate import test_imac
from repro.data.digits import train_test_split


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=48)
    ap.add_argument("--sizes", default="32,64,128")
    ap.add_argument("--techs", default="MRAM,RRAM,CBRAM,PCM")
    args = ap.parse_args()

    xtr, ytr, xte, yte = train_test_split(4000, 500, seed=0, noise=0.4)
    params = train_mlp(jax.random.PRNGKey(0), TOPOLOGY, xtr, ytr, steps=500)

    sizes = [int(s) for s in args.sizes.split(",")]
    techs = args.techs.split(",")
    print(f"{'':>8s}" + "".join(f"{t:>22s}" for t in techs))
    for size in sizes:
        row = [f"{size:>4d}x{size:<3d}"]
        for tech in techs:
            cfg = IMACConfig(tech=tech, array_rows=size, array_cols=size)
            res = test_imac(
                params, xte, yte, cfg, n_samples=args.samples, chunk=24
            )
            row.append(f"acc={res.accuracy:.2f} p={res.avg_power:5.2f}W")
        print(row[0] + "".join(f"{c:>22s}" for c in row[1:]))
    print("\nrows: subarray size; accuracy falls / power falls as arrays "
          "grow (IR drop); PCM stays accurate at the lowest power "
          "(paper Tables III-IV).")


if __name__ == "__main__":
    main()
