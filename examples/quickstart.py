"""Quickstart: the paper's full pipeline in one script.

Trains the 400x120x84x10 sigmoid MLP (the paper's MNIST workload, on the
offline synthetic digit set), deploys it on an IMAC architecture with
MRAM 32x32 subarrays (auto H_P/V_P — reproduces Table III's [13,4,3] /
[4,3,1]), runs the batched circuit simulation, writes the generated
SPICE netlist files, and cross-validates the analytic Elmore latency
against the waveform-measured settling of the transient co-simulation
engine (repro.transient).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os

import jax

from repro.configs.imac_mnist import TOPOLOGY
from repro.core import IMACConfig, IMACNetwork, map_imac, netlist_stats
from repro.core.digital import accuracy, train_mlp
from repro.core.evaluate import test_imac
from repro.data.digits import train_test_split
from repro.transient import TransientSpec, crossvalidate_settling


def main():
    print("== 1. train the digital reference MLP ==")
    xtr, ytr, xte, yte = train_test_split(4000, 500, seed=0, noise=0.4)
    params = train_mlp(jax.random.PRNGKey(0), TOPOLOGY, xtr, ytr, steps=500)
    print(f"digital test accuracy: {accuracy(params, xte, yte):.4f}")

    print("\n== 2. deploy on IMAC (MRAM, 32x32 subarrays, Table II params) ==")
    cfg = IMACConfig(tech="MRAM", array_rows=32, array_cols=32)
    res = test_imac(params, xte, yte, cfg, n_samples=128, chunk=32)
    print(f"H_P = {list(res.hp)}  V_P = {list(res.vp)}   (paper: [13,4,3] / [4,3,1])")
    print(f"analog accuracy : {res.accuracy:.4f}  (digital {res.digital_accuracy:.4f})")
    print(f"average power   : {res.avg_power:.3f} W")
    print(f"latency         : {res.latency * 1e9:.1f} ns")
    print(f"solver residual : {res.worst_residual:.2e}")

    print("\n== 3. emit the SPICE netlist (mapLayer/mapIMAC) ==")
    net = IMACNetwork(params, cfg)
    files = map_imac(net.mapped, net.plans, cfg, sample=xte[0])
    outdir = "artifacts/netlist"
    os.makedirs(outdir, exist_ok=True)
    for name, text in files.items():
        with open(os.path.join(outdir, name), "w") as f:
            f.write(text)
    print(f"wrote {sorted(files)} to {outdir}/")
    print("element counts:", netlist_stats(files))

    print("\n== 4. transient co-simulation: analytic vs waveform latency ==")
    # One stacked integration over increasing wire capacitance; the
    # measured settling must track the analytic RC ordering.
    spec = TransientSpec(t_stop=20e-9, n_steps=24, gs_iters=4, n_probe=1)
    recs = crossvalidate_settling(
        params, xte, cfg, cap_scales=(1.0, 1000.0, 3000.0), spec=spec
    )
    print(f"{'cap scale':>10} {'c_seg (F)':>12} {'analytic (ns)':>14} "
          f"{'waveform (ns)':>14} {'energy (nJ)':>12}")
    for r in recs:
        print(f"{r['scale']:>10g} {r['c_segment']:>12.3g} "
              f"{r['analytic'] * 1e9:>14.2f} {r['measured'] * 1e9:>14.2f} "
              f"{r['energy'] * 1e9:>12.3f}")


if __name__ == "__main__":
    main()
