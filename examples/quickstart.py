"""Quickstart: the paper's full pipeline in one script.

Trains the 400x120x84x10 sigmoid MLP (the paper's MNIST workload, on the
offline synthetic digit set), deploys it on an IMAC architecture with
MRAM 32x32 subarrays (auto H_P/V_P — reproduces Table III's [13,4,3] /
[4,3,1]), runs the batched circuit simulation, and writes the generated
SPICE netlist files.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os

import jax

from repro.configs.imac_mnist import TOPOLOGY
from repro.core import IMACConfig, IMACNetwork, map_imac, netlist_stats
from repro.core.digital import accuracy, train_mlp
from repro.core.evaluate import test_imac
from repro.data.digits import train_test_split


def main():
    print("== 1. train the digital reference MLP ==")
    xtr, ytr, xte, yte = train_test_split(4000, 500, seed=0, noise=0.4)
    params = train_mlp(jax.random.PRNGKey(0), TOPOLOGY, xtr, ytr, steps=500)
    print(f"digital test accuracy: {accuracy(params, xte, yte):.4f}")

    print("\n== 2. deploy on IMAC (MRAM, 32x32 subarrays, Table II params) ==")
    cfg = IMACConfig(tech="MRAM", array_rows=32, array_cols=32)
    res = test_imac(params, xte, yte, cfg, n_samples=128, chunk=32)
    print(f"H_P = {list(res.hp)}  V_P = {list(res.vp)}   (paper: [13,4,3] / [4,3,1])")
    print(f"analog accuracy : {res.accuracy:.4f}  (digital {res.digital_accuracy:.4f})")
    print(f"average power   : {res.avg_power:.3f} W")
    print(f"latency         : {res.latency * 1e9:.1f} ns")
    print(f"solver residual : {res.worst_residual:.2e}")

    print("\n== 3. emit the SPICE netlist (mapLayer/mapIMAC) ==")
    net = IMACNetwork(params, cfg)
    files = map_imac(net.mapped, net.plans, cfg, sample=xte[0])
    outdir = "artifacts/netlist"
    os.makedirs(outdir, exist_ok=True)
    for name, text in files.items():
        with open(os.path.join(outdir, name), "w") as f:
            f.write(text)
    print(f"wrote {sorted(files)} to {outdir}/")
    print("element counts:", netlist_stats(files))


if __name__ == "__main__":
    main()
