"""Observability walkthrough: trace a small Table III sweep end to end.

Enables `repro.obs`, runs a subarray-size sweep twice against the same
on-disk result cache (cold then warm), and prints:

  * the span tree — run_sweep > memo_lookup / group[i] >
    evaluate_batch > map / stamp / solve (with the first solve split
    into solve_chunk[compile] vs solve_chunk[run]) / measure;
  * solver convergence stats (sweeps-to-converge and final-residual
    histograms) straight from the metrics registry;
  * cache hit/miss counters showing the warm rerun never touched the
    solver.

Artifacts land in artifacts/: a Chrome trace_event JSON (open in
chrome://tracing or https://ui.perfetto.dev) and a Prometheus text file.

Run:  PYTHONPATH=src python examples/traced_sweep.py [--samples 16]
"""
import argparse
import os
import tempfile

import jax

from repro import obs
from repro.configs.imac_mnist import TOPOLOGY
from repro.core import IMACConfig
from repro.core.digital import train_mlp
from repro.data.digits import train_test_split
from repro.explore import SweepSpec, run_sweep
from repro.explore.cache import ResultCache


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=16)
    ap.add_argument("--sizes", default="32,64")
    ap.add_argument("--techs", default="MRAM,RRAM")
    ap.add_argument("--out-dir", default="artifacts")
    args = ap.parse_args()

    obs.enable()

    xtr, ytr, xte, yte = train_test_split(2000, 200, seed=0, noise=0.4)
    params = train_mlp(jax.random.PRNGKey(0), TOPOLOGY, xtr, ytr, steps=200)

    sizes = [int(s) for s in args.sizes.split(",")]
    spec = SweepSpec.grid(
        IMACConfig(), array_size=sizes, tech=args.techs.split(",")
    )
    with tempfile.TemporaryDirectory() as tmp:
        cache = ResultCache(tmp)
        kw = dict(n_samples=args.samples, chunk=args.samples, cache=cache)
        run_sweep(params, xte, yte, spec, **kw)     # cold: all misses
        run_sweep(params, xte, yte, spec, **kw)     # warm: all hits
        hits, misses = cache.hits, cache.misses

    print("=== span tree ===")
    print(obs.span_tree())

    print("\n=== cache ===")
    print(f"misses (cold pass): {misses}")
    print(f"hits   (warm pass): {hits}")

    print("\n=== solver convergence ===")
    snap = obs.snapshot()
    for name in ("solver_sweeps", "solver_residual"):
        series = snap.get(name, {}).get("series", [])
        for s in series:
            filled = [
                (b["le"], b["count"]) for b in s["buckets"] if b["count"]
            ]
            print(
                f"{name}: count={s['count']} sum={s['sum']:.3g} "
                f"first_filled_buckets={filled[:4]}"
            )

    os.makedirs(args.out_dir, exist_ok=True)
    trace_path = os.path.join(args.out_dir, "traced_sweep.trace.json")
    prom_path = os.path.join(args.out_dir, "traced_sweep.prom")
    obs.export_chrome_trace(trace_path)
    obs.export_prometheus_file(prom_path)
    print(f"\ntrace:   {trace_path}  (chrome://tracing / perfetto)")
    print(f"metrics: {prom_path}")


if __name__ == "__main__":
    main()
