"""End-to-end LM training driver on the fault-tolerant substrate.

Trains a granite-family model on the synthetic token stream for a few
hundred steps with WSD schedule, async checkpointing and auto-resume.
Default is a ~25M-parameter config sized for this CPU container
(--full switches to a ~100M config for real hardware); loss decreases
measurably as the model learns the stream's successor structure.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--full]
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.synthetic_lm import make_train_stream
from repro.models import build_model
from repro.train import TrainConfig, Trainer


def small_config(full: bool):
    base = get_config("granite-3-8b")
    if full:  # ~100M params
        return dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab=16384,
            param_dtype="float32", compute_dtype="float32",
        )
    return dataclasses.replace(  # ~25M params, CPU-friendly
        base, n_layers=6, d_model=384, n_heads=6, n_kv_heads=2,
        head_dim=64, d_ff=1024, vocab=8192,
        param_dtype="float32", compute_dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="artifacts/train_lm_ckpt")
    args = ap.parse_args()

    cfg = small_config(args.full)
    model = build_model(cfg)
    print(f"model: {cfg.name}-mini  ~{cfg.n_params()/1e6:.0f}M params")

    shape = ShapeConfig("lm", seq_len=256, global_batch=8, kind="train")
    tcfg = TrainConfig(
        peak_lr=3e-3,
        total_steps=args.steps,
        schedule="wsd",
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=50,
        log_every=10,
    )
    trainer = Trainer(model, tcfg)
    trainer.install_preemption_hook()
    stream = make_train_stream(cfg, shape, seed=0)

    def log(step, metrics):
        print(f"step {step:4d}  loss {metrics['loss']:.4f}  "
              f"lr {metrics['lr']:.2e}  gnorm {metrics['grad_norm']:.2f}")

    params, history = trainer.fit(jax.random.PRNGKey(0), stream, on_metrics=log)
    stream.close()
    first, last = history[0][1]["loss"], history[-1][1]["loss"]
    print(f"\nloss: {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    print(f"checkpoints: {trainer.ckpt.all_steps()} in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
