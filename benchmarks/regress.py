"""Performance-regression gate over the run ledger (CI entry point).

Compares the latest ledger entry of each benchmark against its history
on the same backend/device (`repro.obs.regress`: median-of-last-N
baseline, noise-aware thresholds from the historical spread) and prints
one verdict per timing row. Exits non-zero when any row regresses —
unless ``--report-only``, or the history is still shallower than
``--enforce-after`` runs (the CI bootstrap mode: the gate observes
silently until enough baseline entries exist, then starts enforcing
without a workflow change).

Usage:
  python -m benchmarks.regress [--ledger PATH] [--bench NAME]
      [--baseline-n N] [--min-ratio R] [--enforce-after N]
      [--report-only]
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional

from repro.obs import ledger, regress


def main(argv: "Optional[list[str]]" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.regress", description=__doc__
    )
    ap.add_argument(
        "--ledger",
        default=None,
        help="ledger path (default: $REPRO_OBS_LEDGER or "
        "artifacts/perf_ledger.jsonl)",
    )
    ap.add_argument("--bench", default=None, help="gate only this bench")
    ap.add_argument(
        "--baseline-n",
        type=int,
        default=5,
        help="baseline = median of the last N matching runs",
    )
    ap.add_argument(
        "--min-ratio",
        type=float,
        default=regress.MIN_RATIO,
        help="minimum tolerated current/baseline ratio",
    )
    ap.add_argument(
        "--enforce-after",
        type=int,
        default=0,
        help="exit 0 despite regressions until at least N historical "
        "runs back the baseline (CI bootstrap)",
    )
    ap.add_argument(
        "--report-only",
        action="store_true",
        help="always exit 0; print verdicts only",
    )
    args = ap.parse_args(argv)

    entries, skipped = ledger.load_report(args.ledger)
    if skipped:
        print(f"# skipped {skipped} corrupt ledger line(s)", file=sys.stderr)
    if not entries:
        print("ledger is empty — nothing to gate", file=sys.stderr)
        return 0

    benches = sorted({e.get("bench", "?") for e in entries})
    if args.bench is not None:
        if args.bench not in benches:
            print(
                f"no ledger entries for bench {args.bench!r}; "
                f"present: {benches}",
                file=sys.stderr,
            )
            return 2
        benches = [args.bench]

    failed = []
    for bench in benches:
        hist = ledger.matching(entries, bench=bench, ok_only=False)
        latest = hist[-1]
        verdicts = regress.compare(
            latest,
            hist,
            n_baseline=args.baseline_n,
            min_ratio=args.min_ratio,
        )
        depth = regress.baseline_depth(verdicts)
        print(f"=== {bench} (run {latest.get('run_id')}, history depth "
              f"{depth}) ===")
        print(regress.format_table(verdicts))
        gating = [v for v in verdicts if v.gating]
        if gating:
            if depth < args.enforce_after:
                print(
                    f"-> {len(gating)} regression(s) NOT enforced: history "
                    f"depth {depth} < --enforce-after {args.enforce_after}"
                )
            else:
                failed.extend((bench, v.row) for v in gating)

    if failed:
        print(f"\nREGRESSIONS: {failed}", file=sys.stderr)
        return 0 if args.report_only else 1
    print("\nno enforced regressions")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
