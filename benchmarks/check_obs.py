"""CI assertion helper for the observability exports.

Parses a Prometheus text exposition and a Chrome trace_event JSON
produced by ``benchmarks.run --trace --metrics-out`` and asserts the
layer actually observed the run:

  * the Prometheus file parses (``# TYPE`` lines + ``name{labels} value``
    samples only) and contains the key series;
  * the trace is valid trace_event JSON with complete ("X") spans,
    including at least one compile-phase and one steady-state
    ``solve_chunk`` span;
  * any additional arguments are ``BENCH_<name>.json`` payloads checked
    against the v2 schema (`validate_bench_payload`).

Usage: python -m benchmarks.check_obs METRICS.prom TRACE.json [BENCH.json...]
Exits non-zero with a message on the first missing invariant.
"""
from __future__ import annotations

import json
import re
import sys

#: v2 BENCH_<name>.json: required metadata keys and their types.
BENCH_REQUIRED = {
    "schema_version": int,
    "bench": str,
    "ok": bool,
    "git_sha": str,
    "jax_version": str,
    "jax_backend": str,
    "device_platform": str,
    "device_count": int,
    "python_version": str,
    "rows": list,
}

REQUIRED_SERIES = (
    "solver_sweeps",
    "cache_hits_total",
    "backend_fallback_total",
)

_SAMPLE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})? (-?[0-9.e+-]+|\+Inf|NaN)$"
)


def parse_prometheus(text: str) -> "dict[str, list[str]]":
    """Parse a text exposition; returns {metric family: sample lines}.

    Raises ValueError on any line that is neither a comment nor a valid
    sample — the format check CI relies on.
    """
    families: "dict[str, list[str]]" = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        m = _SAMPLE.match(line)
        if not m:
            raise ValueError(f"line {lineno} is not a valid sample: {line!r}")
        name = m.group(1)
        # _bucket/_sum/_count samples belong to their histogram family.
        family = re.sub(r"_(bucket|sum|count)$", "", name)
        families.setdefault(family, []).append(line)
        if name != family:
            families.setdefault(name, []).append(line)
    return families


def check_metrics(path: str) -> None:
    with open(path) as fh:
        families = parse_prometheus(fh.read())
    missing = [s for s in REQUIRED_SERIES if s not in families]
    if missing:
        raise SystemExit(
            f"metrics export {path} is missing key series: {missing}; "
            f"present: {sorted(k for k in families if '_bucket' not in k)}"
        )
    print(f"ok: {path} parses; key series present: {REQUIRED_SERIES}")


def check_trace(path: str) -> None:
    with open(path) as fh:
        trace = json.load(fh)
    events = trace["traceEvents"]
    complete = [e for e in events if e.get("ph") == "X"]
    if not complete:
        raise SystemExit(f"trace {path} has no complete ('X') spans")
    for e in complete:
        for field in ("name", "ts", "dur", "pid", "tid"):
            if field not in e:
                raise SystemExit(f"span missing {field!r}: {e}")
    names = {e["name"] for e in complete}
    if "solve_chunk[compile]" not in names:
        raise SystemExit(
            f"trace {path} has no solve_chunk[compile] span; got {sorted(names)}"
        )
    if "solve_chunk[run]" not in names:
        raise SystemExit(
            f"trace {path} has no steady-state solve_chunk[run] span; "
            f"got {sorted(names)}"
        )
    print(
        f"ok: {path} has {len(complete)} spans incl. compile/run "
        f"solve_chunk split"
    )


def validate_bench_payload(payload: dict) -> None:
    """Assert a BENCH_<name>.json payload matches the v2 schema.

    Raises ValueError naming the first violated invariant. Additive
    keys (``git_dirty``, the embedded ``metrics`` snapshot) are allowed
    — the schema only pins what trend tooling depends on.
    """
    if not isinstance(payload, dict):
        raise ValueError(f"payload is {type(payload).__name__}, not an object")
    for key, typ in BENCH_REQUIRED.items():
        if key not in payload:
            raise ValueError(f"missing required key {key!r}")
        if not isinstance(payload[key], typ):
            raise ValueError(
                f"key {key!r} is {type(payload[key]).__name__}, "
                f"expected {typ.__name__}"
            )
    if payload["schema_version"] < 2:
        raise ValueError(
            f"schema_version {payload['schema_version']} < 2"
        )
    if not payload["git_sha"]:
        raise ValueError("git_sha is empty")
    for i, row in enumerate(payload["rows"]):
        if not isinstance(row, dict):
            raise ValueError(f"rows[{i}] is not an object")
        if not isinstance(row.get("name"), str) or not row["name"]:
            raise ValueError(f"rows[{i}] has no name")
        if not isinstance(row.get("us_per_call"), (int, float)) or isinstance(
            row.get("us_per_call"), bool
        ):
            raise ValueError(f"rows[{i}] us_per_call is not a number")
        if not isinstance(row.get("derived"), str):
            raise ValueError(f"rows[{i}] derived is not a string")
    metrics = payload.get("metrics")
    if metrics is not None:
        if not isinstance(metrics, dict):
            raise ValueError("metrics snapshot is not an object")
        for name, fam in metrics.items():
            if not isinstance(fam, dict) or "type" not in fam or (
                "series" not in fam
            ):
                raise ValueError(
                    f"metrics[{name!r}] lacks type/series"
                )


def check_bench_json(path: str) -> None:
    with open(path) as fh:
        payload = json.load(fh)
    try:
        validate_bench_payload(payload)
    except ValueError as e:
        raise SystemExit(f"bench payload {path} violates the v2 schema: {e}")
    print(f"ok: {path} matches the v2 BENCH schema "
          f"({len(payload['rows'])} rows)")


def main() -> None:
    if len(sys.argv) < 3:
        raise SystemExit(__doc__)
    check_metrics(sys.argv[1])
    check_trace(sys.argv[2])
    for path in sys.argv[3:]:
        check_bench_json(path)


if __name__ == "__main__":
    main()
