"""CI assertion helper for the observability exports.

Parses a Prometheus text exposition and a Chrome trace_event JSON
produced by ``benchmarks.run --trace --metrics-out`` and asserts the
layer actually observed the run:

  * the Prometheus file parses (``# TYPE`` lines + ``name{labels} value``
    samples only) and contains the key series;
  * the trace is valid trace_event JSON with complete ("X") spans,
    including at least one compile-phase and one steady-state
    ``solve_chunk`` span.

Usage: python -m benchmarks.check_obs METRICS.prom TRACE.json
Exits non-zero with a message on the first missing invariant.
"""
from __future__ import annotations

import json
import re
import sys

REQUIRED_SERIES = (
    "solver_sweeps",
    "cache_hits_total",
    "backend_fallback_total",
)

_SAMPLE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})? (-?[0-9.e+-]+|\+Inf|NaN)$"
)


def parse_prometheus(text: str) -> "dict[str, list[str]]":
    """Parse a text exposition; returns {metric family: sample lines}.

    Raises ValueError on any line that is neither a comment nor a valid
    sample — the format check CI relies on.
    """
    families: "dict[str, list[str]]" = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        m = _SAMPLE.match(line)
        if not m:
            raise ValueError(f"line {lineno} is not a valid sample: {line!r}")
        name = m.group(1)
        # _bucket/_sum/_count samples belong to their histogram family.
        family = re.sub(r"_(bucket|sum|count)$", "", name)
        families.setdefault(family, []).append(line)
        families.setdefault(name, []).append(line)
    return families


def check_metrics(path: str) -> None:
    with open(path) as fh:
        families = parse_prometheus(fh.read())
    missing = [s for s in REQUIRED_SERIES if s not in families]
    if missing:
        raise SystemExit(
            f"metrics export {path} is missing key series: {missing}; "
            f"present: {sorted(k for k in families if '_bucket' not in k)}"
        )
    print(f"ok: {path} parses; key series present: {REQUIRED_SERIES}")


def check_trace(path: str) -> None:
    with open(path) as fh:
        trace = json.load(fh)
    events = trace["traceEvents"]
    complete = [e for e in events if e.get("ph") == "X"]
    if not complete:
        raise SystemExit(f"trace {path} has no complete ('X') spans")
    for e in complete:
        for field in ("name", "ts", "dur", "pid", "tid"):
            if field not in e:
                raise SystemExit(f"span missing {field!r}: {e}")
    names = {e["name"] for e in complete}
    if "solve_chunk[compile]" not in names:
        raise SystemExit(
            f"trace {path} has no solve_chunk[compile] span; got {sorted(names)}"
        )
    if "solve_chunk[run]" not in names:
        raise SystemExit(
            f"trace {path} has no steady-state solve_chunk[run] span; "
            f"got {sorted(names)}"
        )
    print(
        f"ok: {path} has {len(complete)} spans incl. compile/run "
        f"solve_chunk split"
    )


def main() -> None:
    if len(sys.argv) != 3:
        raise SystemExit(__doc__)
    check_metrics(sys.argv[1])
    check_trace(sys.argv[2])


if __name__ == "__main__":
    main()
