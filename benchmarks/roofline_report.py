"""Roofline table from the dry-run artifacts (EXPERIMENTS §Roofline)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import ART, emit


def load_cells(dirname: str = None):
    dirname = dirname or os.path.join(ART, "dryrun")
    cells = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def markdown_table(cells) -> str:
    hdr = (
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "dominant | useful_flops | HBM GB/dev |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for c in cells:
        if c.get("skipped"):
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c.get('mesh','-')} | — | — | — | "
                f"SKIP ({c['reason'][:40]}...) | — | — |"
            )
            continue
        if "error" in c:
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — | "
                f"ERROR | — | — |"
            )
            continue
        r = c["roofline"]
        mem = c.get("memory", {})
        hbm = (
            mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
        ) / 1e9
        lines.append(
            "| {arch} | {shape} | {mesh} | {c:.3f} | {m:.3f} | {co:.3f} | "
            "{dom} | {uf} | {hbm:.1f} |".format(
                arch=c["arch"], shape=c["shape"], mesh=c["mesh"],
                c=r["compute_s"] or 0, m=r["memory_s"] or 0,
                co=r["collective_s"] or 0, dom=r["dominant"],
                uf=f"{r['useful_flops_ratio']:.2f}" if r.get("useful_flops_ratio") else "—",
                hbm=hbm,
            )
        )
    return hdr + "\n".join(lines) + "\n"


def run():
    cells = load_cells()
    n_ok = sum(1 for c in cells if not c.get("skipped") and "error" not in c)
    n_skip = sum(1 for c in cells if c.get("skipped"))
    n_err = sum(1 for c in cells if "error" in c)
    emit("roofline/cells", 0.0, f"ok={n_ok};skipped={n_skip};errors={n_err}")
    for c in cells:
        if c.get("skipped") or "error" in c:
            continue
        r = c["roofline"]
        emit(
            f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
            (r["compute_s"] or 0) * 1e6,
            f"dominant={r['dominant']};mem_s={r['memory_s']:.3f};"
            f"coll_s={r['collective_s']:.3f};useful={r.get('useful_flops_ratio') or 0:.2f}",
        )
    out = os.path.join(ART, "roofline_table.md")
    with open(out, "w") as f:
        f.write(markdown_table(cells))
    emit("roofline/table_written", 0.0, out)
