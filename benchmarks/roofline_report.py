"""Roofline table from the dry-run artifacts (EXPERIMENTS §Roofline),
plus a live roofline row for the fused Gauss–Seidel sweep kernel
(`repro.kernels.gs_fused`) measured through the HLO cost-analysis hooks
in `repro.obs.prof` — FLOPs and bytes-accessed come from XLA's own
``cost_analysis()`` where the backend provides one, with a hand-derived
sweep-count estimate as the fallback, joined with the measured runtime
into achieved GFLOP/s and arithmetic intensity."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import ART, emit


def load_cells(dirname: str = None):
    dirname = dirname or os.path.join(ART, "dryrun")
    cells = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def markdown_table(cells) -> str:
    hdr = (
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "dominant | useful_flops | HBM GB/dev |\n"
        "|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for c in cells:
        if c.get("skipped"):
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c.get('mesh','-')} | — | — | — | "
                f"SKIP ({c['reason'][:40]}...) | — | — |"
            )
            continue
        if "error" in c:
            lines.append(
                f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — | — | "
                f"ERROR | — | — |"
            )
            continue
        r = c["roofline"]
        mem = c.get("memory", {})
        hbm = (
            mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
        ) / 1e9
        lines.append(
            "| {arch} | {shape} | {mesh} | {c:.3f} | {m:.3f} | {co:.3f} | "
            "{dom} | {uf} | {hbm:.1f} |".format(
                arch=c["arch"], shape=c["shape"], mesh=c["mesh"],
                c=r["compute_s"] or 0, m=r["memory_s"] or 0,
                co=r["collective_s"] or 0, dom=r["dominant"],
                uf=f"{r['useful_flops_ratio']:.2f}" if r.get("useful_flops_ratio") else "—",
                hbm=hbm,
            )
        )
    return hdr + "\n".join(lines) + "\n"


def fused_flops_estimate(
    sweeps: int, lanes: int, m: int, n: int
) -> float:
    """Hand-derived FLOP count of the fused GS sweep loop (fallback).

    Per sweep each lane solves a row tridiagonal pass over M systems of
    N and a column pass over N systems of M. With the sweep-invariant
    Thomas coefficients precomputed host-side, forward elimination is
    ~3 flops/unknown and back-substitution ~2; the SOR blend and
    residual reduction add ~4 more over the M x N grid.
    """
    per_sweep = (2 * 5 + 4) * m * n
    return float(sweeps) * lanes * per_sweep


def gs_fused_roofline(tiles: int = 4, size: int = 16, batch: int = 4):
    """Emit achieved-GFLOP/s / intensity rows for the fused kernel.

    Uses `repro.obs.prof.hlo_cost` (XLA ``cost_analysis()`` on the
    jitted solve) instead of hand-derived counts where the backend
    provides them; interpret-mode timings off-TPU are emitted but
    labeled, mirroring benchmarks/solver_scaling.py's caveat.
    """
    import jax

    from benchmarks.common import time_call
    from repro.core.backends import on_tpu
    from repro.core.devices import MRAM
    from repro.core.solver import (
        CircuitParams,
        SolveOptions,
        solve_crossbar,
        suggest_iters,
    )
    from repro.obs import prof

    key = jax.random.PRNGKey(0)
    g = jax.random.uniform(
        key, (tiles, size, size), minval=MRAM.g_off, maxval=MRAM.g_on
    )
    v = jax.random.uniform(
        jax.random.PRNGKey(1), (batch, tiles, size), maxval=0.8
    )
    cp = CircuitParams(gs_iters=suggest_iters(size, size))
    opts = SolveOptions(backend="fused")
    fn = jax.jit(
        lambda g, v: solve_crossbar(g[None], v, cp, options=opts).i_out
    )
    cost = prof.hlo_cost(fn, g, v)
    us, _ = time_call(fn, g, v)

    lanes = batch * tiles
    est = fused_flops_estimate(cp.gs_iters, lanes, size, size)
    hlo_flops = (cost or {}).get("flops") or 0.0
    # XLA cannot see through the kernel's custom call in interpret
    # mode and undercounts; trust the HLO figure only when it at least
    # reaches the hand-derived sweep-loop lower bound.
    if hlo_flops >= est:
        flops, src = hlo_flops, "hlo"
    else:
        flops, src = est, f"estimate(hlo={hlo_flops:.3g})"
    secs = us / 1e6
    gflops = flops / secs / 1e9 if secs > 0 else 0.0
    bytes_acc = (cost or {}).get("bytes_accessed") or 0.0
    intensity = f"{flops / bytes_acc:.2f}" if bytes_acc > 0 else "—"
    mode = "tpu" if on_tpu() else "interpret(not-representative)"
    emit(
        f"roofline/gs_fused/{tiles}x{size}x{size}b{batch}",
        us,
        f"flops={flops:.3g}({src});est_flops={est:.3g};"
        f"achieved_gflops={gflops:.2f};intensity={intensity};"
        f"sweeps={cp.gs_iters};mode={mode}",
    )
    peak = prof.peak_flops()
    if peak:
        emit(
            "roofline/gs_fused/utilization",
            0.0,
            f"fraction_of_peak={flops / secs / peak:.4f};peak={peak:.3g}",
        )


def run():
    try:
        gs_fused_roofline()
    except Exception as e:  # the artifact table must still render
        emit("roofline/gs_fused/skipped", 0.0, f"error={type(e).__name__}")
    cells = load_cells()
    n_ok = sum(1 for c in cells if not c.get("skipped") and "error" not in c)
    n_skip = sum(1 for c in cells if c.get("skipped"))
    n_err = sum(1 for c in cells if "error" in c)
    emit("roofline/cells", 0.0, f"ok={n_ok};skipped={n_skip};errors={n_err}")
    for c in cells:
        if c.get("skipped") or "error" in c:
            continue
        r = c["roofline"]
        emit(
            f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
            (r["compute_s"] or 0) * 1e6,
            f"dominant={r['dominant']};mem_s={r['memory_s']:.3f};"
            f"coll_s={r['collective_s']:.3f};useful={r.get('useful_flops_ratio') or 0:.2f}",
        )
    out = os.path.join(ART, "roofline_table.md")
    with open(out, "w") as f:
        f.write(markdown_table(cells))
    emit("roofline/table_written", 0.0, out)
