"""Shared benchmark utilities: timing, CSV emission, cached MLP fixture."""
from __future__ import annotations

import os
import time
from typing import Callable, List, Tuple

import jax
import numpy as np

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")
N_SAMPLES = int(os.environ.get("BENCH_SAMPLES", "64"))
CSV_ROWS: "List[Tuple[str, float, str]]" = []


def emit(name: str, us_per_call: float, derived: str):
    CSV_ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_call(fn: Callable, *args, repeats: int = 3, **kw) -> "tuple[float, object]":
    out = jax.block_until_ready(fn(*args, **kw))  # compile + warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = jax.block_until_ready(fn(*args, **kw))
    dt = (time.perf_counter() - t0) / repeats
    return dt * 1e6, out


_FIXTURE = None


def mnist_like_fixture():
    """Train (once per process) the paper's 400x120x84x10 MLP on the
    synthetic digit set; cache weights on disk across runs."""
    global _FIXTURE
    if _FIXTURE is not None:
        return _FIXTURE
    import jax.numpy as jnp

    from repro.core.digital import accuracy, train_mlp
    from repro.data.digits import train_test_split

    os.makedirs(ART, exist_ok=True)
    cache = os.path.join(ART, "bench_mlp.npz")
    xtr, ytr, xte, yte = train_test_split(6000, 1000, seed=0, noise=0.4)
    if os.path.exists(cache):
        z = np.load(cache)
        params = [
            (jnp.asarray(z[f"w{i}"]), jnp.asarray(z[f"b{i}"])) for i in range(3)
        ]
    else:
        params = train_mlp(
            jax.random.PRNGKey(0), [400, 120, 84, 10], xtr, ytr, steps=600
        )
        np.savez(
            cache,
            **{f"w{i}": np.asarray(w) for i, (w, _) in enumerate(params)},
            **{f"b{i}": np.asarray(b) for i, (_, b) in enumerate(params)},
        )
    acc = accuracy(params, xte, yte)
    _FIXTURE = (params, xte, yte, acc)
    return _FIXTURE
