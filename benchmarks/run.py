"""Benchmark harness: one function per paper table + system benches.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).

  table3      — paper Table III (partitioning design space)
  table4      — paper Table IV (device technologies)
  sweep       — batched exploration engine vs per-config loop (Table III x IV)
  variability — batched Monte-Carlo reliability engine vs per-trial loop
  transient   — batched transient co-simulation vs per-config loop + the
                analytic-vs-waveform settling crossvalidation
  solver      — crossbar circuit-solver scaling (the adapted SPICE engine)
  kernels     — Pallas kernel workloads (ref-path timings on CPU)
  deploy      — IMAC deployment planning for the 10 assigned archs
  roofline    — (arch x shape x mesh) roofline table from dry-run artifacts

Usage: PYTHONPATH=src python -m benchmarks.run [--only table3,table4,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset")
    args = ap.parse_args()

    from benchmarks import (
        deploy_report,
        kernels_bench,
        roofline_report,
        solver_scaling,
        sweep_bench,
        table3_partitioning,
        table4_device_tech,
        transient_bench,
        variability_bench,
    )

    benches = {
        "table3": table3_partitioning.run,
        "table4": table4_device_tech.run,
        "sweep": sweep_bench.run,
        "variability": variability_bench.run,
        "transient": transient_bench.run,
        "solver": solver_scaling.run,
        "kernels": kernels_bench.run,
        "deploy": deploy_report.run,
        "roofline": roofline_report.run,
    }
    selected = (
        [s.strip() for s in args.only.split(",")] if args.only else list(benches)
    )
    print("name,us_per_call,derived")
    failures = []
    for name in selected:
        try:
            benches[name]()
        except Exception as e:  # keep the harness going; report at exit
            traceback.print_exc()
            failures.append((name, repr(e)))
    if failures:
        print(f"FAILED benches: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
