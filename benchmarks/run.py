"""Benchmark harness: one function per paper table + system benches.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit)
and, per selected bench, writes the same rows plus run metadata to
``BENCH_<name>.json`` in the repo root (machine-readable trend input).

  table3      — paper Table III (partitioning design space)
  table4      — paper Table IV (device technologies)
  sweep       — batched exploration engine vs per-config loop (Table III x IV)
  variability — batched Monte-Carlo reliability engine vs per-trial loop
  transient   — batched transient co-simulation vs per-config loop + the
                analytic-vs-waveform settling crossvalidation
  solver      — crossbar circuit-solver scaling (the adapted SPICE engine)
  kernels     — Pallas kernel workloads (ref-path timings on CPU)
  deploy      — IMAC deployment planning for the 10 assigned archs
  roofline    — (arch x shape x mesh) roofline table from dry-run artifacts

Observability (repro.obs): ``--trace FILE`` enables the tracer and
writes a Chrome trace_event JSON (load in chrome://tracing or Perfetto);
``--metrics-out FILE`` enables metrics and writes the Prometheus text
exposition. Either flag also embeds the metrics snapshot in each
``BENCH_<name>.json``.

Performance trajectory (repro.obs.ledger): every invocation appends one
JSONL entry per selected bench — run id, git SHA + dirty flag,
jax/device metadata, the timing rows, the metrics snapshot — to the
run ledger (``--ledger PATH``, default ``$REPRO_OBS_LEDGER`` or
``artifacts/perf_ledger.jsonl``; ``--no-ledger`` to skip). The ledger
is the durable perf store the one-shot BENCH files never were: gate it
with ``python -m benchmarks.regress`` and render it with
``python -m repro.obs.report``.

Deep profiling (repro.obs.prof): ``--jax-profile DIR`` (or
``REPRO_OBS_JAX_PROFILE``) captures a jax.profiler device trace of the
whole run; ``--cost`` (or ``REPRO_OBS_COST=1``) records per-jitted-fn
HLO cost analysis (hlo_flops / achieved_flops_per_s gauges).

Usage: PYTHONPATH=src python -m benchmarks.run [--only table3,table4,...]
           [--trace trace.json] [--metrics-out metrics.prom]
           [--ledger ledger.jsonl | --no-ledger] [--jax-profile DIR]
           [--cost]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import subprocess
import sys
import traceback

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

#: BENCH_<name>.json schema: bumped when the payload layout changes.
#: v2 adds run metadata (git SHA, jax/device/python) and the optional
#: embedded repro.obs metrics snapshot.
SCHEMA_VERSION = 2


def _git_sha() -> str:
    try:
        return (
            subprocess.run(
                ["git", "rev-parse", "HEAD"],
                cwd=REPO_ROOT,
                capture_output=True,
                text=True,
                timeout=10,
            ).stdout.strip()
            or "unknown"
        )
    except Exception:
        return "unknown"


def _git_dirty() -> "bool | None":
    try:
        proc = subprocess.run(
            ["git", "status", "--porcelain"],
            cwd=REPO_ROOT,
            capture_output=True,
            text=True,
            timeout=10,
        )
        return bool(proc.stdout.strip()) if proc.returncode == 0 else None
    except Exception:
        return None


def _metadata() -> dict:
    import jax

    from repro.obs import ledger

    devices = jax.devices()
    return {
        "schema_version": SCHEMA_VERSION,
        "git_sha": _git_sha(),
        "git_dirty": _git_dirty(),
        "jax_version": jax.__version__,
        "jax_backend": jax.default_backend(),
        "device_platform": devices[0].platform if devices else "none",
        "device_count": len(devices),
        # "data8" under a sharded-bench process ($REPRO_MESH_SHAPE or an
        # engine mesh_context); None on single-device runs. Part of the
        # regress env-matching key so sharded timings never gate
        # single-device baselines.
        "mesh_shape": ledger.current_mesh_context(),
        "python_version": platform.python_version(),
    }


def _write_json(name: str, rows, ok: bool, meta: dict) -> None:
    """Snapshot one bench's emitted rows as BENCH_<name>.json."""
    from repro import obs

    payload = {
        "bench": name,
        "ok": ok,
        **meta,
        "rows": [
            {"name": n, "us_per_call": us, "derived": derived}
            for n, us, derived in rows
        ],
    }
    if obs.enabled():
        payload["metrics"] = obs.snapshot()
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset")
    ap.add_argument(
        "--trace",
        default=None,
        metavar="FILE",
        help="enable repro.obs and write a Chrome trace_event JSON",
    )
    ap.add_argument(
        "--metrics-out",
        default=None,
        metavar="FILE",
        help="enable repro.obs and write a Prometheus text exposition",
    )
    ap.add_argument(
        "--ledger",
        default=None,
        metavar="FILE",
        help="perf-ledger path (default: $REPRO_OBS_LEDGER or "
        "artifacts/perf_ledger.jsonl)",
    )
    ap.add_argument(
        "--no-ledger",
        action="store_true",
        help="do not append this run to the perf ledger",
    )
    ap.add_argument(
        "--jax-profile",
        default=None,
        metavar="DIR",
        help="capture a jax.profiler trace of the run into DIR "
        "(also: REPRO_OBS_JAX_PROFILE)",
    )
    ap.add_argument(
        "--cost",
        action="store_true",
        help="enable per-jitted-fn HLO cost analysis "
        "(also: REPRO_OBS_COST=1)",
    )
    args = ap.parse_args()

    from repro import obs

    if args.trace or args.metrics_out:
        obs.enable()
    if args.cost:
        obs.prof.enable_cost()

    from benchmarks import (
        deploy_report,
        kernels_bench,
        roofline_report,
        solver_scaling,
        sweep_bench,
        table3_partitioning,
        table4_device_tech,
        transient_bench,
        variability_bench,
    )

    benches = {
        "table3": table3_partitioning.run,
        "table4": table4_device_tech.run,
        "sweep": sweep_bench.run,
        "variability": variability_bench.run,
        "transient": transient_bench.run,
        "solver": solver_scaling.run,
        "kernels": kernels_bench.run,
        "deploy": deploy_report.run,
        "roofline": roofline_report.run,
    }
    selected = (
        [s.strip() for s in args.only.split(",")] if args.only else list(benches)
    )
    from benchmarks import common

    meta = _metadata()
    ledger_path = None if args.no_ledger else (
        args.ledger or obs.ledger.default_path()
    )

    def _record(name: str, rows, ok: bool) -> None:
        _write_json(name, rows, ok=ok, meta=meta)
        if ledger_path is None:
            return
        try:
            obs.ledger.record_run(
                name,
                rows,
                ok=ok,
                meta={k: v for k, v in meta.items()},
                metrics=obs.snapshot() if obs.enabled() else None,
                path=ledger_path,
            )
        except Exception as e:  # a broken ledger must not fail the bench
            print(f"# ledger append failed: {e!r}", file=sys.stderr)

    print("name,us_per_call,derived")
    failures = []
    with obs.prof.jax_profile(args.jax_profile):
        for name in selected:
            start = len(common.CSV_ROWS)
            try:
                with obs.trace(f"bench[{name}]"):
                    benches[name]()
                _record(name, common.CSV_ROWS[start:], ok=True)
            except Exception as e:  # keep the harness going; report at exit
                traceback.print_exc()
                failures.append((name, repr(e)))
                _record(name, common.CSV_ROWS[start:], ok=False)
    if ledger_path is not None:
        print(f"# ledger appended: {ledger_path}", file=sys.stderr)
    if args.trace:
        obs.export_chrome_trace(args.trace)
        print(f"# trace written to {args.trace}", file=sys.stderr)
    if args.metrics_out:
        obs.export_prometheus_file(args.metrics_out)
        print(f"# metrics written to {args.metrics_out}", file=sys.stderr)
    if failures:
        print(f"FAILED benches: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
