"""Benchmark harness: one function per paper table + system benches.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit)
and, per selected bench, writes the same rows plus run metadata to
``BENCH_<name>.json`` in the repo root (machine-readable trend input).

  table3      — paper Table III (partitioning design space)
  table4      — paper Table IV (device technologies)
  sweep       — batched exploration engine vs per-config loop (Table III x IV)
  variability — batched Monte-Carlo reliability engine vs per-trial loop
  transient   — batched transient co-simulation vs per-config loop + the
                analytic-vs-waveform settling crossvalidation
  solver      — crossbar circuit-solver scaling (the adapted SPICE engine)
  kernels     — Pallas kernel workloads (ref-path timings on CPU)
  deploy      — IMAC deployment planning for the 10 assigned archs
  roofline    — (arch x shape x mesh) roofline table from dry-run artifacts

Usage: PYTHONPATH=src python -m benchmarks.run [--only table3,table4,...]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _write_json(name: str, rows, ok: bool) -> None:
    """Snapshot one bench's emitted rows as BENCH_<name>.json."""
    import jax

    payload = {
        "bench": name,
        "ok": ok,
        "jax_backend": jax.default_backend(),
        "rows": [
            {"name": n, "us_per_call": us, "derived": derived}
            for n, us, derived in rows
        ],
    }
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated subset")
    args = ap.parse_args()

    from benchmarks import (
        deploy_report,
        kernels_bench,
        roofline_report,
        solver_scaling,
        sweep_bench,
        table3_partitioning,
        table4_device_tech,
        transient_bench,
        variability_bench,
    )

    benches = {
        "table3": table3_partitioning.run,
        "table4": table4_device_tech.run,
        "sweep": sweep_bench.run,
        "variability": variability_bench.run,
        "transient": transient_bench.run,
        "solver": solver_scaling.run,
        "kernels": kernels_bench.run,
        "deploy": deploy_report.run,
        "roofline": roofline_report.run,
    }
    selected = (
        [s.strip() for s in args.only.split(",")] if args.only else list(benches)
    )
    from benchmarks import common

    print("name,us_per_call,derived")
    failures = []
    for name in selected:
        start = len(common.CSV_ROWS)
        try:
            benches[name]()
            _write_json(name, common.CSV_ROWS[start:], ok=True)
        except Exception as e:  # keep the harness going; report at exit
            traceback.print_exc()
            failures.append((name, repr(e)))
            _write_json(name, common.CSV_ROWS[start:], ok=False)
    if failures:
        print(f"FAILED benches: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
