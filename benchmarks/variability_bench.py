"""Batched Monte-Carlo reliability engine vs the seed per-trial loop.

The seed repo's Monte-Carlo path (examples/monte_carlo.py before the
repro.variability port) redrew and re-simulated one variation trial at a
time: T calls to `test_imac`, each re-tracing and re-compiling the
circuit solve. The reliability engine draws all T trials as a stacked
leading axis and runs them through ONE jitted solve via the
leading-config-axis machinery of `evaluate_batch`.

Both paths use the same per-trial PRNG keys, so their per-trial
accuracies must be IDENTICAL — the bench asserts it — and the speedup is
pure retrace/recompile elimination. Target: >= 3x at T=16 in the regime
a reliability sweep targets (many trials x few samples per trial, like
sweep_bench's many-configs-x-few-samples default; measured 3.6-4.8x
across runs at the defaults, ~1.9x when per-trial solve time dominates
at 16 samples).

BENCH_MC_TRIALS (default 16) and BENCH_MC_SAMPLES (default 4) control
the trial count and samples per trial.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, mnist_like_fixture
from repro.core.devices import get_tech
from repro.core.evaluate import test_imac
from repro.core.imac import IMACConfig
from repro.variability import VariabilitySpec, run_variability

N_TRIALS = int(os.environ.get("BENCH_MC_TRIALS", "16"))
N_SAMPLES = int(os.environ.get("BENCH_MC_SAMPLES", "4"))


def run():
    params, xte, yte, _ = mnist_like_fixture()
    tech = dataclasses.replace(get_tech("PCM"), sigma_rel=0.10)
    cfg = IMACConfig(tech=tech, array_rows=32, array_cols=32)
    keys = jnp.stack(
        [jax.random.PRNGKey(100 + t) for t in range(N_TRIALS)]
    )

    # Seed path: one test_imac per trial, re-traced and re-compiled.
    t0 = time.perf_counter()
    loop_accs = [
        test_imac(
            params, xte, yte, cfg,
            n_samples=N_SAMPLES, chunk=N_SAMPLES, variation_key=keys[t],
        ).accuracy
        for t in range(N_TRIALS)
    ]
    t_loop = time.perf_counter() - t0

    # Batched engine: all trials stacked through one jitted solve.
    t0 = time.perf_counter()
    report = run_variability(
        params, xte, yte, cfg,
        VariabilitySpec(trials=N_TRIALS),
        keys=keys, n_samples=N_SAMPLES, chunk=N_SAMPLES,
    )
    t_batched = time.perf_counter() - t0

    if list(report.per_trial_accuracy) != loop_accs:
        raise AssertionError(
            "batched trials diverged from the per-trial loop: "
            f"{list(report.per_trial_accuracy)} vs {loop_accs}"
        )

    speedup = t_loop / t_batched
    emit(
        "variability/seed_per_trial_loop",
        t_loop / N_TRIALS * 1e6,
        f"total_s={t_loop:.2f};trials={N_TRIALS};samples={N_SAMPLES}",
    )
    emit(
        "variability/batched_engine",
        t_batched / N_TRIALS * 1e6,
        f"total_s={t_batched:.2f};trials={N_TRIALS};samples={N_SAMPLES}",
    )
    emit(
        "variability/speedup_vs_seed_loop",
        0.0,
        f"x={speedup:.2f};per_trial_identical=1",
    )
    emit(
        "variability/report",
        0.0,
        f"acc_mean={report.acc_mean:.4f};acc_q05={report.acc_q05:.4f};"
        f"yield={report.yield_frac:.2f};p_worst={report.power_worst:.4g}",
    )
    if speedup < 3.0:
        print(
            f"WARNING: reliability engine speedup {speedup:.2f}x vs the "
            f"seed per-trial loop is below the 3x target"
        )
    return report


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
