"""Batched sweep engine vs the per-config loop on the Table III x IV grid.

The design space is the cross product of the paper's partitioning
configurations (Table III) and device technologies (Table IV): 24
configurations, 6 traced structures.

Three sweep implementations are timed:

  * seed_per_config_loop — the pre-explore sweep path this PR replaces
    (faithful reproduction of the seed `test_imac`): a fresh
    IMACNetwork + jitted chunk solve re-traced and re-compiled for
    every configuration, plus the per-config batch-1 structural-latency
    solve and per-config digital reference it performed.
  * lean_per_config_loop — `evaluate.sweep` today: per-config calls to
    the shared `evaluate_batch` core (still one compile per config, but
    no redundant latency solve).
  * batched_engine — `repro.explore.run_sweep`: one vmap-free stacked
    solve and ONE compilation per structure group (6 instead of 24),
    with mapWB memoized across groups (4 mappings instead of 24).

Emits wall-clock per path, engine speedups vs both loops, and a
numerical cross-check that all paths agree. BENCH_SWEEP_SAMPLES
(default 4) controls samples per evaluation — the regime a design-space
sweep targets is many configurations x few samples, where per-config
retracing dominates.

Multi-device axis: on hosts with more than one JAX device (e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) the bench also
times one large structure group through `run_sweep(shard=...)` at
device counts 1, 2 and all, and emits a ``sweep/shard_speedup`` row.
``BENCH_SHARD_MIN_SPEEDUP`` (unset = report-only) turns the all-devices
speedup into a hard assertion — the CI mesh-smoke job sets it.
``BENCH_SHARD_CONFIGS`` / ``BENCH_SHARD_SAMPLES`` size the workload.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, mnist_like_fixture
from repro.configs.imac_mnist import TABLE_III_CONFIGS, TABLE_IV_CONFIGS
from repro.core.digital import mlp_forward
from repro.core.evaluate import IMACResult, sweep
from repro.core.imac import IMACNetwork
from repro.explore import pareto_front, run_sweep

N_SWEEP_SAMPLES = int(os.environ.get("BENCH_SWEEP_SAMPLES", "4"))
N_SHARD_CONFIGS = int(os.environ.get("BENCH_SHARD_CONFIGS", "16"))
N_SHARD_SAMPLES = int(os.environ.get("BENCH_SHARD_SAMPLES", "64"))
MIN_SHARD_SPEEDUP = float(os.environ.get("BENCH_SHARD_MIN_SPEEDUP", "0"))


def cross_product():
    """Table III partitioning x Table IV technology: 24 configurations."""
    items = []
    for part_name, part_cfg in TABLE_III_CONFIGS:
        for tech_name, _ in TABLE_IV_CONFIGS:
            items.append(
                (
                    f"{part_name}/{tech_name}",
                    dataclasses.replace(part_cfg, tech=tech_name),
                )
            )
    return items


def _seed_test_imac(params, x, y, cfg, *, n_samples=None, chunk=256):
    """The seed repo's per-config testIMAC, reproduced as the baseline.

    Identical algorithm and outputs to the pre-explore code path:
    IMACNetwork + per-config jitted chunk solve, an extra batch-1
    forward for the latency estimate, and a per-config digital
    reference pass.
    """
    n = n_samples or x.shape[0]
    x, y = x[:n], y[:n]
    net = IMACNetwork(params, cfg)

    @jax.jit
    def run_chunk(xb):
        out, stats = net(xb)
        pred = jnp.argmax(out, axis=-1)
        return (
            pred,
            jnp.stack([jnp.mean(s.power) for s in stats]),
            jnp.stack([s.residual for s in stats]),
        )

    preds, powers, residuals = [], [], []
    n_chunks = (n + chunk - 1) // chunk
    for ci in range(n_chunks):
        xb = x[ci * chunk : (ci + 1) * chunk]
        pred, pwr, res = run_chunk(xb)
        preds.append(pred)
        powers.append(pwr * xb.shape[0])
        residuals.append(res)
    pred = jnp.concatenate(preds)
    per_layer_power = jnp.sum(jnp.stack(powers), axis=0) / n
    worst_res = float(jnp.max(jnp.stack(residuals)))

    errors = int(jnp.sum((pred != y).astype(jnp.int32)))
    _, stats = net(x[:1])  # latency from a structural batch-1 forward
    latency = float(net.total_latency(stats))
    dig_pred = jnp.argmax(mlp_forward(params, x, "sigmoid"), axis=-1)
    dig_acc = float(jnp.mean((dig_pred == y).astype(jnp.float32)))
    return IMACResult(
        accuracy=1.0 - errors / n,
        error_rate=errors / n,
        avg_power=float(jnp.sum(per_layer_power)),
        latency=latency,
        digital_accuracy=dig_acc,
        per_layer_power=tuple(float(p) for p in per_layer_power),
        worst_residual=worst_res,
        n_samples=n,
        hp=tuple(net.hp),
        vp=tuple(net.vp),
    )


def _max_deltas(reference, results):
    worst_acc = max(
        abs(r1.accuracy - r2.result.accuracy)
        for r1, r2 in zip(reference, results)
    )
    worst_pow = max(
        abs(r1.avg_power - r2.result.avg_power) / max(r1.avg_power, 1e-12)
        for r1, r2 in zip(reference, results)
    )
    return worst_acc, worst_pow


def run():
    params, xte, yte, _ = mnist_like_fixture()
    items = cross_product()
    n = N_SWEEP_SAMPLES

    t0 = time.perf_counter()
    seed_results = [
        _seed_test_imac(params, xte, yte, cfg, n_samples=n, chunk=n)
        for _, cfg in items
    ]
    t_seed = time.perf_counter() - t0

    t0 = time.perf_counter()
    lean_results = sweep(params, xte, yte, items, n_samples=n, chunk=n)
    t_lean = time.perf_counter() - t0

    t0 = time.perf_counter()
    batched = run_sweep(params, xte, yte, items, n_samples=n, chunk=n)
    t_batched = time.perf_counter() - t0

    speedup_seed = t_seed / t_batched
    speedup_lean = t_lean / t_batched
    d_acc_seed, d_pow_seed = _max_deltas(seed_results, batched)
    d_acc_lean, d_pow_lean = _max_deltas(
        [r for _, r in lean_results], batched
    )
    emit(
        "sweep/seed_per_config_loop",
        t_seed / len(items) * 1e6,
        f"total_s={t_seed:.2f};configs={len(items)};samples={n}",
    )
    emit(
        "sweep/lean_per_config_loop",
        t_lean / len(items) * 1e6,
        f"total_s={t_lean:.2f};configs={len(items)};samples={n}",
    )
    emit(
        "sweep/batched_engine",
        t_batched / len(items) * 1e6,
        f"total_s={t_batched:.2f};configs={len(items)};samples={n}",
    )
    emit(
        "sweep/speedup_vs_seed_loop",
        0.0,
        f"x={speedup_seed:.2f};acc_delta={d_acc_seed:.2e};"
        f"pow_rel_delta={d_pow_seed:.2e}",
    )
    emit(
        "sweep/speedup_vs_lean_loop",
        0.0,
        f"x={speedup_lean:.2f};acc_delta={d_acc_lean:.2e};"
        f"pow_rel_delta={d_pow_lean:.2e}",
    )
    # Memoization: the same sweep against a cold then warm ResultCache.
    # The warm pass returns every point from disk (cache_hits_total in
    # the repro.obs export equals the grid size) without a single solve.
    from repro.explore.cache import ResultCache

    with tempfile.TemporaryDirectory() as tmp:
        rcache = ResultCache(tmp)
        # Two chunks per solve so the steady-state (second) chunk shows
        # up as a distinct solve_chunk[run] span in traced runs.
        ch = max(1, n // 2)
        t0 = time.perf_counter()
        run_sweep(params, xte, yte, items, n_samples=n, chunk=ch, cache=rcache)
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        warm = run_sweep(
            params, xte, yte, items, n_samples=n, chunk=ch, cache=rcache
        )
        t_warm = time.perf_counter() - t0
        emit(
            "sweep/cache_warm_rerun",
            t_warm / len(items) * 1e6,
            f"total_s={t_warm:.3f};cold_s={t_cold:.2f};"
            f"hits={rcache.hits};misses={rcache.misses};"
            f"all_cached={all(r.cached for r in warm)}",
        )

    front = pareto_front(batched)
    emit("sweep/pareto_front", 0.0, ";".join(batched[i].name for i in front))
    if speedup_seed < 3.0:
        print(
            f"WARNING: engine speedup {speedup_seed:.2f}x vs the seed "
            f"per-config loop is below the 3x target"
        )
    _shard_axis(params, xte, yte)
    return batched


def _shard_axis(params, xte, yte):
    """Device-count scaling of the sharded engine on ONE structure group.

    Times the same sweep unsharded (the 1-device reference) and sharded
    at 2 and all devices. The workload is one large group (every config
    shares the default structure) so the stacked solve is the whole
    run; samples >> configs keeps steady-state chunk execution — not
    the one-time trace/compile — dominant.
    """
    n_dev = len(jax.devices())
    if n_dev < 2:
        emit(
            "sweep/shard_speedup",
            0.0,
            f"x=1.00;devices={n_dev};skipped=single_device_host",
        )
        return
    from repro.distributed.sweep import MeshPlan

    techs = ("MRAM", "RRAM", "CBRAM", "PCM")
    base = cross_product()[0][1]
    items = [
        (
            f"{techs[i % len(techs)]}/r{5.0 + 0.5 * (i // len(techs)):g}",
            dataclasses.replace(
                base,
                tech=techs[i % len(techs)],
                r_tia=5.0 + 0.5 * (i // len(techs)),
            ),
        )
        for i in range(N_SHARD_CONFIGS)
    ]
    n, ch = N_SHARD_SAMPLES, max(1, N_SHARD_SAMPLES // 4)
    times = {}
    for d in sorted({1, 2, n_dev}):
        shard = None if d == 1 else MeshPlan(devices=d, min_group=2)
        t0 = time.perf_counter()
        run_sweep(params, xte, yte, items, n_samples=n, chunk=ch,
                  shard=shard)
        times[d] = time.perf_counter() - t0
        emit(
            f"sweep/shard_d{d}",
            times[d] / len(items) * 1e6,
            f"total_s={times[d]:.2f};devices={d};configs={len(items)};"
            f"samples={n};points_per_s={len(items) / times[d]:.2f}",
        )
    speedup = times[1] / times[n_dev]
    gate = MIN_SHARD_SPEEDUP if MIN_SHARD_SPEEDUP > 0 else "report-only"
    emit(
        "sweep/shard_speedup",
        0.0,
        f"x={speedup:.2f};devices={n_dev};min={gate}",
    )
    if MIN_SHARD_SPEEDUP > 0 and speedup < MIN_SHARD_SPEEDUP:
        raise AssertionError(
            f"sharded sweep speedup {speedup:.2f}x on {n_dev} devices is "
            f"below the BENCH_SHARD_MIN_SPEEDUP={MIN_SHARD_SPEEDUP:g} gate"
        )


if __name__ == "__main__":
    run()
