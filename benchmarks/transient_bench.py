"""Batched transient co-simulation engine vs a per-config Python loop.

Two claims, both asserted here:

1. Stacking configurations along the leading axis and integrating them
   as ONE jitted scan beats an equivalent per-config loop (which
   re-traces and re-compiles its own scan per configuration, exactly as
   a one-netlist-at-a-time SPICE flow would) by >= 3x at >= 8 configs.
   With refinement off, the stacked lanes are the same arithmetic as the
   solo runs, so per-config latencies/energies must agree.

2. On the quickstart configuration (MRAM, 32x32 subarrays, the paper's
   400x120x84x10 MLP), the waveform-measured latency is finite,
   positive, and monotonically nondecreasing in the interconnect
   capacitance per segment — and reproduces the analytic Elmore
   estimate's RC time-constant ordering (the crossvalidation path).

BENCH_TRAN_CONFIGS (default 8) and BENCH_TRAN_STEPS (default 32) size
the speedup workload; BENCH_TRAN_QSTEPS (default 24) the quickstart
crossvalidation.
"""
from __future__ import annotations

import math
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, mnist_like_fixture
from repro.core.imac import IMACConfig
from repro.transient import TransientSpec, crossvalidate_settling, run_transient

N_CONFIGS = int(os.environ.get("BENCH_TRAN_CONFIGS", "8"))
N_STEPS = int(os.environ.get("BENCH_TRAN_STEPS", "32"))
N_QSTEPS = int(os.environ.get("BENCH_TRAN_QSTEPS", "24"))


def _small_net():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    params = [
        (jax.random.normal(k1, (24, 12)) * 0.4, jnp.zeros((12,))),
        (jax.random.normal(k2, (12, 6)) * 0.4, jnp.zeros((6,))),
    ]
    x = jax.random.uniform(k3, (8, 24))
    return params, x


def run():
    # ---- 1. stacked integration vs per-config loop -------------------
    params, x = _small_net()
    # refine_passes=0: the refinement window is a batch max, so solo and
    # stacked runs would legitimately pick different fine steps; with a
    # single pass the lanes are the same arithmetic and must agree.
    spec = TransientSpec(
        t_stop=20e-9, n_steps=N_STEPS, gs_iters=6, n_probe=2, refine_passes=0
    )
    cfgs = [
        IMACConfig(
            tech="MRAM", array_rows=8, array_cols=8,
            r_source=60.0 + 10.0 * i, transient=spec,
        )
        for i in range(N_CONFIGS)
    ]

    t0 = time.perf_counter()
    loop = [run_transient(params, [c], x, spec=spec) for c in cfgs]
    t_loop = time.perf_counter() - t0

    t0 = time.perf_counter()
    batched = run_transient(params, cfgs, x, spec=spec)
    t_batched = time.perf_counter() - t0

    lat_loop = [float(r.latency[0]) for r in loop]
    lat_batch = [float(v) for v in batched.latency]
    for a, b in zip(lat_loop, lat_batch):
        if not math.isclose(a, b, rel_tol=1e-5):
            raise AssertionError(
                f"stacked integration diverged from the per-config loop: "
                f"{lat_batch} vs {lat_loop}"
            )

    speedup = t_loop / t_batched
    emit(
        "transient/per_config_loop",
        t_loop / N_CONFIGS * 1e6,
        f"total_s={t_loop:.2f};configs={N_CONFIGS};steps={N_STEPS}",
    )
    emit(
        "transient/batched_engine",
        t_batched / N_CONFIGS * 1e6,
        f"total_s={t_batched:.2f};configs={N_CONFIGS};steps={N_STEPS}",
    )
    emit(
        "transient/speedup_vs_loop",
        0.0,
        f"x={speedup:.2f};per_config_identical=1",
    )
    if speedup < 3.0:
        raise AssertionError(
            f"transient engine speedup {speedup:.2f}x vs the per-config "
            f"loop is below the 3x target (measured 7-10x; the loop "
            f"re-traces per config, so a miss means the batching broke)"
        )

    # ---- 2. quickstart-config crossvalidation ------------------------
    qparams, xte, _, _ = mnist_like_fixture()
    qspec = TransientSpec(
        t_stop=20e-9, n_steps=N_QSTEPS, gs_iters=4, n_probe=1,
        refine_passes=1,
    )
    qcfg = IMACConfig(tech="MRAM", array_rows=32, array_cols=32)
    t0 = time.perf_counter()
    recs = crossvalidate_settling(
        qparams, xte, qcfg,
        cap_scales=(1.0, 500.0, 1500.0, 3000.0), spec=qspec,
    )
    t_xval = time.perf_counter() - t0

    measured = [r["measured"] for r in recs]
    analytic = [r["analytic"] for r in recs]
    for r in recs:
        if not (math.isfinite(r["measured"]) and r["measured"] > 0.0):
            raise AssertionError(f"non-finite/non-positive latency: {r}")
    for a, b in zip(measured, measured[1:]):
        if b < a:
            raise AssertionError(
                f"measured latency not nondecreasing in c_segment: {measured}"
            )
    order_m = sorted(range(len(recs)), key=lambda i: measured[i])
    order_a = sorted(range(len(recs)), key=lambda i: analytic[i])
    if order_m != order_a:
        raise AssertionError(
            f"measured settling disagrees with the analytic RC ordering: "
            f"{order_m} vs {order_a}"
        )
    for r in recs:
        emit(
            f"transient/xval_cap_x{r['scale']:g}",
            0.0,
            f"analytic_ns={r['analytic'] * 1e9:.2f};"
            f"measured_ns={r['measured'] * 1e9:.2f};"
            f"energy_nJ={r['energy'] * 1e9:.3f};settled={int(r['settled'])}",
        )
    emit(
        "transient/xval_stacked",
        t_xval / len(recs) * 1e6,
        f"total_s={t_xval:.2f};configs={len(recs)};ordering_agrees=1",
    )
    return recs


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
