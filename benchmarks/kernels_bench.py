"""Pallas kernel microbenches (interpret mode on CPU: correctness-scale
timings; TPU wall-times come from the roofline analysis instead)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.imac_mvm.ref import imac_mvm_ref
from repro.kernels.tridiag.ref import tridiag_ref


def run():
    key = jax.random.PRNGKey(0)

    # tridiag: solver-shaped workload (lanes = tiles*samples)
    shape = (2048, 64)
    d = 2.0 + jax.random.uniform(key, shape)
    dl = -jax.random.uniform(jax.random.PRNGKey(1), shape)
    du = -jax.random.uniform(jax.random.PRNGKey(2), shape)
    b = jax.random.normal(jax.random.PRNGKey(3), shape)
    us, _ = time_call(jax.jit(tridiag_ref), dl, d, du, b)
    emit("kernels/tridiag_ref_2048x64", us, f"systems={shape[0]}")

    # imac_mvm: analog projection (ref path; kernel validated in tests)
    x = jax.random.uniform(jax.random.PRNGKey(4), (256, 1024))
    w = jax.random.uniform(jax.random.PRNGKey(5), (1024, 1024), minval=-1, maxval=1)
    us, _ = time_call(jax.jit(lambda x, w: imac_mvm_ref(x, w, dac_bits=8, levels=16)), x, w)
    flops = 2 * 256 * 1024 * 1024
    emit("kernels/imac_mvm_ref_256x1024x1024", us, f"gflops={flops/us/1e3:.2f}")

    # decode attention: long-context single step
    q = jax.random.normal(jax.random.PRNGKey(6), (4, 32, 128), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(7), (4, 8192, 8, 128), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(8), (4, 8192, 8, 128), jnp.bfloat16)
    us, _ = time_call(jax.jit(decode_attention_ref), q, k, v)
    emit("kernels/decode_attn_ref_s8192", us, "bf16;gqa4x")
