"""Circuit-solver scaling: batched tridiagonal-GS vs dense MNA oracle.

The adapted engine's value proposition: one SPICE-style DC solve per
(tile x sample) batched on accelerator-friendly primitives. Reports
us/solve across array sizes and the dense-MNA crossover.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_call
from repro.core.devices import MRAM
from repro.core.solver import (
    CircuitParams,
    solve_crossbar,
    solve_dense_mna,
    suggest_iters,
)


def run():
    key = jax.random.PRNGKey(0)
    for size in (16, 32, 64, 128, 256, 512):
        g = jax.random.uniform(
            key, (size, size), minval=MRAM.g_off, maxval=MRAM.g_on
        )
        v = jax.random.uniform(jax.random.PRNGKey(1), (size,), maxval=0.8)
        cp = CircuitParams(gs_iters=suggest_iters(size, size))
        fn = jax.jit(lambda g, v: solve_crossbar(g, v, cp).i_out)
        us, _ = time_call(fn, g, v)
        emit(f"solver/gs_{size}x{size}", us, f"iters={cp.gs_iters}")
        if size <= 32:
            fn_mna = jax.jit(lambda g, v: solve_dense_mna(g, v, cp).i_out)
            us_mna, _ = time_call(fn_mna, g, v)
            emit(f"solver/mna_{size}x{size}", us_mna, "oracle")

    # Batched throughput: the paper's workload shape (52 tiles x batch).
    g = jax.random.uniform(key, (104, 32, 32), minval=MRAM.g_off, maxval=MRAM.g_on)
    v = jax.random.uniform(jax.random.PRNGKey(2), (64, 104, 32), maxval=0.8)
    cp = CircuitParams(gs_iters=suggest_iters(32, 32))
    fn = jax.jit(lambda g, v: solve_crossbar(g[None], v, cp).i_out)
    us, out = time_call(fn, g, v)
    n_solves = 64 * 104
    emit("solver/batched_tiles", us / n_solves, f"solves={n_solves};us_total={us:.0f}")
