"""Circuit-solver scaling: batched tridiagonal-GS vs dense MNA oracle.

The adapted engine's value proposition: one SPICE-style DC solve per
(tile x sample) batched on accelerator-friendly primitives. Reports
us/solve across array sizes and the dense-MNA crossover, then times the
paper's batched-tiles workload once per registered solver backend
("scan" / "pallas" / "fused") and — on a real TPU only — asserts the
fused kernel's >= 3x speedup over the scan baseline. Off-TPU the Pallas
backends run in interpret mode, whose timings are meaningless, so the
kernel rows are skipped (never faked) unless BENCH_SOLVER_TINY=1
shrinks the workload enough for an interpret-mode correctness pass.
"""
from __future__ import annotations

import os

import jax
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.backends import on_tpu
from repro.core.devices import MRAM
from repro.core.solver import (
    CircuitParams,
    SolveOptions,
    solve_crossbar,
    solve_dense_mna,
    suggest_iters,
)

FUSED_MIN_SPEEDUP = 3.0


def run():
    tiny = os.environ.get("BENCH_SOLVER_TINY", "") == "1"
    key = jax.random.PRNGKey(0)
    sizes = (8, 16) if tiny else (16, 32, 64, 128, 256, 512)
    for size in sizes:
        g = jax.random.uniform(
            key, (size, size), minval=MRAM.g_off, maxval=MRAM.g_on
        )
        v = jax.random.uniform(jax.random.PRNGKey(1), (size,), maxval=0.8)
        cp = CircuitParams(gs_iters=suggest_iters(size, size))
        fn = jax.jit(lambda g, v: solve_crossbar(g, v, cp).i_out)
        us, _ = time_call(fn, g, v)
        emit(f"solver/gs_{size}x{size}", us, f"iters={cp.gs_iters}")
        if size <= 32:
            fn_mna = jax.jit(lambda g, v: solve_dense_mna(g, v, cp).i_out)
            us_mna, _ = time_call(fn_mna, g, v)
            emit(f"solver/mna_{size}x{size}", us_mna, "oracle")

    # Batched throughput: the paper's workload shape (52 tiles x batch),
    # timed once per solver backend.
    if tiny:
        tiles, size, batch = 4, 8, 2
    else:
        tiles, size, batch = 104, 32, 64
    g = jax.random.uniform(
        key, (tiles, size, size), minval=MRAM.g_off, maxval=MRAM.g_on
    )
    v = jax.random.uniform(
        jax.random.PRNGKey(2), (batch, tiles, size), maxval=0.8
    )
    cp = CircuitParams(gs_iters=suggest_iters(size, size))
    n_solves = batch * tiles

    # Interpret-mode Pallas timings are not representative: off-TPU the
    # kernel backends only run in tiny mode (as a correctness pass).
    kernel_backends_run = on_tpu() or tiny
    backends = ("scan", "pallas", "fused") if kernel_backends_run else ("scan",)
    per_backend_us = {}
    ref_out = None
    for backend in backends:
        opts = SolveOptions(backend=backend)
        fn = jax.jit(
            lambda g, v, o=opts: solve_crossbar(g[None], v, cp, options=o).i_out
        )
        us, out = time_call(fn, g, v)
        per_backend_us[backend] = us
        emit(
            f"solver/batched_tiles[{backend}]",
            us / n_solves,
            f"solves={n_solves};us_total={us:.0f}",
        )
        if ref_out is None:
            ref_out = out
        else:
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(ref_out), rtol=5e-4, atol=1e-9
            )

    if on_tpu():
        speedup = per_backend_us["scan"] / per_backend_us["fused"]
        emit(
            "solver/fused_speedup",
            speedup,
            f"target>={FUSED_MIN_SPEEDUP}x(scan/fused)",
        )
        assert speedup >= FUSED_MIN_SPEEDUP, (
            f"fused backend speedup {speedup:.2f}x over scan is below the "
            f"{FUSED_MIN_SPEEDUP}x target on the batched_tiles workload"
        )
    else:
        emit(
            "solver/fused_speedup",
            0.0,
            "skipped=no-TPU(interpret-mode timings not representative)",
        )
