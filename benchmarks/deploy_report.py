"""IMAC deployment planning for the assigned archs (the paper's
design-space exploration at LLM scale): tiles/devices/power/area per
architecture on 512x512 PCM subarrays (+ a tech sweep for one arch)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs import ARCHS
from repro.core.planner import plan_arch


def run():
    for name in sorted(ARCHS):
        rep = plan_arch(ARCHS[name], tech="PCM", array_rows=512, array_cols=512)
        r = rep.as_row()
        emit(
            f"deploy/{name}",
            0.0,
            f"tiles={r['tiles']};devices={r['devices']:.3e};"
            f"power_w={r['est_power_w']};area_mm2={r['area_mm2']};"
            f"latency_ns={r['est_latency_ns']}",
        )
    # Device-technology sensitivity on one mid-size arch.
    for tech in ("MRAM", "RRAM", "CBRAM", "PCM"):
        rep = plan_arch(ARCHS["yi-9b"], tech=tech, array_rows=512, array_cols=512)
        emit(
            f"deploy/yi-9b/{tech}",
            0.0,
            f"power_w={rep.as_row()['est_power_w']}",
        )
