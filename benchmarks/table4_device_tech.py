"""Paper Table IV: memristive device technology sweep (MRAM/RRAM/CBRAM/
PCM) at fixed H_P=[13,4,3], V_P=[4,3,1].

All four technologies share one traced structure, so the exploration
engine (repro.explore) evaluates the whole table as a single stacked
circuit solve — one compilation instead of four.
"""
from __future__ import annotations

import time

from benchmarks.common import N_SAMPLES, emit, mnist_like_fixture
from repro.configs.imac_mnist import TABLE_IV_CONFIGS
from repro.explore import run_sweep


def run():
    params, xte, yte, _ = mnist_like_fixture()
    t0 = time.perf_counter()
    results = run_sweep(
        params, xte, yte, TABLE_IV_CONFIGS, n_samples=N_SAMPLES, chunk=32
    )
    us_per_cfg = (time.perf_counter() - t0) / len(results) * 1e6
    rows = []
    for r in results:
        res = r.result
        tech = r.config.resolved_tech()
        emit(
            f"table4/{r.name}",
            us_per_cfg / res.n_samples,
            f"acc={res.accuracy:.4f};power_w={res.avg_power:.3f};"
            f"rlow={tech.r_low:.0f};rhigh={tech.r_high:.0f}",
        )
        rows.append((r.name, res))
    by = {n: r for n, r in rows}
    trends = {
        "pcm_least_power": by["PCM"].avg_power == min(r.avg_power for _, r in rows),
        "pcm_top_accuracy": by["PCM"].accuracy == max(r.accuracy for _, r in rows),
        "rram_more_power_than_pcm": by["RRAM"].avg_power > by["PCM"].avg_power,
    }
    emit("table4/trends", 0.0, ";".join(f"{k}={v}" for k, v in trends.items()))
    return rows
