"""Paper Table IV: memristive device technology sweep (MRAM/RRAM/CBRAM/
PCM) at fixed H_P=[13,4,3], V_P=[4,3,1]."""
from __future__ import annotations

import time

from benchmarks.common import N_SAMPLES, emit, mnist_like_fixture
from repro.configs.imac_mnist import TABLE_IV_CONFIGS
from repro.core.evaluate import test_imac


def run():
    params, xte, yte, dig_acc = mnist_like_fixture()
    rows = []
    for name, cfg in TABLE_IV_CONFIGS:
        t0 = time.perf_counter()
        res = test_imac(params, xte, yte, cfg, n_samples=N_SAMPLES, chunk=32)
        dt = time.perf_counter() - t0
        emit(
            f"table4/{name}",
            dt / res.n_samples * 1e6,
            f"acc={res.accuracy:.4f};power_w={res.avg_power:.3f};"
            f"rlow={cfg.resolved_tech().r_low:.0f};"
            f"rhigh={cfg.resolved_tech().r_high:.0f}",
        )
        rows.append((name, res))
    by = {n: r for n, r in rows}
    trends = {
        "pcm_least_power": by["PCM"].avg_power == min(r.avg_power for _, r in rows),
        "pcm_top_accuracy": by["PCM"].accuracy == max(r.accuracy for _, r in rows),
        "rram_more_power_than_pcm": by["RRAM"].avg_power > by["PCM"].avg_power,
    }
    emit("table4/trends", 0.0, ";".join(f"{k}={v}" for k, v in trends.items()))
    return rows
