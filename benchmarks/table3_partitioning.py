"""Paper Table III: IMAC array partitioning design space.

Reproduces the array-size sweep (32x32 .. 512x512, auto H_P/V_P) plus
the over-partitioned [16,8,8]/[8,8,1] row, reporting accuracy and
average power for each configuration on the 400x120x84x10 MLP.

Evaluated through the batched exploration engine (repro.explore); each
partitioning is its own traced structure here, so the win over the
per-config loop is modest — see sweep_bench for the grouped case.
"""
from __future__ import annotations

import time

from benchmarks.common import N_SAMPLES, emit, mnist_like_fixture
from repro.configs.imac_mnist import TABLE_III_CONFIGS
from repro.explore import run_sweep


def run():
    params, xte, yte, dig_acc = mnist_like_fixture()
    emit("table3/digital_reference", 0.0, f"acc={dig_acc:.4f}")
    t0 = time.perf_counter()
    results = run_sweep(
        params, xte, yte, TABLE_III_CONFIGS, n_samples=N_SAMPLES, chunk=32
    )
    us_per_cfg = (time.perf_counter() - t0) / len(results) * 1e6
    rows = []
    for r in results:
        res = r.result
        emit(
            f"table3/{r.name}",
            us_per_cfg / res.n_samples,
            f"acc={res.accuracy:.4f};power_w={res.avg_power:.3f};"
            f"hp={list(res.hp)};vp={list(res.vp)};lat_ns={res.latency*1e9:.1f}",
        )
        rows.append((r.name, res))
    # Trend assertions (soft — printed, not raised):
    by = {n: r for n, r in rows}
    trends = {
        "acc_32_ge_128": by["32x32"].accuracy >= by["128x128"].accuracy,
        "acc_128_collapsed": by["128x128"].accuracy < 0.5,
        "pwr_32_gt_512": by["32x32"].avg_power > by["512x512"].avg_power,
        "hp16_acc_ge_auto": by["32x32-hp16"].accuracy >= by["32x32"].accuracy,
        "hp16_pwr_gt_auto": by["32x32-hp16"].avg_power > by["32x32"].avg_power,
    }
    emit("table3/trends", 0.0, ";".join(f"{k}={v}" for k, v in trends.items()))
    return rows
