"""Declarative specification of a Monte-Carlo reliability analysis.

A `VariabilitySpec` names everything a batched Monte-Carlo run needs:
how many trials to draw, the PRNG seed, optional overrides of the
device technology's non-ideality knobs (programming variation, finite
conductance levels, read noise), stuck-at fault-injection rates, and the
accuracy threshold the yield metric is computed against.

The spec is a frozen, hashable dataclass so it can ride on
`IMACConfig.variability`, be swept as a `SweepSpec` axis, and be
fingerprinted by the on-disk result cache.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.devices import DeviceTech


@dataclasses.dataclass(frozen=True)
class VariabilitySpec:
    """One Monte-Carlo reliability analysis.

    Attributes:
      trials: number of independent variation trials T.
      seed: PRNG seed the T trial keys are split from (ignored when
        explicit per-trial keys are passed to the engine).
      sigma_rel: override of the technology's lognormal programming
        variation (None = use the tech's own `sigma_rel`).
      levels: override of the technology's programmable conductance
        levels (None = use the tech's own `levels`).
      read_noise_rel: override of the technology's per-access Gaussian
        read-current noise (None = use the tech's own).
      p_stuck_on: per-device probability of a stuck-at-G_on fault.
      p_stuck_off: per-device probability of a stuck-at-G_off fault.
      acc_threshold: accuracy bar for the yield metric
        P(accuracy >= acc_threshold).
    """

    trials: int = 8
    seed: int = 0
    sigma_rel: Optional[float] = None
    levels: Optional[int] = None
    read_noise_rel: Optional[float] = None
    p_stuck_on: float = 0.0
    p_stuck_off: float = 0.0
    acc_threshold: float = 0.9

    def __post_init__(self):
        if self.trials < 1:
            raise ValueError(f"need at least one trial, got {self.trials}")
        for name in ("p_stuck_on", "p_stuck_off"):
            p = getattr(self, name)
            if not (0.0 <= p <= 1.0):
                raise ValueError(f"{name} must be a probability, got {p}")
        if self.p_stuck_on + self.p_stuck_off > 1.0:
            raise ValueError(
                "p_stuck_on + p_stuck_off must be <= 1, got "
                f"{self.p_stuck_on} + {self.p_stuck_off}"
            )

    @property
    def has_faults(self) -> bool:
        return self.p_stuck_on > 0.0 or self.p_stuck_off > 0.0

    def is_deterministic_for(self, tech: DeviceTech) -> bool:
        """True when trials of this spec on `tech` carry no stochastic
        content (no programming variation, no read noise, no faults) —
        all T trials are then bitwise identical and one solve suffices."""
        resolved = self.resolve_tech(tech)
        return (
            resolved.sigma_rel <= 0.0
            and resolved.read_noise_rel <= 0.0
            and not self.has_faults
        )

    def resolve_tech(self, tech: DeviceTech) -> DeviceTech:
        """Apply the spec's non-ideality overrides to a technology."""
        overrides = {}
        if self.sigma_rel is not None:
            overrides["sigma_rel"] = self.sigma_rel
        if self.levels is not None:
            overrides["levels"] = self.levels
        if self.read_noise_rel is not None:
            overrides["read_noise_rel"] = self.read_noise_rel
        return dataclasses.replace(tech, **overrides) if overrides else tech
