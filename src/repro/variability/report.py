"""Yield-style summary statistics of a Monte-Carlo reliability run.

A `ReliabilityReport` condenses T per-trial `IMACResult`s into the
distributional quantities a designer actually asks for: accuracy
mean/std/min/max and quantiles, worst-case power, and the yield
P(accuracy >= threshold). It proxies `accuracy`/`avg_power` to the trial
means so every consumer of point results — the Pareto extractor, report
printers — works on reliability results unchanged, while quantile-aware
objectives (repro.explore.pareto.RELIABILITY_OBJECTIVES) can address
`acc_q05`/`power_worst` directly.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np

from repro.core.evaluate import IMACResult

# Fixed quantile grid: (attribute suffix, quantile).
ACC_QUANTILES = (
    ("q05", 0.05),
    ("q25", 0.25),
    ("q50", 0.50),
    ("q75", 0.75),
    ("q95", 0.95),
)


class ReliabilityReport(NamedTuple):
    """Distributional statistics over T Monte-Carlo variation trials."""

    n_trials: int
    acc_mean: float
    acc_std: float
    acc_min: float
    acc_max: float
    acc_q05: float
    acc_q25: float
    acc_q50: float
    acc_q75: float
    acc_q95: float
    acc_threshold: float      # the yield bar
    yield_frac: float         # P(accuracy >= acc_threshold)
    power_mean: float         # W, mean over trials of per-trial avg power
    power_worst: float        # W, worst trial
    latency: float            # s, trial mean (analytic latency is
                              # structural and identical across trials;
                              # waveform-measured latency varies with the
                              # trial's drawn conductances)
    digital_accuracy: float   # float-model reference
    worst_residual: float     # worst solver residual across trials
    n_samples: int
    per_trial_accuracy: tuple
    per_trial_power: tuple
    hp: tuple
    vp: tuple
    # Waveform-derived distribution (repro.transient): when the design
    # point carries a TransientSpec every trial integrates its own
    # transient, so latency and energy become per-trial quantities.
    latency_worst: float = 0.0   # s, slowest trial
    energy_mean: float = 0.0     # J, mean energy per inference
    energy_worst: float = 0.0    # J, worst trial
    per_trial_latency: tuple = ()
    per_trial_energy: tuple = ()

    # IMACResult-compatible aliases: point-result consumers (default
    # Pareto objectives, report tables) read the trial means.
    @property
    def accuracy(self) -> float:
        return self.acc_mean

    @property
    def avg_power(self) -> float:
        return self.power_mean

    @property
    def error_rate(self) -> float:
        return 1.0 - self.acc_mean


def summarize(
    results: "Sequence[IMACResult]",
    *,
    acc_threshold: float = 0.9,
) -> ReliabilityReport:
    """Condense per-trial IMACResults into a ReliabilityReport."""
    if not results:
        raise ValueError("need at least one trial result to summarize")
    accs = np.array([r.accuracy for r in results], dtype=float)
    powers = np.array([r.avg_power for r in results], dtype=float)
    latencies = np.array([r.latency for r in results], dtype=float)
    energies = np.array([r.energy for r in results], dtype=float)
    q = {
        name: float(np.quantile(accs, frac)) for name, frac in ACC_QUANTILES
    }
    return ReliabilityReport(
        n_trials=len(results),
        acc_mean=float(accs.mean()),
        acc_std=float(accs.std()),
        acc_min=float(accs.min()),
        acc_max=float(accs.max()),
        acc_q05=q["q05"],
        acc_q25=q["q25"],
        acc_q50=q["q50"],
        acc_q75=q["q75"],
        acc_q95=q["q95"],
        acc_threshold=acc_threshold,
        yield_frac=float(np.mean(accs >= acc_threshold)),
        power_mean=float(powers.mean()),
        power_worst=float(powers.max()),
        latency=float(latencies.mean()),
        digital_accuracy=results[0].digital_accuracy,
        worst_residual=float(max(r.worst_residual for r in results)),
        n_samples=results[0].n_samples,
        per_trial_accuracy=tuple(float(a) for a in accs),
        per_trial_power=tuple(float(p) for p in powers),
        hp=tuple(results[0].hp),
        vp=tuple(results[0].vp),
        latency_worst=float(latencies.max()),
        energy_mean=float(energies.mean()),
        energy_worst=float(energies.max()),
        per_trial_latency=tuple(float(v) for v in latencies),
        per_trial_energy=tuple(float(v) for v in energies),
    )
