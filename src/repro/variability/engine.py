"""Batched Monte-Carlo reliability engine.

IMAC-Sim's yield question — "how does this design behave across device
variation draws?" — used to be answered by a Python loop: redraw, remap,
re-simulate, T times, re-tracing and re-compiling the circuit solve per
trial. Here the T trials are drawn as a stacked leading axis (vectorized
lognormal programming variation, level quantization and stuck-at fault
masks over per-trial PRNG keys) and the whole batch runs through ONE
jitted circuit solve via `core.evaluate.evaluate_batch` — the same
leading-config-axis machinery the design-space engine uses, because a
Monte-Carlo run IS a batch of structurally-identical configurations that
differ only in conductance leaves.

Trial sampling mirrors `core.mapping.map_network`'s key derivation
exactly (split per layer, then per differential polarity), so trial t of
a batched run is bitwise-identical to a per-trial
``test_imac(..., variation_key=keys[t])`` loop for the same keys — see
tests/test_variability.py and benchmarks/variability_bench.py. One
deliberate difference: when the resolved technology has read noise, the
engine injects an independent spec-seeded draw per trial, which the old
loop (variation_key only, no noise_key) never drew at all.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.devices import apply_stuck_faults, sample_stuck_faults
from repro.core.digital import Params
from repro.core.evaluate import evaluate_batch
from repro.core.imac import IMACConfig
from repro.core.mapping import MappedLayer, map_network
from repro.distributed.sweep import as_mesh_plan
from repro.variability.report import ReliabilityReport, summarize
from repro.variability.spec import VariabilitySpec

# Distinct fold_in tags so the fault and read-noise streams never collide
# with the programming-variation stream (which consumes the raw trial
# key exactly like map_network does).
_FAULT_SALT = 0x0FA17
_NOISE_SALT = 0x0A01E


def trial_keys(spec: VariabilitySpec) -> jax.Array:
    """The T per-trial PRNG keys implied by the spec's seed."""
    return jax.random.split(jax.random.PRNGKey(spec.seed), spec.trials)


def reliability_noise_key(spec: VariabilitySpec) -> jax.Array:
    """The read-noise key a reliability run derives from its spec's seed.

    Shared by run_variability and the design-space engine so a point
    evaluated through either path reports identical numbers."""
    return jax.random.fold_in(jax.random.PRNGKey(spec.seed), _NOISE_SALT)


def sample_trial_layers(
    base: "Sequence[MappedLayer]",
    tech,
    spec: VariabilitySpec,
    keys: jax.Array,
) -> "list[tuple[jax.Array, jax.Array]]":
    """Draw T stacked variation trials of a mapped network.

    Args:
      base: the deterministic mapWB output (quantized, unperturbed).
      tech: resolved technology (after spec overrides).
      spec: fault-injection rates.
      keys: (T, 2) stacked trial keys.

    Returns:
      Per layer, (g_pos, g_neg) arrays of shape (T, fan_in+1, fan_out);
      trial t is bitwise-identical to
      ``map_network(..., variation_key=keys[t])`` when faults are off.
    """
    keys = jnp.asarray(keys)
    n_layers = len(base)
    # (T, L, 2): the same split map_network applies to a variation_key.
    layer_keys = jax.vmap(lambda k: jax.random.split(k, n_layers))(keys)
    if spec.has_faults:
        fault_keys = jax.vmap(
            lambda k: jax.random.split(jax.random.fold_in(k, _FAULT_SALT), n_layers)
        )(keys)

    def faulted(g, fkeys):
        def one(k, g1):
            on, off = sample_stuck_faults(
                k, g1.shape, spec.p_stuck_on, spec.p_stuck_off
            )
            return apply_stuck_faults(g1, on, off, tech.g_on, tech.g_off)

        return jax.vmap(one)(fkeys, g)

    stacked = []
    for layer, m in enumerate(base):
        # (T, 2, 2): per-polarity keys, as map_wb's split(variation_key).
        kpn = jax.vmap(jax.random.split)(layer_keys[:, layer])
        g_pos = tech.perturb_trials(kpn[:, 0], m.g_pos)
        g_neg = tech.perturb_trials(kpn[:, 1], m.g_neg)
        if spec.has_faults:
            fpn = jax.vmap(jax.random.split)(fault_keys[:, layer])
            g_pos = faulted(g_pos, fpn[:, 0])
            g_neg = faulted(g_neg, fpn[:, 1])
        stacked.append((g_pos, g_neg))
    return stacked


def expand_trials(
    params: Params,
    cfg: IMACConfig,
    spec: VariabilitySpec,
    *,
    keys: Optional[jax.Array] = None,
    base_mapped: Optional[list] = None,
) -> "tuple[list[IMACConfig], list[MappedLayer]]":
    """Expand one design point into its T Monte-Carlo trial entries.

    Returns (cfgs, mapped_stacked) ready for `evaluate_batch`: T copies
    of the configuration (tech overrides applied, `variability` cleared)
    and a per-layer list of MappedLayer whose g_pos/g_neg carry the
    (T, ...) stacked trial draws (variation + fault masks) and whose k is
    the (T,) sense scale — the trial tensors are sampled directly in the
    form the batched solve consumes, never materialized per trial. The
    design-space engine concatenates these with ordinary point entries
    (core.evaluate.lift_mapped / concat_mapped) to run mixed groups in
    one solve.
    """
    tech = spec.resolve_tech(cfg.resolved_tech())
    cfg_t = dataclasses.replace(cfg, tech=tech, variability=None)
    base = (
        base_mapped
        if base_mapped is not None
        else map_network(params, tech, v_unit=cfg.vdd, quantize=cfg.quantize)
    )
    keys = trial_keys(spec) if keys is None else jnp.asarray(keys)
    n_trials = keys.shape[0]
    stacked = sample_trial_layers(base, tech, spec, keys)
    mapped_stacked = [
        dataclasses.replace(
            base[layer],
            g_pos=gp,
            g_neg=gn,
            k=jnp.full((n_trials,), base[layer].k),
        )
        for layer, (gp, gn) in enumerate(stacked)
    ]
    return [cfg_t] * n_trials, mapped_stacked


def run_variability(
    params: Params,
    x: jax.Array,
    y: jax.Array,
    cfg: IMACConfig,
    spec: Optional[VariabilitySpec] = None,
    *,
    keys: Optional[jax.Array] = None,
    n_samples: Optional[int] = None,
    chunk: int = 256,
    noise_key: Optional[jax.Array] = None,
    activation: str = "sigmoid",
    shard=None,
) -> ReliabilityReport:
    """Batched Monte-Carlo reliability analysis of one design point.

    Draws T variation trials (lognormal programming variation, level
    quantization, Gaussian read noise, stuck-at fault injection per
    `spec`) as a stacked leading axis and runs them through one jitted
    circuit solve instead of T separate `test_imac` calls.

    Args:
      params: trained digital weights/biases [(W, b), ...].
      x, y: evaluation data (digital units / integer labels).
      cfg: the design point. `cfg.variability` is used when `spec` is
        None and the config carries one.
      spec: the Monte-Carlo specification (trials, seed, overrides).
      keys: optional explicit (T, 2) per-trial PRNG keys — overrides
        `spec.trials`/`spec.seed`; trial t then reproduces
        ``test_imac(..., variation_key=keys[t])`` bitwise.
      n_samples: samples per trial evaluation (default: all of x).
      chunk: samples per jitted solve.
      noise_key: read-noise draw; auto-derived from `spec.seed` when the
        resolved technology has read noise and no key is given. Noise is
        drawn independently per trial (`noise_per_config`).
      activation: digital reference activation.
      shard: shard the stacked trial axis across a device mesh — a
        `repro.distributed.sweep.MeshPlan`, True, an int device count,
        or None (single device). Trials with per-trial read-noise draws
        automatically keep the unsharded path (the draws depend on the
        full stacked shape), so sharded reports stay bitwise-identical
        on the circuit-solve path (ideal-MVM power: see
        core.evaluate.evaluate_batch's ``mesh_plan`` caveat).

    Returns:
      ReliabilityReport with accuracy distribution, worst-case power and
      yield P(acc >= spec.acc_threshold).
    """
    if spec is None:
        spec = cfg.variability or VariabilitySpec()
    # Degenerate specs (no variation, no noise, no faults) make all T
    # trials bitwise identical — solve once and replicate the result.
    collapse = (
        keys is None
        and spec.trials > 1
        and spec.is_deterministic_for(cfg.resolved_tech())
    )
    with obs.trace(
        "run_variability", {"trials": spec.trials, "collapsed": collapse}
    ):
        with obs.trace("sample_trials"):
            if collapse:
                keys = trial_keys(spec)[:1]
            cfgs, mapped_stacked = expand_trials(
                params, cfg, spec, keys=keys
            )
            if (
                noise_key is None
                and cfgs[0].resolved_tech().read_noise_rel > 0.0
            ):
                noise_key = reliability_noise_key(spec)
        results = evaluate_batch(
            params,
            x,
            y,
            cfgs,
            n_samples=n_samples,
            chunk=chunk,
            noise_key=noise_key,
            noise_per_config=True,
            activation=activation,
            mapped_stacked=mapped_stacked,
            mesh_plan=as_mesh_plan(shard),
        )
        with obs.trace("summarize"):
            if collapse:
                results = results * spec.trials
            return summarize(results, acc_threshold=spec.acc_threshold)
