"""Batched Monte-Carlo reliability analysis for IMAC designs.

The yield-style question behind IMAC-Sim's device-variation story —
"across programming variation, quantization, read noise and stuck-at
faults, what accuracy distribution does this design actually deliver?" —
answered with one jitted circuit solve over a stacked trial axis:

  spec.VariabilitySpec     trials, seed, non-ideality overrides, fault rates
  engine.run_variability   T trials -> one batched solve -> ReliabilityReport
  engine.expand_trials     trial expansion reused by repro.explore groups
  report.ReliabilityReport accuracy quantiles, worst-case power, yield

Example::

    from repro.variability import VariabilitySpec, run_variability

    spec = VariabilitySpec(trials=32, sigma_rel=0.1, p_stuck_off=1e-3)
    report = run_variability(params, x, y, cfg, spec, n_samples=256)
    print(report.acc_mean, report.acc_q05, report.yield_frac)

Reliability sweeps across the design space go through `repro.explore`:
`SweepSpec` accepts `trials`/`sigma_rel`/`fault_rate`/... axes which
attach a VariabilitySpec to each point, and `run_sweep` batches all
trials of all structurally-compatible points into single solves.
"""
from repro.variability.engine import expand_trials, run_variability, trial_keys
from repro.variability.report import ReliabilityReport, summarize
from repro.variability.spec import VariabilitySpec

__all__ = [
    "ReliabilityReport",
    "VariabilitySpec",
    "expand_trials",
    "run_variability",
    "summarize",
    "trial_keys",
]
