"""Declarative specification of a batched transient co-simulation.

A `TransientSpec` names everything the time-domain engine needs: the
integration horizon and fixed-step count, the implicit method (backward
Euler or trapezoidal), the PWL input ramp, the settling tolerance band,
the adaptive-refinement schedule, the per-step Gauss–Seidel budget and
the periphery capacitances that join `Interconnect.c_segment` in the
node-capacitance assembly.

The spec is a frozen, hashable dataclass so it can ride on
`IMACConfig.transient`, participate in `structure_key` grouping (its
static fields shape the traced scan), be swept as a `SweepSpec` axis and
be fingerprinted by the on-disk result cache.
"""
from __future__ import annotations

import dataclasses

METHODS = ("be", "trap")


@dataclasses.dataclass(frozen=True)
class TransientSpec:
    """One waveform-accurate transient analysis of the mapped circuit.

    Attributes:
      t_stop: integration horizon per layer (seconds). Output waveforms
        that have not entered the settling band by `t_stop` report
        `t_stop` as their settling time (finite by construction).
      n_steps: fixed number of implicit time steps per integration pass
        (static: it is the scan length of the jitted integrator).
      method: 'be' (backward Euler, L-stable, 1st order) or 'trap'
        (trapezoidal companion model, A-stable, 2nd order — SPICE's
        default).
      t_rise: input PWL ramp 0 -> v_in over [0, t_rise]. 0.0 means
        "one coarse step" (t_stop / n_steps), which keeps the drive
        consistent with the initial condition v(0) = 0 and is what the
        generated netlist's PWL sources state.
      rtol / atol: settling band around the steady-state output node
        voltage v_ss: a node is in band when
        |v(t) - v_ss| <= rtol * max|v_ss| + atol (volts).
      refine_passes: adaptive refinement rounds. After each pass the
        window [0, max settling time + refine_margin steps] is re-run
        with the same n_steps, shrinking dt wherever settling happens
        well before t_stop — one extra stacked integration per pass, no
        per-config loops.
      refine_margin: coarse steps of slack added to the refinement
        window.
      gs_iters: Gauss–Seidel sweeps per time step. The capacitor
        companion conductance C/dt stiffens the diagonal and each step
        warm-starts from the previous one, so far fewer sweeps than a
        cold DC solve are needed.
      c_driver: row driver output capacitance (farads), added to the
        row-head node.
      c_tia: TIA input capacitance (farads), added to the column-foot
        node.
      n_probe: evaluation samples used to drive the transient (the
        waveform question is per-design, not per-sample; a handful of
        probe inputs bounds the cost). Settling is the worst case over
        probes, energy the mean.

    Waveform recording is a call-site choice, not a spec field — pass
    ``record=True`` to `run_transient`/`layer_transient` to keep the
    final-pass waveforms.
    """

    t_stop: float = 20e-9
    n_steps: int = 128
    method: str = "trap"
    t_rise: float = 0.0
    rtol: float = 0.02
    atol: float = 1e-6
    refine_passes: int = 1
    refine_margin: int = 2
    gs_iters: int = 8
    c_driver: float = 1e-15
    c_tia: float = 2e-15
    n_probe: int = 2

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(
                f"unknown method {self.method!r}; choose from {METHODS}"
            )
        if self.t_stop <= 0.0:
            raise ValueError(f"t_stop must be positive, got {self.t_stop}")
        if self.t_rise > self.t_stop:
            raise ValueError(
                f"t_rise ({self.t_rise}) must not exceed t_stop "
                f"({self.t_stop}): the drive must reach its target within "
                f"the horizon (and the netlist PWL must stay monotone)"
            )
        if self.n_steps < 2:
            raise ValueError(f"need at least 2 steps, got {self.n_steps}")
        if self.refine_passes < 0:
            raise ValueError(f"refine_passes must be >= 0, got {self.refine_passes}")
        if self.gs_iters < 1:
            raise ValueError(f"gs_iters must be >= 1, got {self.gs_iters}")
        if self.n_probe < 1:
            raise ValueError(f"n_probe must be >= 1, got {self.n_probe}")

    @property
    def dt(self) -> float:
        """Coarse (first-pass) step size in seconds."""
        return self.t_stop / self.n_steps

    def resolved_t_rise(self) -> float:
        """The input ramp time actually integrated (0 -> one coarse step)."""
        return self.t_rise if self.t_rise > 0.0 else self.dt
