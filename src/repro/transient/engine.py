"""Batched transient co-simulation of mapped IMAC networks.

One stacked integration answers the timing/energy question for a whole
batch of structurally-compatible configurations (design-space sweep
points, Monte-Carlo trials): conductances and electrical scalars ride a
leading (C,) axis, exactly as in `core.evaluate.evaluate_batch`'s DC
path, and each layer's parasitic RC network is integrated with the
implicit fixed-step engine in `repro.transient.integrator`.

Semantics mirror the analytic latency model they replace: each layer's
transient is driven by the layer's DC input activations (the behavioural
neuron decouples consecutive crossbars, as IMAC-Sim's subcircuits do),
network latency is the sum of per-layer measured settling times plus the
sampling window, and network energy integrates crossbar dissipation over
the horizon plus the static interface power.

Adaptive refinement: after the coarse pass over [0, t_stop], the window
[0, max settling + margin] is re-integrated with the same step count —
`dt` is a traced scalar, so every pass reuses one compiled scan and the
whole batch refines together (the window is the batch max: still ONE
stacked integration, never a per-config loop).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.digital import Params
from repro.core.evaluate import stack_mapped, structure_key
from repro.core.imac import (
    IMACConfig,
    TransientStats,
    build_plans,
    layer_latency,
)
from repro.core.mapping import map_network
from repro.core.partition import combine_outputs, tile_inputs, tile_matrix
from repro.core.solver import (
    CircuitParams,
    SolveOptions,
    _align,
    solve_crossbar,
    suggest_iters,
)
from repro.transient.integrator import (
    integrate_tiles,
    node_capacitances,
    settle_time,
)
from repro.transient.spec import TransientSpec


class TransientResult(NamedTuple):
    """Network-level outcome of one stacked transient co-simulation."""

    latency: jax.Array        # (C,) sum of layer settling + sampling (s)
    energy: jax.Array         # (C,) integrated energy per inference (J)
    settled: jax.Array        # (C,) bool: every layer in band at its horizon
    layers: "tuple[TransientStats, ...]"  # per-layer detail

    @property
    def layer_settle(self) -> "tuple[jax.Array, ...]":
        return tuple(s.t_settle for s in self.layers)


def layer_transient(
    g_pos: jax.Array,
    g_neg: jax.Array,
    plan,
    cp: CircuitParams,
    spec: TransientSpec,
    a: jax.Array,
    v_unit,
    *,
    c_segment,
    dtype=jnp.float32,
    record: bool = False,
    solve_options: Optional[SolveOptions] = None,
) -> "tuple[TransientStats, jax.Array]":
    """Integrate one layer's parasitic crossbars for a probe batch.

    Args:
      g_pos / g_neg: (..., fan_in+1, fan_out) stacked conductances.
      plan: the layer's partition plan.
      cp: electrical parameters (leading-axis scalars allowed).
      spec: transient specification.
      a: (..., P, fan_in) probe activations in digital units.
      v_unit: drive voltage per digital unit.
      c_segment: wire capacitance per segment — float or (C,) stacked.
      record: keep the final-pass waveform.

    Returns:
      (TransientStats, i_out_ss): the stats carry the leading config
      axis reduced over probes and tiles (settling: worst case; energy:
      probe mean, tile sum); i_out_ss is the (..., P, 2T, N) DC
      steady-state TIA currents — the operating point the transient
      settles onto, which the network engine chains activations from
      without a second DC solve.
    """
    ones = jnp.ones(a.shape[:-1] + (1,), dtype)
    v = jnp.concatenate([a.astype(dtype), ones], axis=-1) * v_unit
    tiles_p = tile_matrix(g_pos.astype(dtype), plan)
    tiles_n = tile_matrix(g_neg.astype(dtype), plan)
    g_all = jnp.concatenate([tiles_p, tiles_n], axis=-3)   # (..., 2T, M, N)
    v_tiles = tile_inputs(v, plan)                         # (..., P, hp, M)
    v_per_tile = jnp.repeat(v_tiles, plan.vp, axis=-2)     # (..., P, T, M)
    v_all = jnp.concatenate([v_per_tile, v_per_tile], axis=-2)
    g_b = g_all[..., None, :, :, :]                        # (..., 1, 2T, M, N)

    # Node capacitances, aligned against g_b's (C, P, 2T, M, N) batch.
    cseg = jnp.asarray(c_segment, dtype)
    if cseg.ndim > 0:
        cseg = cseg[..., None, None]  # (C, 1, 1): broadcast over P and 2T
    c_row, c_col = node_capacitances(
        plan.rows, plan.cols, cseg, spec.c_driver, spec.c_tia, dtype
    )

    # One full-budget DC solve: the settling-band reference for every
    # refinement pass AND the operating point downstream layers chain
    # from.
    ss = solve_crossbar(g_b, v_all, cp, options=solve_options)

    t_rise = spec.resolved_t_rise()
    dt0 = spec.t_stop / spec.n_steps
    record_now = record and spec.refine_passes == 0
    res = integrate_tiles(
        g_b, v_all, cp, spec, dt0,
        c_row=c_row, c_col=c_col, t_rise=t_rise, record=record_now, ss=ss,
        solve_options=solve_options,
    )
    # Reduce probes (axis -2) and tiles (axis -1) of the (C, P, 2T) batch;
    # leading config/trial axes survive.
    last = jnp.max(res.last_oob, axis=(-1, -2))
    settle = settle_time(last, dt0, spec.n_steps)
    # Energy over the full horizon from the coarse pass (second-order
    # trapezoidal quadrature): sum tiles, average probes.
    energy = jnp.mean(jnp.sum(res.energy, axis=-1), axis=-1)
    dt_cur = jnp.asarray(dt0, dtype)
    for p in range(spec.refine_passes):
        window = jnp.minimum(
            spec.t_stop, jnp.max(settle) + spec.refine_margin * dt_cur
        )
        dt_cur = window / spec.n_steps
        record_now = record and p == spec.refine_passes - 1
        res = integrate_tiles(
            g_b, v_all, cp, spec, dt_cur,
            c_row=c_row, c_col=c_col, t_rise=t_rise, record=record_now,
            ss=ss, solve_options=solve_options,
        )
        last = jnp.max(res.last_oob, axis=(-1, -2))
        settle = settle_time(last, dt_cur, spec.n_steps)
    settled = last < spec.n_steps - 1
    stats = TransientStats(
        t_settle=settle,
        energy=energy,
        settled=settled,
        dt=dt_cur,
        waveform=res.waveform if record else None,
    )
    return stats, ss.i_out


def network_transient_stacked(
    g_pos: "Sequence[jax.Array]",
    g_neg: "Sequence[jax.Array]",
    k: "Sequence[jax.Array]",
    scal: dict,
    plans,
    neuron,
    spec: TransientSpec,
    x_probe: jax.Array,
    v_unit,
    iters: "Sequence[int]",
    tol: float,
    dtype=jnp.float32,
    record: bool = False,
    solve_options: Optional[SolveOptions] = None,
) -> TransientResult:
    """Transient co-simulation of a stacked configuration batch.

    Consumes exactly the stacked form `core.evaluate.evaluate_batch`
    assembles: per-layer (C, fan_in+1, fan_out) conductances, (C,) sense
    scales, and a `scal` dict of (C,) electrical scalars (r_seg,
    r_source, r_tia, omega, plus c_seg and t_samp for the transient).
    The whole network — every layer, every refinement pass — runs as one
    jitted computation.
    """
    n_layers = len(plans)

    def run(gp, gn, kk, sc, xb):
        a = xb
        stats = []
        for layer, plan in enumerate(plans):
            cp = CircuitParams(
                r_row=sc["r_seg"],
                r_col=sc["r_seg"],
                r_source=sc["r_source"],
                r_tia=sc["r_tia"],
                gs_iters=iters[layer],
                omega=sc["omega"],
                tol=tol,
            )
            s, i_out_ss = layer_transient(
                gp[layer], gn[layer], plan, cp, spec, a, v_unit,
                c_segment=sc["c_seg"], dtype=dtype, record=record,
                solve_options=solve_options,
            )
            stats.append(s)
            # Chain probe activations through the DC operating point the
            # transient settles onto (noise-free, as the analytic latency
            # model assumes) — reusing the layer's steady-state solve.
            nt = plan.n_tiles
            i_diff = combine_outputs(i_out_ss[..., :nt, :], plan) - (
                combine_outputs(i_out_ss[..., nt:, :], plan)
            )
            z = i_diff / (_align(kk[layer], i_diff.ndim, dtype) * v_unit)
            z = neuron.clip_preactivation(z)
            a = z if layer == n_layers - 1 else neuron.activation(z)
        # Static interface power burns for the whole horizon per layer.
        energy = jnp.zeros_like(stats[0].energy)
        for plan, s in zip(plans, stats):
            n_amps = plan.hp * plan.vp * plan.cols * 2
            p_iface = n_amps * neuron.p_amp + plan.total_cols * neuron.p_neuron
            energy = energy + s.energy + p_iface * spec.t_stop
        settle = sum(s.t_settle for s in stats)
        settled = stats[0].settled
        for s in stats[1:]:
            settled = jnp.logical_and(settled, s.settled)
        return TransientResult(
            latency=settle + sc["t_samp"],
            energy=energy,
            settled=settled,
            layers=tuple(stats),
        )

    integrate = obs.instrument_jit(jax.jit(run), "transient_integrate")
    return integrate(tuple(g_pos), tuple(g_neg), tuple(k), scal, x_probe)


def run_transient(
    params: Params,
    cfgs: "Sequence[IMACConfig] | IMACConfig",
    x: jax.Array,
    *,
    spec: Optional[TransientSpec] = None,
    record: bool = False,
    solve_options: Optional[SolveOptions] = None,
) -> TransientResult:
    """Waveform-accurate latency & energy of one or more configurations.

    All configurations must be structurally compatible (same partition
    plans, neuron, dtype — as grouped by `core.evaluate.structure_key`);
    they stack along a leading axis and integrate as ONE batched scan.

    Args:
      params: trained digital weights/biases [(W, b), ...].
      cfgs: one IMACConfig or a structurally-compatible list.
      x: (N, fan_in) inputs; the first `spec.n_probe` rows drive the
        transient.
      spec: overrides the configs' own `transient` field (a default
        TransientSpec if neither is set).
      record: keep final-pass waveforms in the per-layer stats.

    Returns:
      TransientResult with (C,) latency / energy / settled arrays.
    """
    if isinstance(cfgs, IMACConfig):
        cfgs = [cfgs]
    cfgs = list(cfgs)
    if not cfgs:
        raise ValueError("need at least one configuration")
    cfg0 = cfgs[0]
    spec = spec or cfg0.transient or TransientSpec()
    topology = [params[0][0].shape[0]] + [w.shape[1] for w, _ in params]
    key0 = structure_key(topology, cfg0)
    for c in cfgs:
        if not c.parasitics:
            raise ValueError(
                "transient co-simulation needs parasitics=True (the node "
                "capacitances live on the parasitic wire grid)"
            )
        if structure_key(topology, c) != key0 or c.vdd != cfg0.vdd:
            raise ValueError(
                "run_transient needs structurally-compatible configs "
                "(equal structure_key and vdd); got a mismatch — group "
                "them with repro.explore.run_sweep(timing=...) instead"
            )
    with obs.trace(
        "run_transient", {"configs": len(cfgs), "n_probe": spec.n_probe}
    ):
        plans = build_plans(topology, cfg0)
        dtype = cfg0.dtype
        iters = [
            cfg0.gs_iters or suggest_iters(p.rows, p.cols) for p in plans
        ]
        with obs.trace("map", {"layers": len(plans)}):
            mapped = [
                map_network(
                    params,
                    c.resolved_tech(),
                    v_unit=c.vdd,
                    quantize=c.quantize,
                )
                for c in cfgs
            ]
            g_pos, g_neg, k = stack_mapped(mapped, dtype)
        with obs.trace("stamp"):
            scal = dict(
                r_seg=jnp.asarray(
                    [c.interconnect.r_segment for c in cfgs], dtype
                ),
                r_source=jnp.asarray([c.r_source for c in cfgs], dtype),
                r_tia=jnp.asarray([c.r_tia for c in cfgs], dtype),
                omega=jnp.asarray([c.sor_omega for c in cfgs], dtype),
                c_seg=jnp.asarray(
                    [c.interconnect.c_segment for c in cfgs], dtype
                ),
                t_samp=jnp.asarray([c.t_sampling for c in cfgs], dtype),
            )
        x_probe = jnp.asarray(x[: spec.n_probe], dtype)
        return network_transient_stacked(
            g_pos, g_neg, k, scal, plans, cfg0.resolved_neuron(), spec,
            x_probe, cfg0.vdd, iters, cfg0.gs_tol, dtype=dtype,
            record=record, solve_options=solve_options,
        )


def analytic_latency(cfg: IMACConfig, topology: "Sequence[int]") -> float:
    """The input-independent Elmore estimate the waveform path replaces."""
    plans = build_plans(topology, cfg)
    neuron = cfg.resolved_neuron()
    return (
        sum(layer_latency(p, cfg.interconnect, neuron) for p in plans)
        + cfg.t_sampling
    )


def crossvalidate_settling(
    params: Params,
    x: jax.Array,
    base_cfg: Optional[IMACConfig] = None,
    *,
    cap_scales: "Sequence[float]" = (0.5, 1.0, 2.0, 4.0),
    spec: Optional[TransientSpec] = None,
) -> "list[dict]":
    """Cross-validate measured settling against the analytic RC estimate.

    Scales the interconnect capacitance per segment (the c in every RC
    time constant) and runs ONE stacked integration over all scalings —
    they share a structure_key since c_segment is a numeric leaf. The
    analytic Elmore latency is linear in c_segment, so the measured
    settling times must reproduce its ordering (and be nondecreasing in
    c_segment); tests and benchmarks/transient_bench.py assert this.

    Returns:
      One record per scale: {scale, c_segment, analytic, measured,
      energy, settled}.
    """
    base_cfg = base_cfg or IMACConfig()
    spec = spec or base_cfg.transient or TransientSpec()
    topology = [params[0][0].shape[0]] + [w.shape[1] for w, _ in params]
    cfgs = [
        dataclasses.replace(
            base_cfg,
            interconnect=dataclasses.replace(
                base_cfg.interconnect,
                cap_per_m=base_cfg.interconnect.cap_per_m * s,
            ),
        )
        for s in cap_scales
    ]
    tr = run_transient(params, cfgs, x, spec=spec)
    return [
        {
            "scale": float(s),
            "c_segment": cfg.interconnect.c_segment,
            "analytic": analytic_latency(cfg, topology),
            "measured": float(tr.latency[i]),
            "energy": float(tr.energy[i]),
            "settled": bool(tr.settled[i]),
        }
        for i, (s, cfg) in enumerate(zip(cap_scales, cfgs))
    ]
