"""repro.transient — batched waveform-accurate transient co-simulation.

Public API:
  TransientSpec       — declarative transient analysis specification
  TransientStats      — per-layer waveform-derived statistics
  TransientResult     — network-level latency / energy / settled arrays
  run_transient       — one stacked integration over compatible configs
  crossvalidate_settling — measured settling vs analytic RC ordering
  integrate_tiles / node_capacitances / settle_time — the integrator core
"""
from repro.core.imac import TransientStats  # noqa: F401
from repro.transient.engine import (  # noqa: F401
    TransientResult,
    analytic_latency,
    crossvalidate_settling,
    layer_transient,
    network_transient_stacked,
    run_transient,
)
from repro.transient.integrator import (  # noqa: F401
    TileTransient,
    integrate_tiles,
    node_capacitances,
    settle_time,
)
from repro.transient.spec import TransientSpec  # noqa: F401
