"""Batch-first implicit time-domain integrator over the crossbar MNA.

The parasitic crossbar is a linear RC network: conductance stamps G (the
same assembly the DC solver sweeps) plus a *diagonal* capacitance matrix
C — every wire node carries `Interconnect.c_segment` to ground, row-head
nodes add the driver output capacitance and column-foot nodes the TIA
input capacitance (exactly the Crw/Ccw/Cdrv/Ctia elements the generated
netlist states). Discretizing C dv/dt = b(t) - G v with an implicit rule
turns each time step into a DC solve of the *same* network with a
companion conductance to ground and a history current injection per
node:

  backward Euler:  g_eq = C/dt,  i_eq = g_eq * v_n
  trapezoidal:     g_eq = 2C/dt, i_eq = g_eq * v_n + i_c_n,
                   i_c_{n+1} = g_eq * (v_{n+1} - v_n) - i_c_n

so the step solve reuses `solve_crossbar`'s alternating batched
tridiagonal Gauss–Seidel unchanged (the stamps only fatten the diagonal,
which *improves* its convergence rate), warm-started from the previous
step. Everything — configs, trials, probe samples, tiles — rides leading
batch axes through one `lax.scan`, which is what lets a design-space
sweep or a Monte-Carlo trial batch integrate as ONE stacked scan instead
of a per-config Python loop (see benchmarks/transient_bench.py).

`dt` enters only through companion values, never through shapes, so it
may be a traced scalar: the adaptive refinement passes in
repro.transient.engine re-invoke the same compiled integration with a
shrunken, data-dependent step size.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.solver import (
    CircuitParams,
    CrossbarSolution,
    SolveOptions,
    Stamps,
    _align,
    crossbar_power,
    solve_crossbar,
)
from repro.transient.spec import TransientSpec


def node_capacitances(
    m: int,
    n: int,
    c_segment,
    c_driver: float,
    c_tia: float,
    dtype=jnp.float32,
) -> "tuple[jax.Array, jax.Array]":
    """Per-node capacitance maps of one M x N tile.

    Args:
      m, n: tile rows / cols.
      c_segment: wire capacitance per bitcell segment (farads); a float
        or an array with leading config axes (aligned like the solver's
        electrical scalars).
      c_driver: extra capacitance on each row-head node (j = 0).
      c_tia: extra capacitance on each column-foot node (i = M-1).

    Returns:
      (c_row, c_col): (..., M, N) capacitances of row and column wire
      nodes, in the same (i, j) layout the solver uses — flattening row
      nodes then column nodes reproduces `mna_system`'s node order.
    """
    cseg = jnp.asarray(c_segment, dtype)
    cseg = _align(cseg, cseg.ndim + 2, dtype)  # append (1, 1) node axes
    col_idx = jnp.arange(n)
    row_idx = jnp.arange(m)[:, None]
    c_row = jnp.broadcast_to(cseg, cseg.shape[:-2] + (m, n)) + jnp.where(
        col_idx == 0, jnp.asarray(c_driver, dtype), 0.0
    )
    c_col = jnp.broadcast_to(cseg, cseg.shape[:-2] + (m, n)) + jnp.where(
        row_idx == m - 1, jnp.asarray(c_tia, dtype), 0.0
    )
    return c_row, c_col


class TileTransient(NamedTuple):
    """Result of one stacked fixed-step integration of crossbar tiles.

    Batch shape (...) is the broadcast of g's and v_in's leading axes
    (configs/trials x probes x tiles).
    """

    last_oob: jax.Array    # (...) int32: last step index out of band (-1: never)
    energy: jax.Array      # (...) integral of dissipated power over the horizon (J)
    i_out: jax.Array       # (..., N) TIA currents at t_stop
    i_out_ss: jax.Array    # (..., N) steady-state (DC) TIA currents
    vc_foot: jax.Array     # (..., N) column-foot voltages at t_stop
    waveform: jax.Array    # (..., steps, N) column-foot voltages, or () if not recorded


def integrate_tiles(
    g: jax.Array,
    v_in: jax.Array,
    cp: CircuitParams,
    spec: TransientSpec,
    dt,
    *,
    c_row: jax.Array,
    c_col: jax.Array,
    t_rise: float,
    solve_options: "SolveOptions | None" = None,
    record: bool = False,
    ss: "CrossbarSolution | None" = None,
) -> TileTransient:
    """Integrate crossbar tiles over `spec.n_steps` implicit steps of `dt`.

    Args:
      g: (..., M, N) memristor conductances; leading axes batch configs,
        probes and tiles together.
      v_in: (..., M) final driver voltages; ramped 0 -> v_in over
        [0, t_rise] (PWL drive), v(0) = 0 everywhere.
      cp: electrical parameters (per-config leading-axis scalars allowed,
        exactly as in the DC solve). `cp.gs_iters`/`cp.tol` are ignored
        for the steps — `spec.gs_iters` sweeps run per step; the
        steady-state reference solve uses `cp` as given.
      spec: transient specification (static fields shape the scan).
      dt: step size in seconds — python float or traced scalar.
      c_row / c_col: (..., M, N) node capacitances (`node_capacitances`).
      t_rise: resolved input ramp time (static float).
      record: stack the column-foot waveform (memory: steps x N per
        batch element).
      ss: optional precomputed DC steady state of (g, v_in, cp) — the
        settling-band reference. Callers running several refinement
        passes pass it once instead of re-solving per pass.

    Returns:
      TileTransient; settling is reported as `last_oob` (step index) so
      the caller converts to seconds with its own dt and reduces over
      non-config axes.
    """
    g = jnp.asarray(g)
    v_in = jnp.asarray(v_in)
    m = g.shape[-2]
    dtype = g.dtype
    dt = jnp.asarray(dt, dtype)

    # Steady state the waveforms settle to (full-budget DC solve).
    if ss is None:
        ss = solve_crossbar(g, v_in, cp, options=solve_options)
    vc_ss_foot = ss.vc[..., m - 1, :]
    band = spec.rtol * jnp.max(jnp.abs(vc_ss_foot), axis=-1, keepdims=True) + spec.atol

    cp_step = CircuitParams(
        r_row=cp.r_row,
        r_col=cp.r_col,
        r_source=cp.r_source,
        r_tia=cp.r_tia,
        gs_iters=spec.gs_iters,
        omega=cp.omega,
        tol=0.0,
    )
    trap = spec.method == "trap"
    fac = 2.0 if trap else 1.0
    geq_r = fac * c_row.astype(dtype) / dt
    geq_c = fac * c_col.astype(dtype) / dt
    g_tia = _align(cp.g_tia, g.ndim - 1, dtype)

    batch = jnp.broadcast_shapes(
        g.shape[:-2], v_in.shape[:-1], geq_r.shape[:-2]
    )
    zeros_nodes = jnp.zeros(batch + g.shape[-2:], dtype)

    def step(carry, t):
        vr, vc, ic_r, ic_c, p_prev, e_acc, last_oob, k = carry
        ramp = jnp.clip(t / t_rise, 0.0, 1.0)
        v_t = v_in * ramp
        inj_r = geq_r * vr + (ic_r if trap else 0.0)
        inj_c = geq_c * vc + (ic_c if trap else 0.0)
        sol = solve_crossbar(
            g,
            v_t,
            cp_step,
            stamps=Stamps(
                g_shunt_row=geq_r,
                g_shunt_col=geq_c,
                i_inj_row=jnp.broadcast_to(inj_r, zeros_nodes.shape),
                i_inj_col=jnp.broadcast_to(inj_c, zeros_nodes.shape),
                v_init=vc,
            ),
            options=solve_options,
        )
        if trap:
            ic_r = geq_r * (sol.vr - vr) - ic_r
            ic_c = geq_c * (sol.vc - vc) - ic_c
        p_t = crossbar_power(g, v_t, sol, cp)
        e_acc = e_acc + 0.5 * (p_prev + p_t) * dt
        foot = sol.vc[..., m - 1, :]
        oob = jnp.any(jnp.abs(foot - vc_ss_foot) > band, axis=-1)
        last_oob = jnp.where(oob, k, last_oob)
        out = foot if record else jnp.zeros(batch + (0,), dtype)
        return (sol.vr, sol.vc, ic_r, ic_c, p_t, e_acc, last_oob, k + 1), out

    ts = dt * (1.0 + jnp.arange(spec.n_steps, dtype=dtype))
    init = (
        zeros_nodes,                       # vr(0)
        zeros_nodes,                       # vc(0)
        jnp.zeros_like(geq_r * zeros_nodes),  # capacitor history currents
        jnp.zeros_like(geq_c * zeros_nodes),
        jnp.zeros(batch, dtype),           # p(0) = 0 (all nodes at 0 V)
        jnp.zeros(batch, dtype),           # energy accumulator
        jnp.full(batch, -1, jnp.int32),    # last out-of-band step
        jnp.zeros((), jnp.int32),
    )
    (vr, vc, _, _, _, energy, last_oob, _), wave = jax.lax.scan(step, init, ts)
    foot = vc[..., m - 1, :]
    waveform = (
        jnp.moveaxis(wave, 0, -2) if record else jnp.zeros(())
    )
    return TileTransient(
        last_oob=last_oob,
        energy=energy,
        i_out=g_tia * foot,
        i_out_ss=ss.i_out,
        vc_foot=foot,
        waveform=waveform,
    )


def settle_time(last_oob: jax.Array, dt, n_steps: int):
    """Settling time implied by `last_oob` at step size `dt` (seconds).

    The solve at step k samples t = (k+1) dt; the first sample after the
    last out-of-band one is the measured settling instant. A waveform
    that never leaves the band settles at the first sample (dt); one
    still out of band at the horizon reports the horizon itself.
    """
    dt = jnp.asarray(dt)
    return jnp.minimum((last_oob.astype(dt.dtype) + 2.0) * dt, n_steps * dt)
