"""Model / shape configuration dataclasses.

Every assigned architecture is a ModelConfig instance in its own file
under repro/configs/; `reduced()` derives the small same-family config
used by the CPU smoke tests. The four assigned input shapes are
ShapeConfig instances (train lowers train_step; prefill lowers the
prefill forward; decode/long lower serve_step with a full KV cache).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # attention
    attn_type: str = "gqa"      # gqa | mla | none
    rope_theta: float = 1e4
    # MLA (deepseek-family)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0      # leading dense layers (deepseek-style)
    moe_every: int = 1          # MoE on layers where (idx % moe_every)==moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_type: str = ""          # rwkv6 | mamba
    attn_period: int = 0        # jamba: one attention layer per period
    attn_period_offset: int = 0
    d_state: int = 16
    d_conv: int = 4
    ssm_expand: int = 2
    # encoder-decoder
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1024     # frontend frames fed to the encoder
    # multimodal stub frontends
    modality_prefix: int = 0    # precomputed embedding tokens per sample
    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    mtp: bool = False           # deepseek multi-token prediction
    scale_emb: float = 1.0      # minicpm embedding scale
    scale_depth: float = 0.0    # minicpm residual scale (0 = off)
    scale_logits: float = 1.0   # minicpm: 1 / (d_model / dim_model_base)
    # numerics
    param_dtype: str = "float32"     # master/param dtype
    compute_dtype: str = "bfloat16"
    # FSDP reach: shard params over data only, or pod+data (huge MoE)
    fsdp_over_pod: bool = False
    # the paper's technique as a serving backend
    analog_mvm: bool = False
    analog_tech: str = "PCM"
    # capability markers
    supports_long_context: bool = False   # sub-quadratic decode state
    # implementation detail: embedding/head tables are padded so the
    # vocab dim shards on the 16-wide model axis (Megatron-style vocab
    # padding; logits for pad entries are masked to -inf). The logical
    # vocab (config above) is unchanged.
    pad_vocab_to: int = 256

    @property
    def vocab_padded(self) -> int:
        m = self.pad_vocab_to
        return ((self.vocab + m - 1) // m) * m

    @property
    def moe_enabled(self) -> bool:
        return self.n_experts > 0

    def is_moe_layer(self, idx: int) -> bool:
        if not self.moe_enabled or idx < self.first_k_dense:
            return False
        return (idx % self.moe_every) == self.moe_offset

    def is_attn_layer(self, idx: int) -> bool:
        if self.ssm_type == "rwkv6":
            return False
        if self.attn_period:
            return (idx % self.attn_period) == self.attn_period_offset
        return True

    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        e, f, v = self.d_model, self.d_ff, self.vocab
        h, kv, hd = self.n_heads, self.n_kv_heads, self.head_dim
        total = v * e  # embedding
        if not self.tie_embeddings:
            total += v * e
        enc_layers = self.n_encoder_layers if self.is_encoder_decoder else 0
        for idx in range(self.n_layers + enc_layers):
            if self.is_attn_layer(idx % max(self.n_layers, 1)):
                if self.attn_type == "mla":
                    qk_head = self.qk_nope_head_dim + self.qk_rope_head_dim
                    total += e * (self.q_lora_rank or e)
                    if self.q_lora_rank:
                        total += self.q_lora_rank * h * qk_head
                    total += e * (self.kv_lora_rank + self.qk_rope_head_dim)
                    total += self.kv_lora_rank * h * (
                        self.qk_nope_head_dim + self.v_head_dim
                    )
                    total += h * self.v_head_dim * e
                else:
                    total += e * h * hd + 2 * e * kv * hd + h * hd * e
            elif self.ssm_type == "mamba" or (
                self.attn_period and not self.is_attn_layer(idx)
            ):
                di = self.ssm_expand * e
                total += e * 2 * di + di * self.d_conv + di * (
                    2 * self.d_state + math.ceil(e / 16)
                ) + di * e
            elif self.ssm_type == "rwkv6":
                total += 5 * e * e  # r,k,v,g,o mixes (approx)
            if self.is_moe_layer(idx):
                total += self.n_experts * 3 * e * self.moe_d_ff
                total += self.n_shared_experts * 3 * e * self.moe_d_ff
                total += e * self.n_experts  # router
            else:
                total += 3 * e * f
            total += 2 * e  # norms
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: routed top-k + shared)."""
        if not self.moe_enabled:
            return self.n_params()
        e = self.d_model
        total = self.n_params()
        n_moe_layers = sum(
            self.is_moe_layer(i) for i in range(self.n_layers)
        )
        inactive = (self.n_experts - self.experts_per_token)
        total -= n_moe_layers * inactive * 3 * e * self.moe_d_ff
        return total

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        def shrink(x, lo, fac):
            return max(lo, x // fac)

        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 4 if not self.attn_period else self.attn_period),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab=512,
            q_lora_rank=64 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_nope_head_dim=32 if self.attn_type == "mla" else self.qk_nope_head_dim,
            qk_rope_head_dim=16 if self.attn_type == "mla" else self.qk_rope_head_dim,
            v_head_dim=32 if self.attn_type == "mla" else self.v_head_dim,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            experts_per_token=min(self.experts_per_token, 2) if self.n_experts else 0,
            moe_d_ff=64 if self.moe_d_ff else 0,
            first_k_dense=min(self.first_k_dense, 1),
            n_encoder_layers=min(self.n_encoder_layers, 2),
            encoder_seq=32,
            modality_prefix=min(self.modality_prefix, 8),
            d_state=min(self.d_state, 8),
            param_dtype="float32",
            compute_dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    def reduced(self) -> "ShapeConfig":
        return dataclasses.replace(
            self, seq_len=min(self.seq_len, 64), global_batch=min(self.global_batch, 2)
        )


SHAPES: "dict[str, ShapeConfig]" = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
