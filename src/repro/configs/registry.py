"""Architecture registry: --arch <id> resolution."""
from __future__ import annotations

from repro.configs.base import ModelConfig

from repro.configs.yi_9b import CONFIG as YI_9B
from repro.configs.granite_3_8b import CONFIG as GRANITE_3_8B
from repro.configs.phi3_medium_14b import CONFIG as PHI3_MEDIUM
from repro.configs.minicpm_2b import CONFIG as MINICPM_2B
from repro.configs.rwkv6_1_6b import CONFIG as RWKV6_1_6B
from repro.configs.llava_next_34b import CONFIG as LLAVA_NEXT_34B
from repro.configs.deepseek_v3_671b import CONFIG as DEEPSEEK_V3
from repro.configs.kimi_k2_1t import CONFIG as KIMI_K2
from repro.configs.jamba_1_5_large import CONFIG as JAMBA_1_5
from repro.configs.seamless_m4t_large import CONFIG as SEAMLESS_M4T

ARCHS: "dict[str, ModelConfig]" = {
    c.name: c
    for c in (
        YI_9B,
        GRANITE_3_8B,
        PHI3_MEDIUM,
        MINICPM_2B,
        RWKV6_1_6B,
        LLAVA_NEXT_34B,
        DEEPSEEK_V3,
        KIMI_K2,
        JAMBA_1_5,
        SEAMLESS_M4T,
    )
}


def get_config(name: str) -> ModelConfig:
    if name in ARCHS:
        return ARCHS[name]
    # Accept underscore variants and unambiguous prefixes.
    canon = name.replace("_", "-")
    if canon in ARCHS:
        return ARCHS[canon]
    matches = [k for k in ARCHS if k.startswith(canon)]
    if len(matches) == 1:
        return ARCHS[matches[0]]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")


def list_archs() -> "list[str]":
    return sorted(ARCHS)
