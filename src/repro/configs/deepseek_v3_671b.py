"""deepseek-v3-671b — MLA + 1 shared/256 routed top-8 MoE + MTP
[arXiv:2412.19437; hf]. First 3 layers dense (d_ff 18432); MoE expert
d_ff 2048; MLA q_lora 1536 / kv_lora 512 (+64 rope)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,        # MLA: heads share one compressed KV (MQA-like cache)
    head_dim=128,
    d_ff=18432,            # dense layers
    vocab=129280,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=256,
    n_shared_experts=1,
    experts_per_token=8,
    moe_d_ff=2048,
    first_k_dense=3,
    mtp=True,
    param_dtype="bfloat16",   # 671B: bf16 params + 8-bit optim state
    fsdp_over_pod=True,
)
