"""llava-next-34b — VLM, anyres tiling [hf:llava-hf/llava-v1.6; unverified].

Backbone only (Yi-34B-like): 60L d7168 56H kv8. The vision frontend is a
STUB per the assignment: input_specs() provides precomputed patch
embeddings (anyres base 576 patches + one 576-patch tile stand-in),
prepended to the token sequence. 56 heads are not divisible by the
model axis; attention runs sequence-parallel."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
    rope_theta=5e6,
    modality_prefix=1152,   # 576 base + 576 anyres tile patch embeddings
)
