"""kimi-k2-1t-a32b — trillion-param MoE, 384 routed experts top-8
[arXiv:2501.kimi2; unverified]. Assignment spec: 61L d7168 64H GQA kv=8,
expert d_ff 2048, vocab 163840. (The real K2 uses MLA; the assignment
pins GQA kv=8, which we follow — switchable via attn_type.)"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=18432,
    vocab=163840,
    n_experts=384,
    n_shared_experts=1,
    experts_per_token=8,
    moe_d_ff=2048,
    first_k_dense=1,
    param_dtype="bfloat16",
    fsdp_over_pod=True,
)
