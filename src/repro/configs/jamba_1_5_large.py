"""jamba-1.5-large-398b — Mamba+attention 1:7 interleave with 16-expert
top-2 MoE every other layer [arXiv:2403.19887; hf]. 72 layers = 9 blocks
of 8 (attention at block index 4); hybrid => runs long_500k (KV cache
only for the 9 attention layers, context-parallel)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    ssm_type="mamba",
    attn_period=8,
    attn_period_offset=4,
    d_state=16,
    d_conv=4,
    ssm_expand=2,
    n_experts=16,
    n_shared_experts=0,
    experts_per_token=2,
    moe_d_ff=24576,
    moe_every=2,
    moe_offset=1,
    param_dtype="bfloat16",
    fsdp_over_pod=True,
    supports_long_context=True,
)
