"""The paper's own workload: 400x120x84x10 sigmoid MLP deployed on IMAC
(Tables II-IV). Not an LM arch — consumed by the core library, examples
and benchmarks rather than the LM substrate."""
from repro.core.imac import IMACConfig

TOPOLOGY = [400, 120, 84, 10]

# Table II fixed hyperparameters.
PAPER_DEFAULTS = dict(
    vdd=0.8,
    vss=-0.8,
    neuron="sigmoid",
    t_sampling=20e-9,
)

# Table III array-size sweep (auto H_P/V_P) + the over-partitioned row.
TABLE_III_CONFIGS = [
    ("32x32", IMACConfig(tech="MRAM", array_rows=32, array_cols=32, **PAPER_DEFAULTS)),
    ("64x64", IMACConfig(tech="MRAM", array_rows=64, array_cols=64, **PAPER_DEFAULTS)),
    ("128x128", IMACConfig(tech="MRAM", array_rows=128, array_cols=128, **PAPER_DEFAULTS)),
    ("256x256", IMACConfig(tech="MRAM", array_rows=256, array_cols=256, **PAPER_DEFAULTS)),
    ("512x512", IMACConfig(tech="MRAM", array_rows=512, array_cols=512, **PAPER_DEFAULTS)),
    ("32x32-hp16", IMACConfig(tech="MRAM", hp=[16, 8, 8], vp=[8, 8, 1], **PAPER_DEFAULTS)),
]

# Table IV device-technology sweep at fixed H_P=[13,4,3], V_P=[4,3,1].
TABLE_IV_CONFIGS = [
    (tech, IMACConfig(tech=tech, hp=[13, 4, 3], vp=[4, 3, 1], **PAPER_DEFAULTS))
    for tech in ("MRAM", "RRAM", "CBRAM", "PCM")
]
