"""phi3-medium-14b — RoPE SwiGLU GQA [arXiv:2404.14219; unverified].

40 heads do not divide the 16-wide model axis; the sharding rules fall
back to sequence-parallel attention (see sharding/rules.py)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab=100352,
    rope_theta=1e4,
)
