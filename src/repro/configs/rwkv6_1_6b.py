"""rwkv6-1.6b (Finch) — attention-free, data-dependent decay
[arXiv:2404.05892; unverified]. 32 heads of 64 (d_model/64); ffn 7168.
O(1)-state decode => runs the long_500k shape."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,          # wkv heads = d_model / 64
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab=65536,
    attn_type="none",
    ssm_type="rwkv6",
    supports_long_context=True,
)
