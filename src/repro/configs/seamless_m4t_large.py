"""seamless-m4t-large-v2 — encoder-decoder, multimodal (audio)
[arXiv:2308.11596; hf]. 24 encoder + 24 decoder layers, d1024 16H MHA,
d_ff 8192, vocab 256206. The speech frontend is a STUB: input_specs()
provides precomputed filterbank-frame embeddings for the encoder."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,              # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab=256206,
    is_encoder_decoder=True,
    n_encoder_layers=24,
    encoder_seq=1024,         # precomputed audio frames (stub frontend)
)
