"""minicpm-2b — llama-like MHA with WSD schedule + mu-p style scaling
[arXiv:2404.06395; hf]. d_model=2304, 36 heads => head_dim 64."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab=122753,
    rope_theta=1e4,
    tie_embeddings=True,
    scale_emb=12.0,       # MiniCPM embedding scale
    scale_depth=1.4,      # residual branch scaled by scale_depth/sqrt(L)
    scale_logits=256.0 / 2304.0,  # mu-p logit scale (dim_model_base=256)
)

# Training schedule: WSD (warmup-stable-decay) — consumed by optim/schedule.
WSD_SCHEDULE = {"warmup_steps": 0.01, "stable_frac": 0.8, "decay_frac": 0.19}
