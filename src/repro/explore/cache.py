"""On-disk memoization of IMAC evaluation results.

Results are tiny (a dozen scalars) while producing them means a full
circuit simulation, so the cache is a directory of JSON files keyed by a
SHA-256 over everything that determines the numbers: the configuration's
canonical fingerprint, the trained parameters, the evaluation data and
the evaluation arguments (sample count, chunking, Monte-Carlo keys).
A warm sweep re-run — or a sweep that shares points with an earlier one —
returns identical results without touching the solver.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import uuid
from typing import Optional, Sequence

import jax
import numpy as np

from repro import obs
from repro.core.evaluate import IMACResult
from repro.core.imac import IMACConfig
from repro.variability.report import ReliabilityReport


def _canonical(obj):
    """JSON-stable canonical form of config values (nested dataclasses,
    dtypes, arrays) for fingerprinting."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        d = {
            f.name: _canonical(getattr(obj, f.name))
            for f in dataclasses.fields(obj)
        }
        d["__class__"] = type(obj).__name__
        return d
    if isinstance(obj, (list, tuple)):
        return [_canonical(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    if isinstance(obj, (np.ndarray, jax.Array)):
        return {
            "__array__": hashlib.sha256(
                np.ascontiguousarray(obj).tobytes()
            ).hexdigest(),
            "shape": list(np.shape(obj)),
        }
    # dtypes and anything else with a stable name/str.
    return str(obj)


def config_fingerprint(cfg: IMACConfig) -> str:
    return hashlib.sha256(
        json.dumps(_canonical(cfg), sort_keys=True).encode()
    ).hexdigest()


def params_fingerprint(params: "Sequence[tuple]") -> str:
    h = hashlib.sha256()
    for w, b in params:
        h.update(np.asarray(w).tobytes())
        h.update(np.asarray(b).tobytes())
    return h.hexdigest()


def data_fingerprint(x, y) -> str:
    h = hashlib.sha256()
    h.update(np.asarray(x).tobytes())
    h.update(np.asarray(y).tobytes())
    return h.hexdigest()


def _key_fingerprint(key: Optional[jax.Array]) -> str:
    if key is None:
        return "none"
    return hashlib.sha256(np.asarray(key).tobytes()).hexdigest()


def result_key(
    cfg: IMACConfig,
    params_fp: str,
    data_fp: str,
    *,
    n_samples: Optional[int],
    chunk: int,
    variation_key=None,
    noise_key=None,
    activation: str = "sigmoid",
) -> str:
    """Cache key for one (config, params, data, eval-args) evaluation.

    Reliability points (cfg.variability set) should be keyed with
    variation_key/noise_key = None: their results derive entirely from
    the spec's seed (see repro.explore.engine.run_sweep)."""
    payload = json.dumps(
        {
            "config": _canonical(cfg),
            "params": params_fp,
            "data": data_fp,
            "n_samples": n_samples,
            "chunk": chunk,
            "variation_key": _key_fingerprint(variation_key),
            "noise_key": _key_fingerprint(noise_key),
            "activation": activation,
        },
        sort_keys=True,
    )
    return hashlib.sha256(payload.encode()).hexdigest()


class ResultCache:
    """Directory-backed result store: one JSON file per evaluation.

    Safe for concurrent writers and readers sharing the directory (the
    dedupe memo store of parallel sweep workers): every `put` writes to
    a uniquely-named temp file and publishes it with an atomic
    `os.replace` — two writers racing on one key both succeed and the
    last rename wins with a complete entry; a reader never observes a
    half-written file through the final name. `get` additionally
    tolerates foreign partial/corrupt entries (a non-atomic writer, a
    torn copy) by treating any unreadable file as a miss instead of
    raising.

    Args:
      path: cache directory (created if missing).
      max_entries: optional size cap; when a `put` pushes the directory
        past it, the oldest entries (by file mtime) are evicted and
        counted as ``cache_evictions_total``.
    """

    def __init__(self, path: str, max_entries: "Optional[int]" = None):
        self.path = path
        os.makedirs(path, exist_ok=True)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.max_entries = max_entries
        if obs.enabled():
            # Register the series at 0 so exports show them even for an
            # all-miss (or never-hit) run.
            obs.counter("cache_hits_total")
            obs.counter("cache_misses_total")
            obs.counter("cache_evictions_total")

    def _file(self, key: str) -> str:
        return os.path.join(self.path, f"{key}.json")

    def get(self, key: str) -> "Optional[IMACResult | ReliabilityReport]":
        f = self._file(key)
        try:
            with open(f) as fh:
                payload = json.load(fh)
            result = self._decode(payload)
        except FileNotFoundError:
            self.misses += 1
            obs.counter("cache_misses_total").inc()
            return None
        except (OSError, ValueError, KeyError, TypeError):
            # Partially-written or corrupt entry (a writer that skipped
            # the atomic-rename protocol, a torn copy): a miss, not an
            # error — the caller recomputes and `put` heals the file.
            self.misses += 1
            obs.counter("cache_misses_total").inc()
            obs.event("cache_corrupt_entry", key=key[:16])
            return None
        self.hits += 1
        obs.counter("cache_hits_total").inc()
        return result

    @staticmethod
    def _decode(payload: dict) -> "IMACResult | ReliabilityReport":
        r = payload["result"]
        if payload.get("kind", "imac") == "reliability":
            # JSON round-trip turns tuples into lists; restore them.
            return ReliabilityReport(**{
                k: tuple(v) if isinstance(v, list) else v
                for k, v in r.items()
            })
        return IMACResult(
            accuracy=r["accuracy"],
            error_rate=r["error_rate"],
            avg_power=r["avg_power"],
            latency=r["latency"],
            digital_accuracy=r["digital_accuracy"],
            per_layer_power=tuple(r["per_layer_power"]),
            worst_residual=r["worst_residual"],
            n_samples=r["n_samples"],
            hp=tuple(r["hp"]),
            vp=tuple(r["vp"]),
            energy=r.get("energy", 0.0),
            latency_analytic=r.get("latency_analytic", 0.0),
            latency_source=r.get("latency_source", "analytic"),
            settled=r.get("settled", True),
        )

    def put(
        self,
        key: str,
        result: "IMACResult | ReliabilityReport",
        name: str = "",
    ) -> None:
        kind = (
            "reliability" if isinstance(result, ReliabilityReport) else "imac"
        )
        payload = {"name": name, "kind": kind, "result": result._asdict()}
        # Unique temp name per writer (pid + thread + nonce): concurrent
        # puts of the same key never stomp each other's temp file, and
        # the atomic os.replace publishes only complete entries
        # (last-writer-wins).
        tmp = (
            f"{self._file(key)}.{os.getpid()}."
            f"{threading.get_ident()}.{uuid.uuid4().hex[:8]}.tmp"
        )
        try:
            with open(tmp, "w") as fh:
                json.dump(payload, fh)
            os.replace(tmp, self._file(key))
        finally:
            if os.path.exists(tmp):  # replace failed mid-way
                try:
                    os.remove(tmp)
                except OSError:
                    pass
        if self.max_entries is not None:
            self.prune()

    def prune(self) -> int:
        """Evict oldest entries until the cache fits `max_entries`.

        Returns the number of files removed (0 when uncapped or under
        the cap). Age is file mtime — `put` rewrites refresh it, so the
        policy is FIFO-with-refresh rather than strict insertion order.
        """
        if self.max_entries is None:
            return 0
        files = [
            os.path.join(self.path, f)
            for f in os.listdir(self.path)
            if f.endswith(".json")
        ]
        excess = len(files) - self.max_entries
        if excess <= 0:
            return 0
        files.sort(key=os.path.getmtime)
        removed = 0
        for f in files[:excess]:
            try:
                os.remove(f)
                removed += 1
            except OSError:
                continue
        if removed:
            self.evictions += removed
            obs.counter("cache_evictions_total").inc(removed)
            if obs.enabled():
                obs.add_instant(
                    "cache_evict",
                    {"removed": removed, "max_entries": self.max_entries},
                )
        return removed

    def __len__(self) -> int:
        return sum(
            1 for f in os.listdir(self.path) if f.endswith(".json")
        )
