"""Multi-objective Pareto-front extraction over sweep results.

The paper's design-space story is a trade-off surface — accuracy against
power against latency. `pareto_front` returns the indices of the
non-dominated points: no other point is at least as good on every
objective and strictly better on one.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

# (IMACResult attribute, direction) — the paper's three objectives.
DEFAULT_OBJECTIVES = (
    ("accuracy", "max"),
    ("avg_power", "min"),
    ("latency", "min"),
)

# Yield-aware objectives for Monte-Carlo reliability sweeps
# (repro.variability.ReliabilityReport results): trade the accuracy a
# design delivers in its bad tail (5th percentile across variation
# trials) against worst-case power — robust-design extraction instead of
# point-estimate extraction. ReliabilityReport also proxies
# accuracy/avg_power to trial means, so DEFAULT_OBJECTIVES work too.
RELIABILITY_OBJECTIVES = (
    ("acc_q05", "max"),
    ("power_worst", "min"),
    ("latency", "min"),
)

# Timing/energy objectives for transient (waveform-accurate) sweeps
# (cfg.transient set, or run_sweep(..., timing=...)): trade accuracy
# against integrated energy per inference and measured settling latency.
# Deterministic points without a transient spec still expose `energy`
# (avg_power x latency estimate), so mixed sweeps extract cleanly.
TRANSIENT_OBJECTIVES = (
    ("accuracy", "max"),
    ("energy", "min"),
    ("latency", "min"),
)


def pareto_mask(points: np.ndarray, maximize: "Sequence[bool]") -> np.ndarray:
    """Boolean mask of non-dominated rows.

    Args:
      points: (n, d) objective values.
      maximize: per-column direction; False = minimize.

    Returns:
      (n,) bool mask; True = on the Pareto front. Duplicate points are
      all kept (they don't strictly dominate each other).
    """
    pts = np.asarray(points, dtype=float)
    if pts.ndim != 2:
        raise ValueError(f"points must be (n, d), got {pts.shape}")
    if pts.shape[1] != len(maximize):
        raise ValueError(
            f"{pts.shape[1]} objectives vs {len(maximize)} directions"
        )
    # Orient so larger is always better.
    sign = np.where(np.asarray(maximize, dtype=bool), 1.0, -1.0)
    v = pts * sign
    n = v.shape[0]
    mask = np.ones(n, dtype=bool)
    for i in range(n):
        at_least_as_good = (v >= v[i]).all(axis=1)
        strictly_better = (v > v[i]).any(axis=1)
        mask[i] = not np.any(at_least_as_good & strictly_better)
    return mask


def pareto_front(
    results,
    objectives: "Sequence[tuple[str, str]]" = DEFAULT_OBJECTIVES,
) -> "list[int]":
    """Indices of Pareto-optimal results, sorted by the first objective.

    Args:
      results: sequence of objects exposing the objective attributes —
        IMACResult or repro.explore.engine.SweepResult (which proxies its
        IMACResult fields).
      objectives: (attribute, 'max'|'min') pairs.

    Returns:
      Indices into `results` of the non-dominated points.
    """
    for _, direction in objectives:
        if direction not in ("max", "min"):
            raise ValueError(f"direction must be 'max'|'min', got {direction!r}")
    if not len(results):
        return []
    points = np.array(
        [[getattr(r, attr) for attr, _ in objectives] for r in results]
    )
    maximize = [direction == "max" for _, direction in objectives]
    mask = pareto_mask(points, maximize)
    idx = [i for i in range(len(results)) if mask[i]]
    first = points[:, 0] * (1.0 if maximize[0] else -1.0)
    idx.sort(key=lambda i: -first[i])
    return idx
