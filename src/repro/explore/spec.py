"""Declarative sweep specifications over the IMAC design space.

A `SweepSpec` names a base `IMACConfig` and a set of axes; materializing
it yields `(name, IMACConfig)` points — the full cross product for grid
mode, or `samples` independent draws for random mode. Axes address
`IMACConfig` fields directly (`tech`, `array_rows`, `r_source`, ...) plus
two compound conveniences:

  * ``array_size=n``       -> ``array_rows=n, array_cols=n``
  * ``partition=(hp, vp)`` -> ``hp=hp, vp=vp`` (per-layer lists)

Example::

    spec = SweepSpec.grid(
        IMACConfig(),
        tech=["MRAM", "RRAM", "CBRAM", "PCM"],
        array_size=[32, 64, 128],
    )
    points = spec.materialize()   # 12 named IMACConfigs
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

import numpy as np

from repro.core.imac import IMACConfig


def _apply_axis(cfg: IMACConfig, field: str, value) -> IMACConfig:
    """Set one axis value on a config, expanding compound fields."""
    if field == "array_size":
        return dataclasses.replace(
            cfg, array_rows=int(value), array_cols=int(value)
        )
    if field == "partition":
        hp, vp = value
        return dataclasses.replace(cfg, hp=list(hp), vp=list(vp))
    if not hasattr(cfg, field):
        raise ValueError(
            f"unknown sweep axis {field!r}: not an IMACConfig field "
            f"(compound axes: 'array_size', 'partition')"
        )
    return dataclasses.replace(cfg, **{field: value})


def _fmt(value) -> str:
    """Compact value rendering for point names."""
    if isinstance(value, (list, tuple)):
        return "x".join(_fmt(v) for v in value)
    if isinstance(value, float):
        return f"{value:g}"
    return str(value)


def point_name(assignment: "Sequence[tuple[str, object]]") -> str:
    return ",".join(f"{field}={_fmt(value)}" for field, value in assignment)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A declarative design-space sweep.

    Attributes:
      base: configuration every point starts from.
      axes: ordered (field, values) axes.
      mode: 'grid' (cross product) or 'random' (independent draws).
      samples: number of draws for random mode.
      seed: RNG seed for random mode.
    """

    base: IMACConfig
    axes: "tuple[tuple[str, tuple], ...]"
    mode: str = "grid"
    samples: int = 0
    seed: int = 0

    @classmethod
    def grid(cls, base: IMACConfig = IMACConfig(), **axes) -> "SweepSpec":
        """Full cross product of the given axes."""
        return cls(base=base, axes=_freeze_axes(axes), mode="grid")

    @classmethod
    def random(
        cls,
        base: IMACConfig = IMACConfig(),
        samples: int = 16,
        seed: int = 0,
        **axes,
    ) -> "SweepSpec":
        """`samples` points drawn uniformly per axis (with replacement)."""
        return cls(
            base=base,
            axes=_freeze_axes(axes),
            mode="random",
            samples=samples,
            seed=seed,
        )

    @property
    def n_points(self) -> int:
        if self.mode == "random":
            return self.samples
        n = 1
        for _, values in self.axes:
            n *= len(values)
        return n

    def materialize(self) -> "list[tuple[str, IMACConfig]]":
        """Expand to concrete (name, config) points."""
        if self.mode == "grid":
            assignments = [
                list(zip([f for f, _ in self.axes], combo))
                for combo in itertools.product(*(v for _, v in self.axes))
            ]
        elif self.mode == "random":
            rng = np.random.default_rng(self.seed)
            assignments = []
            for _ in range(self.samples):
                assignments.append(
                    [(f, v[int(rng.integers(len(v)))]) for f, v in self.axes]
                )
        else:
            raise ValueError(f"unknown sweep mode {self.mode!r}")

        points = []
        for assignment in assignments:
            cfg = self.base
            for field, value in assignment:
                cfg = _apply_axis(cfg, field, value)
            points.append((point_name(assignment), cfg))
        return points


def _freeze_axes(axes: dict) -> "tuple[tuple[str, tuple], ...]":
    frozen = []
    for field, values in axes.items():
        values = tuple(values)
        if not values:
            raise ValueError(f"sweep axis {field!r} has no values")
        frozen.append((field, values))
    return tuple(frozen)
