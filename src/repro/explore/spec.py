"""Declarative sweep specifications over the IMAC design space.

A `SweepSpec` names a base `IMACConfig` and a set of axes; materializing
it yields `(name, IMACConfig)` points — the full cross product for grid
mode, or `samples` independent draws for random mode. Axes address
`IMACConfig` fields directly (`tech`, `array_rows`, `r_source`, ...) plus
compound conveniences:

  * ``array_size=n``       -> ``array_rows=n, array_cols=n``
  * ``partition=(hp, vp)`` -> ``hp=hp, vp=vp`` (per-layer lists)

Monte-Carlo reliability axes attach a `VariabilitySpec` to each point
(creating a default one on first use), turning the sweep into a
reliability sweep whose points evaluate to `ReliabilityReport`s:

  * ``trials``, ``mc_seed``, ``sigma_rel``, ``levels``,
    ``read_noise_rel``, ``p_stuck_on``, ``p_stuck_off``,
    ``acc_threshold``  -> the matching VariabilitySpec field
  * ``fault_rate=r``   -> ``p_stuck_on=r/2, p_stuck_off=r/2``
  * ``variability``    -> a whole VariabilitySpec (or None) per value

Transient (waveform-accurate timing) axes likewise attach a
`TransientSpec`, turning the sweep into a timing sweep whose points
report measured settling latency and integrated energy:

  * ``t_stop``, ``tran_steps``, ``tran_method``, ``t_rise``,
    ``tran_rtol``, ``c_driver``, ``c_tia``, ``n_probe``
                       -> the matching TransientSpec field
  * ``transient``      -> a whole TransientSpec (or None) per value
  * ``cap_scale=s``    -> scale ``interconnect.cap_per_m`` by ``s``

Example::

    spec = SweepSpec.grid(
        IMACConfig(),
        tech=["MRAM", "RRAM", "CBRAM", "PCM"],
        array_size=[32, 64, 128],
    )
    points = spec.materialize()   # 12 named IMACConfigs

    reliability = SweepSpec.grid(
        IMACConfig(),
        tech=["MRAM", "PCM"],
        sigma_rel=[0.05, 0.10, 0.20],
        trials=[32],
    )                             # 6 Monte-Carlo design points
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

import numpy as np

from repro.core.imac import IMACConfig
from repro.transient.spec import TransientSpec
from repro.variability.spec import VariabilitySpec

# Axis name -> VariabilitySpec field for the reliability conveniences.
_VARIABILITY_AXES = {
    "trials": "trials",
    "mc_seed": "seed",
    "sigma_rel": "sigma_rel",
    "levels": "levels",
    "read_noise_rel": "read_noise_rel",
    "p_stuck_on": "p_stuck_on",
    "p_stuck_off": "p_stuck_off",
    "acc_threshold": "acc_threshold",
}

# Axis name -> TransientSpec field for the timing conveniences. Each
# attaches a TransientSpec to the point (creating a default one on first
# use), turning the sweep into a waveform-accurate timing sweep.
_TRANSIENT_AXES = {
    "t_stop": "t_stop",
    "tran_steps": "n_steps",
    "tran_method": "method",
    "t_rise": "t_rise",
    "tran_rtol": "rtol",
    "c_driver": "c_driver",
    "c_tia": "c_tia",
    "n_probe": "n_probe",
}


def _with_variability(cfg: IMACConfig, **fields) -> IMACConfig:
    vspec = cfg.variability or VariabilitySpec()
    return dataclasses.replace(
        cfg, variability=dataclasses.replace(vspec, **fields)
    )


def _with_transient(cfg: IMACConfig, **fields) -> IMACConfig:
    tspec = cfg.transient or TransientSpec()
    return dataclasses.replace(
        cfg, transient=dataclasses.replace(tspec, **fields)
    )


def _apply_axis(cfg: IMACConfig, field: str, value) -> IMACConfig:
    """Set one axis value on a config, expanding compound fields."""
    if field == "array_size":
        return dataclasses.replace(
            cfg, array_rows=int(value), array_cols=int(value)
        )
    if field == "partition":
        hp, vp = value
        return dataclasses.replace(cfg, hp=list(hp), vp=list(vp))
    if field == "fault_rate":
        return _with_variability(
            cfg, p_stuck_on=value / 2.0, p_stuck_off=value / 2.0
        )
    if field == "cap_scale":
        # Scale the interconnect capacitance per segment — the c of every
        # RC settling time constant (transient crossvalidation sweeps).
        return dataclasses.replace(
            cfg,
            interconnect=dataclasses.replace(
                cfg.interconnect, cap_per_m=cfg.interconnect.cap_per_m * value
            ),
        )
    if field in _VARIABILITY_AXES:
        return _with_variability(cfg, **{_VARIABILITY_AXES[field]: value})
    if field in _TRANSIENT_AXES:
        return _with_transient(cfg, **{_TRANSIENT_AXES[field]: value})
    if not hasattr(cfg, field):
        raise ValueError(
            f"unknown sweep axis {field!r}: not an IMACConfig field "
            f"(compound axes: 'array_size', 'partition', 'fault_rate', "
            f"'cap_scale', {sorted(_VARIABILITY_AXES)}, "
            f"{sorted(_TRANSIENT_AXES)})"
        )
    return dataclasses.replace(cfg, **{field: value})


def _fmt(value) -> str:
    """Compact value rendering for point names."""
    if isinstance(value, (list, tuple)):
        return "x".join(_fmt(v) for v in value)
    if isinstance(value, float):
        return f"{value:g}"
    if isinstance(value, (VariabilitySpec, TransientSpec)):
        # Non-default fields only: mc(trials=16,sigma_rel=0.1) /
        # tran(n_steps=64,method=be).
        tag = "mc" if isinstance(value, VariabilitySpec) else "tran"
        diffs = [
            f"{f.name}={_fmt(getattr(value, f.name))}"
            for f in dataclasses.fields(value)
            if getattr(value, f.name) != f.default
        ]
        return f"{tag}({','.join(diffs)})" if diffs else f"{tag}()"
    return str(value)


def point_name(assignment: "Sequence[tuple[str, object]]") -> str:
    return ",".join(f"{field}={_fmt(value)}" for field, value in assignment)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A declarative design-space sweep.

    Attributes:
      base: configuration every point starts from.
      axes: ordered (field, values) axes.
      mode: 'grid' (cross product) or 'random' (independent draws).
      samples: number of draws for random mode.
      seed: RNG seed for random mode.
      shard: multi-device execution request carried by the spec —
        a `repro.distributed.sweep.MeshPlan`, True (all devices), an
        int (device count), or None (single device). `run_sweep` picks
        it up unless its own ``shard=`` argument overrides it. Sharded
        circuit-solve results are bitwise-identical to the unsharded
        engine (see run_sweep's ``shard`` docs for the ideal-MVM
        power caveat).
    """

    base: IMACConfig
    axes: "tuple[tuple[str, tuple], ...]"
    mode: str = "grid"
    samples: int = 0
    seed: int = 0
    shard: "object" = None

    @classmethod
    def grid(
        cls, base: IMACConfig = IMACConfig(), *, shard=None, **axes
    ) -> "SweepSpec":
        """Full cross product of the given axes."""
        return cls(base=base, axes=_freeze_axes(axes), mode="grid", shard=shard)

    @classmethod
    def random(
        cls,
        base: IMACConfig = IMACConfig(),
        samples: int = 16,
        seed: int = 0,
        *,
        shard=None,
        **axes,
    ) -> "SweepSpec":
        """`samples` points drawn uniformly per axis (with replacement)."""
        return cls(
            base=base,
            axes=_freeze_axes(axes),
            mode="random",
            samples=samples,
            seed=seed,
            shard=shard,
        )

    @property
    def n_points(self) -> int:
        if self.mode == "random":
            return self.samples
        n = 1
        for _, values in self.axes:
            n *= len(values)
        return n

    def materialize(self) -> "list[tuple[str, IMACConfig]]":
        """Expand to concrete (name, config) points."""
        if self.mode == "grid":
            assignments = [
                list(zip([f for f, _ in self.axes], combo))
                for combo in itertools.product(*(v for _, v in self.axes))
            ]
        elif self.mode == "random":
            rng = np.random.default_rng(self.seed)
            assignments = []
            for _ in range(self.samples):
                assignments.append(
                    [(f, v[int(rng.integers(len(v)))]) for f, v in self.axes]
                )
        else:
            raise ValueError(f"unknown sweep mode {self.mode!r}")

        points = []
        for assignment in assignments:
            cfg = self.base
            for field, value in assignment:
                cfg = _apply_axis(cfg, field, value)
            points.append((point_name(assignment), cfg))
        return points


def _freeze_axes(axes: dict) -> "tuple[tuple[str, tuple], ...]":
    frozen = []
    for field, values in axes.items():
        values = tuple(values)
        if not values:
            raise ValueError(f"sweep axis {field!r} has no values")
        frozen.append((field, values))
    return tuple(frozen)
