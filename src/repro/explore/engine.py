"""Batched design-space exploration engine.

Takes an arbitrary list of (name, IMACConfig) points — usually from a
`SweepSpec` — and evaluates them by:

  1. memo lookup: points already in the `ResultCache` are returned
     without touching the solver;
  2. structural grouping: the remaining points are bucketed by
     `core.evaluate.structure_key` (partition plans, solver iteration
     schedule, neuron model, parasitics flag, dtype);
  3. batched solves: each bucket runs as ONE vmapped, jitted circuit
     simulation via `core.evaluate.evaluate_batch` — conductances and
     electrical scalars stacked along a leading config axis — instead of
     one re-traced, re-compiled solve per configuration.

On the paper's Table III x Table IV cross product (24 configurations,
6 structures) this replaces 24 XLA compilations with 6; see
benchmarks/sweep_bench.py for the measured wall-clock win.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Union

import jax

from repro.core.digital import Params
from repro.core.evaluate import IMACResult, evaluate_batch, structure_key
from repro.core.imac import IMACConfig
from repro.core.mapping import map_network
from repro.explore.cache import (
    ResultCache,
    data_fingerprint,
    params_fingerprint,
    result_key,
)
from repro.explore.pareto import DEFAULT_OBJECTIVES, pareto_front
from repro.explore.spec import SweepSpec

SweepInput = Union[SweepSpec, Sequence]


@dataclasses.dataclass
class SweepResult:
    """One evaluated design point."""

    name: str
    config: IMACConfig
    result: IMACResult
    cached: bool = False

    def __getattr__(self, attr):
        # Proxy IMACResult fields (accuracy, avg_power, latency, ...) so
        # pareto_front and report code can address points directly.
        if attr.startswith("_") or attr == "result":
            raise AttributeError(attr)
        return getattr(self.result, attr)


def _as_points(points: SweepInput) -> "list[tuple[str, IMACConfig]]":
    if isinstance(points, SweepSpec):
        return points.materialize()
    out = []
    for i, item in enumerate(points):
        if isinstance(item, IMACConfig):
            out.append((f"cfg{i}", item))
        else:
            name, cfg = item
            out.append((str(name), cfg))
    return out


def run_sweep(
    params: Params,
    x: jax.Array,
    y: jax.Array,
    points: SweepInput,
    *,
    n_samples: Optional[int] = None,
    chunk: int = 256,
    cache: "ResultCache | str | None" = None,
    variation_key: Optional[jax.Array] = None,
    noise_key: Optional[jax.Array] = None,
    activation: str = "sigmoid",
    verbose: bool = False,
) -> "list[SweepResult]":
    """Evaluate a design-space sweep with batching and memoization.

    Args:
      params: trained digital weights/biases [(W, b), ...].
      x, y: evaluation data (digital units / integer labels).
      points: a SweepSpec, or a sequence of IMACConfig or (name, config).
      n_samples: samples per evaluation (default: all of x).
      chunk: samples per jitted solve.
      cache: ResultCache instance, a directory path to open one, or None.
      variation_key / noise_key: Monte-Carlo draws shared by every point
        (paired comparison across the design space).
      activation: digital reference activation.
      verbose: print per-group progress lines.

    Returns:
      One SweepResult per point, in input order.
    """
    items = _as_points(points)
    if isinstance(cache, str):
        cache = ResultCache(cache)
    topology = [params[0][0].shape[0]] + [w.shape[1] for w, _ in params]

    results: "list[Optional[SweepResult]]" = [None] * len(items)

    # 1. Memo lookup.
    keys: "list[Optional[str]]" = [None] * len(items)
    pending: "list[int]" = []
    if cache is not None:
        params_fp = params_fingerprint(params)
        data_fp = data_fingerprint(
            x[: n_samples or x.shape[0]], y[: n_samples or y.shape[0]]
        )
        for i, (name, cfg) in enumerate(items):
            keys[i] = result_key(
                cfg,
                params_fp,
                data_fp,
                n_samples=n_samples,
                chunk=chunk,
                variation_key=variation_key,
                noise_key=noise_key,
                activation=activation,
            )
            hit = cache.get(keys[i])
            if hit is not None:
                results[i] = SweepResult(name, cfg, hit, cached=True)
            else:
                pending.append(i)
    else:
        pending = list(range(len(items)))

    # 2. Group the misses by traced structure.
    groups: "dict[tuple, list[int]]" = {}
    for i in pending:
        groups.setdefault(structure_key(topology, items[i][1]), []).append(i)

    # mapWB depends only on (tech, vdd, quantize) for fixed params, so a
    # sweep over P partitionings x T technologies needs T mappings, not
    # P*T — memoize across groups.
    mapping_memo: dict = {}

    def _mapped(cfg: IMACConfig):
        tech = cfg.resolved_tech()
        memo_key = (
            tech.name, tech.r_low, tech.r_high, tech.levels, tech.sigma_rel,
            cfg.vdd, cfg.quantize,
        )
        if memo_key not in mapping_memo:
            mapping_memo[memo_key] = map_network(
                params,
                tech,
                v_unit=cfg.vdd,
                quantize=cfg.quantize,
                variation_key=variation_key,
            )
        return mapping_memo[memo_key]

    # 3. One batched solve per group.
    for gi, (skey, idxs) in enumerate(groups.items()):
        t0 = time.perf_counter()
        batch = evaluate_batch(
            params,
            x,
            y,
            [items[i][1] for i in idxs],
            n_samples=n_samples,
            chunk=chunk,
            variation_key=variation_key,
            noise_key=noise_key,
            activation=activation,
            mapped=[_mapped(items[i][1]) for i in idxs],
        )
        if verbose:
            dt = time.perf_counter() - t0
            print(
                f"[explore] group {gi + 1}/{len(groups)}: "
                f"{len(idxs)} configs in {dt:.2f}s "
                f"(plans {skey[1]})"
            )
        for i, res in zip(idxs, batch):
            name, cfg = items[i]
            results[i] = SweepResult(name, cfg, res, cached=False)
            if cache is not None:
                cache.put(keys[i], res, name=name)

    return [r for r in results if r is not None]


def explore(
    params: Params,
    x: jax.Array,
    y: jax.Array,
    points: SweepInput,
    *,
    objectives=DEFAULT_OBJECTIVES,
    **kw,
) -> "tuple[list[SweepResult], list[SweepResult]]":
    """run_sweep + Pareto extraction: returns (all results, front)."""
    results = run_sweep(params, x, y, points, **kw)
    front = [results[i] for i in pareto_front(results, objectives)]
    return results, front
