"""Batched design-space exploration engine.

Takes an arbitrary list of (name, IMACConfig) points — usually from a
`SweepSpec` — and evaluates them by:

  1. memo lookup: points already in the `ResultCache` are returned
     without touching the solver;
  2. structural grouping: the remaining points are bucketed by
     `core.evaluate.structure_key` (partition plans, solver iteration
     schedule, neuron model, parasitics flag, dtype);
  3. batched solves: each bucket runs as ONE vmapped, jitted circuit
     simulation via `core.evaluate.evaluate_batch` — conductances and
     electrical scalars stacked along a leading config axis — instead of
     one re-traced, re-compiled solve per configuration.

On the paper's Table III x Table IV cross product (24 configurations,
6 structures) this replaces 24 XLA compilations with 6; see
benchmarks/sweep_bench.py for the measured wall-clock win.

Points whose configuration carries a `VariabilitySpec`
(`cfg.variability`, usually set by SweepSpec's `trials`/`sigma_rel`/
`fault_rate`/... axes) are Monte-Carlo reliability points: each expands
into T stacked trial entries (repro.variability.expand_trials) that join
its structure group's single batched solve, and collapses back into a
`ReliabilityReport` instead of a point `IMACResult`. A sweep over
C configurations x T trials therefore still compiles once per structure
group. Use `pareto.RELIABILITY_OBJECTIVES` to extract fronts over
accuracy quantiles / worst-case power instead of point values.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Union

import jax

from repro import obs
from repro.core.digital import Params
from repro.core.evaluate import (
    IMACResult,
    concat_mapped,
    evaluate_batch,
    lift_mapped,
    structure_key,
)
from repro.core.imac import IMACConfig
from repro.core.mapping import map_network
from repro.distributed.sweep import MeshPlan, as_mesh_plan, shard_put
from repro.explore.cache import (
    ResultCache,
    data_fingerprint,
    params_fingerprint,
    result_key,
)
from repro.explore.pareto import DEFAULT_OBJECTIVES, pareto_front
from repro.explore.spec import SweepSpec
from repro.transient.spec import TransientSpec
from repro.variability.engine import expand_trials, run_variability, trial_keys
from repro.variability.report import ReliabilityReport, summarize

SweepInput = Union[SweepSpec, Sequence]


@dataclasses.dataclass
class SweepResult:
    """One evaluated design point.

    `result` is an IMACResult for deterministic points, or a
    ReliabilityReport for Monte-Carlo points (cfg.variability set).
    """

    name: str
    config: IMACConfig
    result: "IMACResult | ReliabilityReport"
    cached: bool = False

    def __getattr__(self, attr):
        # Proxy IMACResult fields (accuracy, avg_power, latency, ...) so
        # pareto_front and report code can address points directly.
        if attr.startswith("_") or attr == "result":
            raise AttributeError(attr)
        return getattr(self.result, attr)


def _as_points(points: SweepInput) -> "list[tuple[str, IMACConfig]]":
    if isinstance(points, SweepSpec):
        return points.materialize()
    out = []
    for i, item in enumerate(points):
        if isinstance(item, IMACConfig):
            out.append((f"cfg{i}", item))
        else:
            name, cfg = item
            out.append((str(name), cfg))
    return out


def run_sweep(
    params: Params,
    x: jax.Array,
    y: jax.Array,
    points: SweepInput,
    *,
    n_samples: Optional[int] = None,
    chunk: int = 256,
    cache: "ResultCache | str | None" = None,
    variation_key: Optional[jax.Array] = None,
    noise_key: Optional[jax.Array] = None,
    activation: str = "sigmoid",
    timing: "bool | TransientSpec | None" = None,
    shard: "MeshPlan | bool | int | None" = None,
    verbose: bool = False,
) -> "list[SweepResult]":
    """Evaluate a design-space sweep with batching and memoization.

    Args:
      params: trained digital weights/biases [(W, b), ...].
      x, y: evaluation data (digital units / integer labels).
      points: a SweepSpec, or a sequence of IMACConfig or (name, config).
        Points whose config carries a VariabilitySpec evaluate as
        Monte-Carlo reliability points (ReliabilityReport results); their
        trials — including read-noise draws when the resolved technology
        has read_noise_rel > 0 — derive from the spec's own seed,
        independent of `variation_key`/`noise_key`, so a point evaluated
        here matches a direct run_variability call exactly.
      n_samples: samples per evaluation (default: all of x).
      chunk: samples per jitted solve.
      cache: ResultCache instance, a directory path to open one, or None.
      variation_key / noise_key: Monte-Carlo draws shared by every
        deterministic point (paired comparison across the design space).
        Reliability points ignore both — see `points` above — so their
        cache entries survive changes to either.
      activation: digital reference activation.
      timing: timing mode — run every point through the batched transient
        co-simulation (repro.transient) so results report
        waveform-measured latency and integrated energy. True uses a
        default TransientSpec; a TransientSpec applies that one. Points
        that already carry cfg.transient keep their own spec. Pair with
        `pareto.TRANSIENT_OBJECTIVES` for energy-aware extraction.
      shard: execute each structure group's stacked solve sharded
        across a JAX device mesh — a `repro.distributed.sweep.MeshPlan`,
        True (all visible devices), an int (device count), or None.
        None falls back to the spec's own `SweepSpec.shard`. Groups
        schedule largest-first, the next group's tensors stage onto the
        mesh (double-buffered `device_put`) while the current one
        computes, and circuit-solve results (parasitics=True) are
        bitwise-identical to the unsharded engine — padding replicates
        a real config and the solver's convergence test is pmax'ed
        across shards, so trip counts match. (Ideal-MVM points keep
        bitwise predictions; their power agrees to ~1e-7 relative —
        the einsum's reduction order follows the local batch shape.)
        Read-noise Monte-Carlo points and the transient part of
        timing sweeps keep their unsharded path.
      verbose: print per-group progress lines.

    Returns:
      One SweepResult per point, in input order.
    """
    items = _as_points(points)
    if shard is None and isinstance(points, SweepSpec):
        shard = points.shard
    plan = as_mesh_plan(shard)
    mesh = plan.build() if plan is not None else None
    t_run0 = time.perf_counter()
    with obs.trace("run_sweep", {"points": len(items)}):
        if timing:
            tspec = timing if isinstance(timing, TransientSpec) else TransientSpec()
            items = [
                (
                    name,
                    cfg
                    if cfg.transient is not None
                    else dataclasses.replace(cfg, transient=tspec),
                )
                for name, cfg in items
            ]
        if isinstance(cache, str):
            cache = ResultCache(cache)
        topology = [params[0][0].shape[0]] + [w.shape[1] for w, _ in params]

        results: "list[Optional[SweepResult]]" = [None] * len(items)

        # 1. Memo lookup.
        keys: "list[Optional[str]]" = [None] * len(items)
        pending: "list[int]" = []
        with obs.trace("memo_lookup", {"points": len(items)}):
            if cache is not None:
                params_fp = params_fingerprint(params)
                data_fp = data_fingerprint(
                    x[: n_samples or x.shape[0]], y[: n_samples or y.shape[0]]
                )
                for i, (name, cfg) in enumerate(items):
                    # Reliability points draw everything from their spec's seed,
                    # so their results — and cache keys — are independent of the
                    # sweep-level Monte-Carlo keys.
                    is_mc = cfg.variability is not None
                    keys[i] = result_key(
                        cfg,
                        params_fp,
                        data_fp,
                        n_samples=n_samples,
                        chunk=chunk,
                        variation_key=None if is_mc else variation_key,
                        noise_key=None if is_mc else noise_key,
                        activation=activation,
                    )
                    hit = cache.get(keys[i])
                    if hit is not None:
                        results[i] = SweepResult(name, cfg, hit, cached=True)
                    else:
                        pending.append(i)
            else:
                pending = list(range(len(items)))

        # 2. Group the misses by traced structure.
        groups: "dict[tuple, list[int]]" = {}
        for i in pending:
            groups.setdefault(structure_key(topology, items[i][1]), []).append(i)

        # mapWB depends only on (tech, vdd, quantize) for fixed params, so a
        # sweep over P partitionings x T technologies needs T mappings, not
        # P*T — memoize across groups. Monte-Carlo points share the same memo
        # for their deterministic base mapping (variation is drawn per trial,
        # not via the sweep-wide variation_key).
        mapping_memo: dict = {}

        def _mapped(cfg: IMACConfig, tech=None, vkey=variation_key):
            tech = tech if tech is not None else cfg.resolved_tech()
            memo_key = (
                tech.name, tech.r_low, tech.r_high, tech.levels, tech.sigma_rel,
                cfg.vdd, cfg.quantize, vkey is None,
            )
            if memo_key not in mapping_memo:
                mapping_memo[memo_key] = map_network(
                    params,
                    tech,
                    v_unit=cfg.vdd,
                    quantize=cfg.quantize,
                    variation_key=vkey,
                )
            return mapping_memo[memo_key]

        # 3. One batched solve per group. Deterministic points contribute one
        # stacked entry each; Monte-Carlo points contribute their T trial
        # entries — all sharing the group's single compiled solve. Exception:
        # Monte-Carlo points whose resolved technology has read noise run
        # solo through run_variability so their per-trial noise draws depend
        # only on the spec's seed (not on the point's position in the stack)
        # — identical results to a direct run_variability call, and safe to
        # memoize across differently-composed sweeps.
        # Phase A — prepare every group host-side: expand Monte-Carlo
        # trials, build the stacked mapping, note solo points. Kept
        # separate from execution so the scheduler can reorder groups
        # and the stager can work one group ahead.
        prepared = []
        with obs.trace("prepare", {"groups": len(groups)}):
            for skey, idxs in groups.items():
                entry_cfgs, stacks, spans, solo = [], [], [], []
                for i in idxs:
                    cfg = items[i][1]
                    vspec = cfg.variability
                    if vspec is None:
                        entry_cfgs.append(cfg)
                        stacks.append(lift_mapped(_mapped(cfg)))
                        spans.append((i, 1, None))
                        continue
                    base_tech = vspec.resolve_tech(cfg.resolved_tech())
                    if base_tech.read_noise_rel > 0.0:
                        solo.append(i)
                        continue
                    # Degenerate spec: all trials identical -> one stacked entry,
                    # replicated back to T at summarize time.
                    collapse = (
                        vspec.trials > 1
                        and vspec.is_deterministic_for(cfg.resolved_tech())
                    )
                    tcfgs, tstacked = expand_trials(
                        params, cfg, vspec,
                        keys=trial_keys(vspec)[:1] if collapse else None,
                        base_mapped=_mapped(cfg, tech=base_tech, vkey=None),
                    )
                    entry_cfgs.extend(tcfgs)
                    stacks.append(tstacked)
                    spans.append((i, len(tcfgs), vspec))
                prepared.append({
                    "skey": skey,
                    "idxs": idxs,
                    "entry_cfgs": entry_cfgs,
                    "stacked": concat_mapped(stacks) if stacks else None,
                    "spans": spans,
                    "solo": solo,
                })

        # Scheduler: largest stacked batch first — the wide groups
        # saturate the mesh while the narrow tail drains quickly.
        if plan is not None and plan.largest_first:
            prepared.sort(key=lambda g: len(g["entry_cfgs"]), reverse=True)

        def _stage(group):
            # Double-buffered host→device staging: issue the (async)
            # device_put of the next group's stacked tensors while the
            # current group computes. Best-effort — non-divisible
            # groups stage replicated and evaluate_batch pads/shards
            # them itself; transient groups integrate unsharded, so
            # their tensors stay on the default device.
            if (
                plan is not None
                and plan.overlap
                and group["stacked"] is not None
                and group["entry_cfgs"][0].transient is None
            ):
                group = dict(
                    group,
                    stacked=[
                        dataclasses.replace(
                            m,
                            g_pos=shard_put(m.g_pos, mesh, plan.axis),
                            g_neg=shard_put(m.g_neg, mesh, plan.axis),
                            k=shard_put(m.k, mesh, plan.axis),
                        )
                        for m in group["stacked"]
                    ],
                )
            return group

        staged = iter(prepared)
        if plan is not None and plan.overlap:
            from repro.distributed.sweep import stage_pipeline

            staged = (g for _, g in stage_pipeline(prepared, _stage))

        # Phase B — one batched (optionally sharded) solve per group.
        for gi, group in enumerate(staged):
            skey, idxs = group["skey"], group["idxs"]
            entry_cfgs, spans, solo = (
                group["entry_cfgs"], group["spans"], group["solo"]
            )
            g_attrs = {"configs": len(idxs), "group": gi}
            if plan is not None:
                g_attrs["devices"] = plan.axis_size()
            with obs.trace(f"group[{gi}]", g_attrs) as g_span:
                t0 = time.perf_counter()
                g_span.set("stacked", len(entry_cfgs))
                g_span.set("solo", len(solo))
                batch = evaluate_batch(
                    params,
                    x,
                    y,
                    entry_cfgs,
                    n_samples=n_samples,
                    chunk=chunk,
                    variation_key=variation_key,
                    noise_key=noise_key,
                    activation=activation,
                    mapped_stacked=group["stacked"],
                    mesh_plan=plan,
                ) if entry_cfgs else []
                for i in solo:
                    name, cfg = items[i]
                    rep = run_variability(
                        params, x, y, cfg, cfg.variability,
                        n_samples=n_samples, chunk=chunk, activation=activation,
                    )
                    results[i] = SweepResult(name, cfg, rep, cached=False)
                    if cache is not None:
                        cache.put(keys[i], rep, name=name)
                if verbose:
                    dt = time.perf_counter() - t0
                    print(
                        f"[explore] group {gi + 1}/{len(groups)}: "
                        f"{len(idxs)} configs ({len(entry_cfgs)} stacked entries, "
                        f"{len(solo)} solo) in {dt:.2f}s (plans {skey[1]})"
                    )
                pos = 0
                for i, count, vspec in spans:
                    name, cfg = items[i]
                    if vspec is None:
                        res = batch[pos]
                    else:
                        trials = batch[pos : pos + count]
                        if count == 1 and vspec.trials > 1:  # collapsed degenerate
                            trials = trials * vspec.trials
                        res = summarize(trials, acc_threshold=vspec.acc_threshold)
                    pos += count
                    results[i] = SweepResult(name, cfg, res, cached=False)
                    if cache is not None:
                        cache.put(keys[i], res, name=name)

        elapsed = time.perf_counter() - t_run0
        derived = f"points={len(items)};groups={len(groups)}"
        if plan is not None:
            derived += f";mesh={plan.shape_str()}"
        if obs.enabled() and elapsed > 0:
            obs.gauge("sweep_points_per_s").set(len(items) / elapsed)
        # Opt-in perf-trajectory entry (obs enabled + REPRO_OBS_LEDGER
        # set): us/point with the metrics snapshot riding along. Sharded
        # runs tag their mesh shape so regression baselines stay
        # per-device-population (ledger.ENV_KEYS).
        with obs.ledger.mesh_context(
            plan.shape_str() if plan is not None else None
        ):
            obs.ledger.record_engine_run(
                "run_sweep",
                elapsed,
                count=len(items),
                derived=derived,
            )
        return [r for r in results if r is not None]


def explore(
    params: Params,
    x: jax.Array,
    y: jax.Array,
    points: SweepInput,
    *,
    objectives=DEFAULT_OBJECTIVES,
    **kw,
) -> "tuple[list[SweepResult], list[SweepResult]]":
    """run_sweep + Pareto extraction: returns (all results, front)."""
    results = run_sweep(params, x, y, points, **kw)
    front = [results[i] for i in pareto_front(results, objectives)]
    return results, front
