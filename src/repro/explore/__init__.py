"""Design-space exploration for IMAC architectures.

The paper's headline capability — multi-objective design-space
exploration — as a first-class engine:

  spec.SweepSpec        declarative grid/random sweeps over the config space
  engine.run_sweep      batched, memoized evaluation (vmapped group solves)
  engine.explore        run_sweep + Pareto-front extraction
  pareto.pareto_front   non-dominated (accuracy, power, latency) points
  cache.ResultCache     on-disk result memoization (concurrency-safe)
  MeshPlan              multi-device sharded execution (run_sweep
                        ``shard=`` / SweepSpec ``shard=``) — structure
                        groups split across a device mesh, circuit-
                        solve results bitwise-identical to the
                        single-device engine

Reliability sweeps: SweepSpec's `trials`/`sigma_rel`/`fault_rate`/...
axes attach a repro.variability.VariabilitySpec to each point; run_sweep
then batches every point's Monte-Carlo trials into the same structure-
grouped solves and returns ReliabilityReports, extractable with
pareto.RELIABILITY_OBJECTIVES (acc_q05 / power_worst / latency).

Example::

    from repro.explore import SweepSpec, explore

    spec = SweepSpec.grid(
        tech=["MRAM", "RRAM", "CBRAM", "PCM"], array_size=[32, 64]
    )
    results, front = explore(params, x, y, spec, n_samples=64,
                             cache="artifacts/sweep_cache")
    for p in front:
        print(p.name, p.accuracy, p.avg_power, p.latency)
"""
from repro.distributed.sweep import MeshPlan
from repro.explore.cache import ResultCache
from repro.explore.engine import SweepResult, explore, run_sweep
from repro.explore.pareto import (
    DEFAULT_OBJECTIVES,
    RELIABILITY_OBJECTIVES,
    pareto_front,
    pareto_mask,
)
from repro.explore.spec import SweepSpec

__all__ = [
    "DEFAULT_OBJECTIVES",
    "MeshPlan",
    "RELIABILITY_OBJECTIVES",
    "ResultCache",
    "SweepResult",
    "SweepSpec",
    "explore",
    "pareto_front",
    "pareto_mask",
    "run_sweep",
]
