"""Crossbar DC circuit solver — the "SPICE engine" of IMAC-Sim-JAX.

A crossbar partition with M row wires and N column wires, per-segment wire
resistance, row drivers behind a source resistance and column TIAs
(virtual grounds) forms a 2MN-node linear resistive network (the neuron
nonlinearity sits *behind* the TIA, so each layer's crossbar solve is
linear — nonlinearity is applied between layers, exactly as IMAC-Sim's
behavioural neuron subcircuits do).

Structure exploited: holding the column node voltages fixed, every row is
an independent tridiagonal system (wire chain + memristor loads);
symmetrically for columns. Alternating batched tridiagonal (Thomas) solves
is a block Gauss–Seidel on a symmetric diagonally-dominant M-matrix and
converges geometrically. All rows × all tiles × all samples solve in one
batched kernel — this is what makes the simulator TPU-native where SPICE
is one-netlist-at-a-time.

`solve_dense_mna` is the small-array oracle (full MNA matrix +
jnp.linalg.solve) used by tests and by the SPICE-netlist round-trip.

The solve itself is pluggable through the named backend registry in
`repro.core.backends` (selected via `SolveOptions` or the
``REPRO_SOLVER_BACKEND`` env var):

  * ``"scan"``  — lax.scan Thomas inner solve (reference, default);
  * ``"pallas"`` — Pallas Thomas tile per half-sweep
    (`repro.kernels.tridiag`);
  * ``"fused"`` — one Pallas kernel runs the *entire* sweep loop in
    VMEM (`repro.kernels.gs_fused`);
  * any `TridiagFn` callable — a custom inner solve.

Companion-model stamps (node-capacitor conductances / history currents
of a transient step, warm-start voltages) enter through the frozen
`Stamps` pytree rather than loose kwargs; the old per-field kwargs are
accepted for one release behind a DeprecationWarning.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.backends import (
    SolverBackend,
    TridiagFn,
    get_backend,
)


@dataclasses.dataclass(frozen=True)
class CircuitParams:
    """Electrical parameters of one crossbar tile's periphery + wires.

    Attributes:
      r_row: row-wire resistance per bitcell segment (ohms).
      r_col: column-wire resistance per segment (ohms).
      r_source: row driver output resistance (ohms).
      r_tia: TIA input resistance / virtual-ground quality (ohms).
      gs_iters: block Gauss–Seidel sweeps (fixed for jit).
      omega: SOR over-relaxation factor (1.0 = plain Gauss–Seidel;
        ~1.8 roughly quadruples the convergence rate on large tiles).
    """

    r_row: float = 13.8
    r_col: float = 13.8
    r_source: float = 100.0
    r_tia: float = 10.0
    gs_iters: int = 64
    omega: float = 1.8
    tol: float = 0.0  # >0: stop sweeping once max |Δvc| < tol (volts)

    @property
    def g_row(self) -> float:
        return 1.0 / self.r_row

    @property
    def g_col(self) -> float:
        return 1.0 / self.r_col

    @property
    def g_source(self) -> float:
        return 1.0 / self.r_source

    @property
    def g_tia(self) -> float:
        return 1.0 / self.r_tia


class CrossbarSolution(NamedTuple):
    """Solved node voltages and outputs of a crossbar tile batch."""

    i_out: jax.Array   # (..., N) column currents into the TIAs
    vr: jax.Array      # (..., M, N) row-wire node voltages
    vc: jax.Array      # (..., M, N) column-wire node voltages
    residual: jax.Array  # scalar-ish (...) final GS update magnitude
    # Gauss–Seidel sweeps actually run: the while_loop trip count under
    # tol-based early exit, else the static gs_iters budget. A scalar
    # (the early-exit condition is a batch max, so the whole batch
    # sweeps together) — solver telemetry rides this aux output out of
    # jit instead of host callbacks (see repro.obs).
    sweeps: jax.Array = 0


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Stamps:
    """Companion-model stamps added to the crossbar MNA assembly.

    A frozen dataclass registered as a JAX pytree — it crosses jit
    boundaries and lax control flow like any array container. All fields
    are optional (None = absent) and, when present, broadcast against
    the solve's ``(..., M, N)`` batch:

    Attributes:
      g_shunt_row: per-node extra conductance to ground on row-wire
        nodes — e.g. C/dt (BE) or 2C/dt (trapezoidal) of a node
        capacitor in an implicit transient step.
      g_shunt_col: same, on column-wire nodes.
      i_inj_row: per-node current injection into row-wire nodes — the
        companion history source of the discretized capacitor.
      i_inj_col: same, into column-wire nodes.
      v_init: initial column-node voltages — warm-starts the
        Gauss–Seidel iteration (the previous time step's solution),
        which is what makes few sweeps per transient step sufficient.
    """

    g_shunt_row: Optional[jax.Array] = None
    g_shunt_col: Optional[jax.Array] = None
    i_inj_row: Optional[jax.Array] = None
    i_inj_col: Optional[jax.Array] = None
    v_init: Optional[jax.Array] = None

    def fields(self) -> "tuple[Optional[jax.Array], ...]":
        return (
            self.g_shunt_row,
            self.g_shunt_col,
            self.i_inj_row,
            self.i_inj_col,
            self.v_init,
        )


@dataclasses.dataclass(frozen=True)
class SolveOptions:
    """Static solver configuration (how to run, not what to solve).

    Attributes:
      backend: a registry name (``"scan"``, ``"pallas"``, ``"fused"``),
        a `repro.core.backends.SolverBackend`, a bare `TridiagFn`
        callable (custom inner solve), or None for the process default
        (``$REPRO_SOLVER_BACKEND``, else ``"scan"``).
      interpret: force Pallas interpret mode on/off; None = automatic
        (interpret off-TPU, with a single logged notice).
      shard_axis: mesh axis name when the solve runs inside a
        `shard_map` over a leading batch axis (sharded sweeps,
        repro.distributed.sweep). The tol early-exit condition is a
        *batch-global* residual max; under sharding it must be pmax'ed
        across shards so every shard runs the same number of sweeps —
        that is what keeps sharded results bitwise-identical to the
        unsharded solve. None = no cross-shard reduction (default).
    """

    backend: Union[str, SolverBackend, TridiagFn, None] = None
    interpret: Optional[bool] = None
    shard_axis: Optional[str] = None

    def resolved(self) -> SolverBackend:
        return get_backend(self.backend)


DEFAULT_OPTIONS = SolveOptions()


def tridiag_scan(dl: jax.Array, d: jax.Array, du: jax.Array, b: jax.Array) -> jax.Array:
    """Batched Thomas algorithm along the last axis via lax.scan.

    Args:
      dl: (..., N) sub-diagonal (dl[..., 0] ignored).
      d:  (..., N) diagonal.
      du: (..., N) super-diagonal (du[..., N-1] ignored).
      b:  (..., N) right-hand side.

    Returns:
      x: (..., N) solution.
    """
    n = d.shape[-1]
    if n == 1:
        return b / d
    # Move the system axis to the front for scan: (N, batch...).
    dl_t = jnp.moveaxis(dl, -1, 0)
    d_t = jnp.moveaxis(d, -1, 0)
    du_t = jnp.moveaxis(du, -1, 0)
    b_t = jnp.moveaxis(b, -1, 0)

    def fwd(carry, row):
        cp_prev, dp_prev = carry
        dl_j, d_j, du_j, b_j = row
        denom = d_j - dl_j * cp_prev
        cp = du_j / denom
        dp = (b_j - dl_j * dp_prev) / denom
        return (cp, dp), (cp, dp)

    zeros = jnp.zeros_like(d_t[0])
    # First row has no sub-diagonal coupling.
    dl_eff = dl_t.at[0].set(0.0)
    (_, _), (cp, dp) = jax.lax.scan(fwd, (zeros, zeros), (dl_eff, d_t, du_t, b_t))

    def bwd(x_next, row):
        cp_j, dp_j = row
        x_j = dp_j - cp_j * x_next
        return x_j, x_j

    _, x_rev = jax.lax.scan(bwd, zeros, (cp, dp), reverse=True)
    return jnp.moveaxis(x_rev, 0, -1)


def _align(value, ndim: int, dtype=None) -> jax.Array:
    """Align a circuit scalar against an array of rank `ndim`.

    Electrical parameters may be python floats (one tile family) or
    arrays with leading batch axes — e.g. a (C,) vector of per-config
    resistances in a batched design-space sweep. Leading-axis arrays are
    reshaped so their axes line up with the target's *leading* axes and
    broadcast over the rest.
    """
    v = jnp.asarray(value, dtype)
    if v.ndim == 0 or v.ndim == ndim:
        return v
    return v.reshape(v.shape + (1,) * (ndim - v.ndim))


def _row_system(
    g: jax.Array,
    vc: jax.Array,
    v_in: jax.Array,
    cp: CircuitParams,
    g_shunt=None,
    i_inj=None,
):
    """Tridiagonal systems for all rows given column voltages.

    g, vc: (..., M, N); v_in: (..., M). Systems run along N.
    `g_shunt`/`i_inj` add a per-node conductance to ground / current
    injection — the companion-model stamps of node capacitors in a
    transient step (repro.transient); both default to absent (pure DC).
    """
    n = g.shape[-1]
    dtype = g.dtype
    g_row = _align(cp.g_row, g.ndim, dtype)
    g_source = _align(cp.g_source, g.ndim, dtype)
    if n == 1:
        chain = g_source
    else:
        idx = jnp.arange(n)
        chain = jnp.where(
            idx == 0,
            g_row + g_source,
            jnp.where(idx == n - 1, g_row, 2.0 * g_row),
        )
    d = chain + g
    if g_shunt is not None:
        d = d + g_shunt
    off = jnp.broadcast_to(-g_row, g.shape)
    dl = off
    du = off
    b = g * vc
    b = b.at[..., 0].add(_align(cp.g_source, g.ndim - 1, dtype) * v_in)
    if i_inj is not None:
        b = b + i_inj
    return dl, d, du, b


def _col_system(
    g: jax.Array,
    vr: jax.Array,
    cp: CircuitParams,
    g_shunt=None,
    i_inj=None,
):
    """Tridiagonal systems for all columns given row voltages.

    Transposed view: systems run along M. g, vr: (..., M, N);
    `g_shunt`/`i_inj` are per-node capacitor companion stamps in the
    untransposed (..., M, N) layout. Returns arrays shaped (..., N, M).
    """
    m = g.shape[-2]
    dtype = g.dtype
    gt = jnp.swapaxes(g, -1, -2)     # (..., N, M)
    vrt = jnp.swapaxes(vr, -1, -2)
    g_col = _align(cp.g_col, gt.ndim, dtype)
    g_tia = _align(cp.g_tia, gt.ndim, dtype)
    if m == 1:
        chain = g_tia
    else:
        idx = jnp.arange(m)
        chain = jnp.where(
            idx == 0,
            g_col,
            jnp.where(idx == m - 1, g_col + g_tia, 2.0 * g_col),
        )
    d = chain + gt
    if g_shunt is not None:
        d = d + jnp.swapaxes(g_shunt, -1, -2)
    off = jnp.broadcast_to(-g_col, gt.shape)
    dl = off
    du = off
    b = gt * vrt  # TIA node is grounded: no extra rhs term.
    if i_inj is not None:
        b = b + jnp.swapaxes(i_inj, -1, -2)
    return dl, d, du, b


def _merge_deprecated(
    tridiag,
    stamps: Optional[Stamps],
    options: Optional[SolveOptions],
    legacy: dict,
) -> "tuple[Optional[Stamps], SolveOptions]":
    """One-release deprecation shim: warn and forward the old kwargs.

    The pre-registry API passed companion stamps as five loose kwargs
    and the inner solve as a raw ``tridiag=`` callable. Both still work
    (covered by tests/test_backends.py) but emit a DeprecationWarning;
    mixing old and new spellings of the same thing is an error.
    """
    used = {k: v for k, v in legacy.items() if v is not None}
    if used:
        if stamps is not None:
            raise ValueError(
                f"pass companion stamps either as Stamps or as the "
                f"deprecated kwargs {sorted(used)}, not both"
            )
        warnings.warn(
            f"solve_crossbar kwargs {sorted(used)} are deprecated; pass "
            "stamps=Stamps(...) instead (one-release shim)",
            DeprecationWarning,
            stacklevel=3,
        )
        stamps = Stamps(**used)
    if tridiag is not None:
        if options is not None and options.backend is not None:
            raise ValueError(
                "pass the solver either as options=SolveOptions(backend=...) "
                "or as the deprecated tridiag= callable, not both"
            )
        warnings.warn(
            "solve_crossbar's tridiag= argument is deprecated; pass "
            "options=SolveOptions(backend=<name or TridiagFn>) instead "
            "(one-release shim)",
            DeprecationWarning,
            stacklevel=3,
        )
        options = dataclasses.replace(
            options or DEFAULT_OPTIONS, backend=tridiag
        )
    return stamps, options or DEFAULT_OPTIONS


def solve_crossbar(
    g: jax.Array,
    v_in: jax.Array,
    cp: CircuitParams,
    tridiag: Optional[TridiagFn] = None,
    *,
    stamps: Optional[Stamps] = None,
    options: Optional[SolveOptions] = None,
    g_shunt_row: "jax.Array | None" = None,
    g_shunt_col: "jax.Array | None" = None,
    i_inj_row: "jax.Array | None" = None,
    i_inj_col: "jax.Array | None" = None,
    v_init: "jax.Array | None" = None,
) -> CrossbarSolution:
    """Solve crossbar tiles (DC, or one implicit transient step).

    The electrical fields of `cp` may be python floats or arrays with
    leading batch axes aligned to g's leading axes — a design-space sweep
    passes (C,) per-config resistances so C configurations share one
    solve (and one compilation) with a single while_loop; `gs_iters` and
    `tol` stay static.

    With optional companion-model stamps (`stamps`) this same assembly
    solves one implicit time step of the parasitic-RC network: a node
    capacitor C discretized by backward-Euler/trapezoidal becomes a
    conductance to ground (`Stamps.g_shunt_*`, C/dt or 2C/dt) plus a
    history current source (`Stamps.i_inj_*`) — see
    repro.transient.integrator. `Stamps.v_init` warm-starts the
    Gauss–Seidel iteration (the previous time step's column voltages),
    which is what makes few sweeps per step sufficient.

    How the solve runs is chosen by `options` (see `SolveOptions` and
    repro.core.backends): the ``"scan"`` and ``"pallas"`` backends drive
    the sweep loop here with a batched inner tridiagonal solve; the
    ``"fused"`` backend hands the whole loop to one Pallas kernel that
    keeps the systems resident in VMEM across sweeps. The fused backend
    runs the full `gs_iters` budget (no `tol` early exit — on-chip
    sweeps are cheap); `tol` still applies to the other backends.

    Args:
      g: (..., M, N) memristor conductances (S). 0 = absent device.
      v_in: (..., M) driver voltages behind r_source.
      cp: circuit parameters.
      tridiag: DEPRECATED — raw inner-solve callable; use
        ``options=SolveOptions(backend=...)``.
      stamps: optional companion-model stamps / warm start.
      options: backend selection and Pallas interpret override.
      g_shunt_row / g_shunt_col / i_inj_row / i_inj_col / v_init:
        DEPRECATED loose spellings of the `Stamps` fields (one-release
        shim; warns and forwards).

    Returns:
      CrossbarSolution; i_out[..., j] = current into column j's TIA.
    """
    stamps, options = _merge_deprecated(
        tridiag,
        stamps,
        options,
        dict(
            g_shunt_row=g_shunt_row,
            g_shunt_col=g_shunt_col,
            i_inj_row=i_inj_row,
            i_inj_col=i_inj_col,
            v_init=v_init,
        ),
    )
    backend = options.resolved()
    if backend.make_solve is not None:
        return backend.make_solve(options)(g, v_in, cp, stamps)
    return _sweep_solve(
        g, v_in, cp, backend.make_tridiag(options), stamps,
        shard_axis=options.shard_axis,
    )


def _sweep_solve(
    g: jax.Array,
    v_in: jax.Array,
    cp: CircuitParams,
    tridiag: TridiagFn,
    stamps: Optional[Stamps],
    shard_axis: Optional[str] = None,
) -> CrossbarSolution:
    """The generic sweep loop: batched inner tridiag + SOR in jnp."""
    st = stamps or Stamps()
    g_shunt_row, g_shunt_col = st.g_shunt_row, st.g_shunt_col
    i_inj_row, i_inj_col, v_init = st.i_inj_row, st.i_inj_col, st.v_init
    g = jnp.asarray(g)
    v_in = jnp.asarray(v_in)
    m, n = g.shape[-2], g.shape[-1]
    # Broadcast conductances and drives to a common batch shape so the
    # loop carry and scan carries have fixed shapes.
    batch = jnp.broadcast_shapes(
        g.shape[:-2],
        v_in.shape[:-1],
        *(
            x.shape[:-2]
            for x in (g_shunt_row, g_shunt_col, i_inj_row, i_inj_col, v_init)
            if x is not None
        ),
    )
    g = jnp.broadcast_to(g, batch + (m, n))
    v_in = jnp.broadcast_to(v_in, batch + (m,))
    vc0 = (
        jnp.zeros_like(g)
        if v_init is None
        else jnp.broadcast_to(v_init.astype(g.dtype), g.shape)
    )
    omega = _align(cp.omega, g.ndim, g.dtype)

    def sweep(vc):
        dl, d, du, b = _row_system(g, vc, v_in, cp, g_shunt_row, i_inj_row)
        vr = tridiag(dl, d, du, b)
        dl, d, du, b = _col_system(g, vr, cp, g_shunt_col, i_inj_col)
        vct = tridiag(dl, d, du, b)
        return vr, jnp.swapaxes(vct, -1, -2)

    res0 = jnp.full(batch, jnp.inf, g.dtype)

    if cp.tol > 0.0:
        # Early-exit sweeps: most samples/tiles converge well before the
        # worst-case bound (§Perf solver iteration — ~2-3x fewer sweeps
        # on the 32x32 Table-III workload).
        def w_cond(carry):
            _, res, i = carry
            rmax = jnp.max(res)
            if shard_axis is not None:
                # Inside shard_map the batch max only sees the local
                # shard; pmax restores the batch-global trip count so
                # sharded and unsharded solves sweep in lockstep.
                rmax = jax.lax.pmax(rmax, shard_axis)
            return jnp.logical_and(i < cp.gs_iters, rmax > cp.tol)

        def w_body(carry):
            vc, _, i = carry
            vr, vc_gs = sweep(vc)
            vc_new = vc + omega * (vc_gs - vc)
            res = jnp.max(jnp.abs(vc_new - vc), axis=(-1, -2))
            return vc_new, res, i + 1

        vc, residual, sweeps = jax.lax.while_loop(
            w_cond, w_body, (vc0, res0, jnp.zeros((), jnp.int32))
        )
    else:
        def body(_, carry):
            vc, _ = carry
            vr, vc_gs = sweep(vc)
            vc_new = vc + omega * (vc_gs - vc)
            res = jnp.max(jnp.abs(vc_new - vc), axis=(-1, -2))
            return vc_new, res

        vc, residual = jax.lax.fori_loop(0, cp.gs_iters, body, (vc0, res0))
        sweeps = jnp.asarray(cp.gs_iters, jnp.int32)
    vr, vc = sweep(vc)  # final row solve consistent with converged vc
    i_out = _align(cp.g_tia, vc.ndim - 1, g.dtype) * vc[..., m - 1, :]
    return CrossbarSolution(
        i_out=i_out, vr=vr, vc=vc, residual=residual, sweeps=sweeps
    )


def suggest_iters(m: int, n: int) -> int:
    """Sweeps needed for <~1e-3 relative output error (empirical; see
    tests/test_solver.py). The GS rate degrades as total device
    conductance per line approaches the wire conductance, which scales
    with line length."""
    return max(48, int(0.75 * max(m, n)))


def solve_ideal(g: jax.Array, v_in: jax.Array) -> jax.Array:
    """Ideal crossbar (no parasitics): i_out = g^T v. (..., M, N) x (..., M)."""
    return jnp.einsum("...mn,...m->...n", g, v_in)


# ---------------------------------------------------------------------------
# Dense MNA oracle (small arrays; used by tests + netlist round-trip).
# ---------------------------------------------------------------------------


def _mna_matrix(g, v_in, cp: CircuitParams, stamps: "Stamps | None" = None):
    """Assemble the full (2MN, 2MN) conductance matrix and RHS.

    Node order: row nodes r(i,j) = i*N+j, then column nodes
    c(i,j) = M*N + i*N + j. Ground (TIA virtual ground, source return) is
    eliminated. Optional `stamps` add the transient companion model
    (per-node shunt conductances on the diagonal, history-current
    injections on the RHS), so an implicit integrator step can be
    oracle-checked against the same dense assembly as the DC solve.
    """
    m, n = g.shape
    nn = 2 * m * n
    a = jnp.zeros((nn, nn), g.dtype)
    rhs = jnp.zeros((nn,), g.dtype)

    def r_idx(i, j):
        return i * n + j

    def c_idx(i, j):
        return m * n + i * n + j

    def stamp(a, p, q, cond):
        a = a.at[p, p].add(cond)
        a = a.at[q, q].add(cond)
        a = a.at[p, q].add(-cond)
        a = a.at[q, p].add(-cond)
        return a

    # Row wires.
    for i in range(m):
        for j in range(n - 1):
            a = stamp(a, r_idx(i, j), r_idx(i, j + 1), cp.g_row)
    # Column wires.
    for j in range(n):
        for i in range(m - 1):
            a = stamp(a, c_idx(i, j), c_idx(i + 1, j), cp.g_col)
    # Memristors.
    for i in range(m):
        for j in range(n):
            a = stamp(a, r_idx(i, j), c_idx(i, j), g[i, j])
    # Sources (to v_in through g_source) and TIAs (to ground through g_tia).
    for i in range(m):
        p = r_idx(i, 0)
        a = a.at[p, p].add(cp.g_source)
        rhs = rhs.at[p].add(cp.g_source * v_in[i])
    for j in range(n):
        p = c_idx(m - 1, j)
        a = a.at[p, p].add(cp.g_tia)
    if stamps is not None:
        mn = m * n
        diag = jnp.arange(nn)
        for block, g_sh, i_inj in (
            (slice(0, mn), stamps.g_shunt_row, stamps.i_inj_row),
            (slice(mn, nn), stamps.g_shunt_col, stamps.i_inj_col),
        ):
            if g_sh is not None:
                flat = jnp.broadcast_to(
                    jnp.asarray(g_sh, g.dtype), (m, n)
                ).reshape(mn)
                a = a.at[diag[block], diag[block]].add(flat)
            if i_inj is not None:
                flat = jnp.broadcast_to(
                    jnp.asarray(i_inj, g.dtype), (m, n)
                ).reshape(mn)
                rhs = rhs.at[block].add(flat)
    return a, rhs


def mna_system(
    g: jax.Array,
    v_in: jax.Array,
    cp: CircuitParams,
    stamps: "Stamps | None" = None,
) -> "tuple[jax.Array, jax.Array]":
    """Public dense-MNA assembly of one tile: (A, rhs) with A (2MN, 2MN).

    Node order: row nodes r(i,j) = i*N+j first, then column nodes
    c(i,j) = M*N + i*N + j — the same order `node_capacitances` in
    repro.transient.integrator flattens to, so the transient dense oracle
    (C dv/dt = rhs - A v) can be built directly from these stamps.
    Optional `stamps` add the companion model of one implicit step.
    """
    return _mna_matrix(jnp.asarray(g), jnp.asarray(v_in), cp, stamps)


def solve_dense_mna(
    g: jax.Array,
    v_in: jax.Array,
    cp: CircuitParams,
    stamps: "Stamps | None" = None,
) -> CrossbarSolution:
    """Oracle: full MNA solve of one tile. g: (M, N), v_in: (M,).

    With `stamps`, solves the companion system of one implicit transient
    step instead of the DC operating point (`Stamps.v_init` is ignored —
    the dense solve needs no warm start).
    """
    g = jnp.asarray(g)
    v_in = jnp.asarray(v_in)
    m, n = g.shape
    a, rhs = _mna_matrix(g, v_in, cp, stamps)
    x = jnp.linalg.solve(a, rhs)
    vr = x[: m * n].reshape(m, n)
    vc = x[m * n :].reshape(m, n)
    i_out = cp.g_tia * vc[m - 1, :]
    return CrossbarSolution(
        i_out=i_out,
        vr=vr,
        vc=vc,
        residual=jnp.zeros(()),
        sweeps=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Power extraction from a solved tile.
# ---------------------------------------------------------------------------


def crossbar_power(
    g: jax.Array,
    v_in: jax.Array,
    sol: CrossbarSolution,
    cp: CircuitParams,
) -> jax.Array:
    """Total dissipated power (W) of solved tiles; reduces last two dims."""
    vr, vc = sol.vr, sol.vc
    p_dev = jnp.sum(g * (vr - vc) ** 2, axis=(-1, -2))
    ndim = p_dev.ndim
    dr = jnp.diff(vr, axis=-1)
    p_row = _align(cp.g_row, ndim, dr.dtype) * jnp.sum(dr**2, axis=(-1, -2))
    dc = jnp.diff(vc, axis=-2)
    p_col = _align(cp.g_col, ndim, dc.dtype) * jnp.sum(dc**2, axis=(-1, -2))
    p_src = _align(cp.g_source, ndim, vr.dtype) * jnp.sum(
        (v_in - vr[..., :, 0]) ** 2, axis=-1
    )
    p_tia = _align(cp.g_tia, ndim, vc.dtype) * jnp.sum(
        vc[..., -1, :] ** 2, axis=-1
    )
    return p_dev + p_row + p_col + p_src + p_tia


def ideal_power(g: jax.Array, v_in: jax.Array) -> jax.Array:
    """Power of the ideal crossbar (columns at virtual ground)."""
    return jnp.einsum("...mn,...m->...", g, v_in**2)
