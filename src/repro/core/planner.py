"""Analog deployment planner: the paper's design-space exploration
applied to the assigned LM architectures.

For every weight matrix of an arch, plan the crossbar tiling (auto
H_P/V_P per Table III arithmetic), count subarrays and devices, and
estimate static+read power and per-MVM latency with the same circuit
cost model the MNIST pipeline uses. This answers the paper's question —
"what does deploying this network on IMAC cost?" — for 2024-25 LLMs
(benchmarks/deploy_report.py prints the table).

Dynamic matmuls (attention scores) and stateful mixers stay digital;
only static projection weights map to crossbars (the paper's
static-conductance assumption; DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import ModelConfig
from repro.core.devices import DeviceTech, get_tech
from repro.core.interconnect import DEFAULT_INTERCONNECT
from repro.core.neurons import get_neuron
from repro.core.partition import auto_partition


@dataclasses.dataclass(frozen=True)
class MatrixPlan:
    name: str
    fan_in: int
    fan_out: int
    count: int          # how many instances (layers x experts ...)
    hp: int
    vp: int

    @property
    def tiles_per_instance(self) -> int:
        return self.hp * self.vp

    @property
    def tiles(self) -> int:
        return self.tiles_per_instance * self.count

    @property
    def devices(self) -> int:
        # differential pair -> 2 devices per logical weight (+ bias row)
        return 2 * (self.fan_in + 1) * self.fan_out * self.count


@dataclasses.dataclass
class DeploymentReport:
    arch: str
    tech: str
    array_rows: int
    array_cols: int
    matrices: "List[MatrixPlan]"
    total_tiles: int
    total_devices: int
    est_power_w: float       # all tiles active, mean conductance
    est_latency_ns: float    # one analog MVM through the deepest layer
    area_mm2: float          # bitcell-pitch estimate

    def as_row(self) -> Dict[str, object]:
        return {
            "arch": self.arch,
            "tech": self.tech,
            "array": f"{self.array_rows}x{self.array_cols}",
            "tiles": self.total_tiles,
            "devices": self.total_devices,
            "est_power_w": round(self.est_power_w, 1),
            "est_latency_ns": round(self.est_latency_ns, 2),
            "area_mm2": round(self.area_mm2, 1),
        }


def _arch_matrices(cfg: ModelConfig) -> "List[MatrixPlan]":
    """Enumerate the static projection matrices of one arch."""
    e, f = cfg.d_model, cfg.d_ff
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    out: "list[tuple[str, int, int, int]]" = []
    n_attn = sum(cfg.is_attn_layer(i) for i in range(cfg.n_layers))
    n_moe = sum(cfg.is_moe_layer(i) for i in range(cfg.n_layers))
    n_dense = cfg.n_layers - n_moe
    if cfg.ssm_type == "rwkv6":
        out += [("rwkv_rkvgo", e, e, 5 * cfg.n_layers)]
        out += [("cm_wk", e, f, cfg.n_layers), ("cm_wv", f, e, cfg.n_layers)]
    else:
        if cfg.attn_type == "mla":
            qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
            out += [
                ("w_dq", e, cfg.q_lora_rank or e, n_attn),
                ("w_uq", cfg.q_lora_rank or e, h * qk, n_attn),
                ("w_dkv", e, cfg.kv_lora_rank + cfg.qk_rope_head_dim, n_attn),
                ("w_ukv", cfg.kv_lora_rank, h * (cfg.qk_nope_head_dim + cfg.v_head_dim), n_attn),
                ("w_o", h * cfg.v_head_dim, e, n_attn),
            ]
        else:
            out += [
                ("wq", e, h * hd, n_attn),
                ("wk", e, kv * hd, n_attn),
                ("wv", e, kv * hd, n_attn),
                ("wo", h * hd, e, n_attn),
            ]
        if cfg.ssm_type == "mamba":
            n_mamba = cfg.n_layers - n_attn
            di = cfg.ssm_expand * e
            out += [
                ("mamba_in", e, 2 * di, n_mamba),
                ("mamba_out", di, e, n_mamba),
            ]
        if n_dense:
            out += [
                ("mlp_gate", e, f, n_dense),
                ("mlp_up", e, f, n_dense),
                ("mlp_down", f, e, n_dense),
            ]
        if n_moe:
            fe = cfg.moe_d_ff
            n_exp = cfg.n_experts + cfg.n_shared_experts
            out += [
                ("moe_gate", e, fe, n_moe * n_exp),
                ("moe_up", e, fe, n_moe * n_exp),
                ("moe_down", fe, e, n_moe * n_exp),
            ]
    out += [("lm_head", e, cfg.vocab, 1)]
    return out


def plan_arch(
    cfg: ModelConfig,
    tech: "DeviceTech | str" = "PCM",
    array_rows: int = 512,
    array_cols: int = 512,
) -> DeploymentReport:
    tech = get_tech(tech)
    neuron = get_neuron("sigmoid")
    ic = DEFAULT_INTERCONNECT
    plans = []
    for name, fi, fo, count in _arch_matrices(cfg):
        hp, vp = auto_partition(fi, fo, array_rows, array_cols)
        plans.append(MatrixPlan(name, fi, fo, count, hp, vp))

    total_tiles = sum(p.tiles for p in plans)
    total_devices = sum(p.devices for p in plans)

    # Power: mean device conductance at half swing + interface circuits.
    g_mean = 0.5 * (tech.g_on + tech.g_off)
    v_mean = 0.5 * neuron.vdd
    p_device = g_mean * v_mean**2
    n_cols_active = sum(p.fan_out * p.count * p.hp for p in plans)
    est_power = (
        total_devices * p_device
        + n_cols_active * 2 * neuron.p_amp
        + sum(p.fan_out * p.count for p in plans) * neuron.p_neuron
    )

    # Latency: deepest chain = layers in sequence; per-tile Elmore.
    t_tile = 4.6 * (ic.elmore_delay(array_cols) + ic.elmore_delay(array_rows))
    depth = cfg.n_layers * (2 if cfg.moe_enabled or cfg.d_ff else 2)
    est_latency = depth * (t_tile + neuron.t_settle)

    # Area: bitcell pitch^2 per device (2 devices/weight already counted).
    area = total_devices * (ic.pitch**2) * 1e6  # m^2 -> mm^2 (1e6 mm2/m2)
    return DeploymentReport(
        arch=cfg.name,
        tech=tech.name,
        array_rows=array_rows,
        array_cols=array_cols,
        matrices=plans,
        total_tiles=total_tiles,
        total_devices=total_devices,
        est_power_w=float(est_power),
        est_latency_ns=float(est_latency * 1e9),
        area_mm2=float(area),
    )
