"""Composable IMAC circuit modules: IMACLinear / IMACNetwork.

This is the JAX-native equivalent of IMAC-Sim's mapLayer/mapIMAC: each DNN
layer becomes a set of partitioned crossbar tiles (differential G+/G-
arrays), solved with the batched circuit solver, followed by the
behavioural differential-amp + neuron. Power and latency are extracted
from the solved node voltages per Algorithm 1.

Fast paths:
  * parasitics=True  — full circuit solve (the paper's simulation).
  * parasitics=False — ideal analog MVM (quantisation/variation/noise only),
    optionally through the Pallas `imac_mvm` kernel.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, NamedTuple, Optional, Sequence

if TYPE_CHECKING:  # pragma: no cover - annotation only, avoids a cycle
    from repro.transient.spec import TransientSpec
    from repro.variability.spec import VariabilitySpec

import jax
import jax.numpy as jnp

from repro.core.devices import DeviceTech, get_tech
from repro.core.interconnect import DEFAULT_INTERCONNECT, Interconnect
from repro.core.mapping import MappedLayer, map_network
from repro.core.neurons import NeuronModel, get_neuron
from repro.core.partition import (
    PartitionPlan,
    auto_partition,
    combine_outputs,
    plan_partition,
    tile_inputs,
    tile_matrix,
)
from repro.core.solver import (
    CircuitParams,
    SolveOptions,
    _align as _align_leading,
    crossbar_power,
    solve_crossbar,
    suggest_iters,
)


@dataclasses.dataclass(frozen=True)
class IMACConfig:
    """User-facing hyperparameters (paper Table I)."""

    tech: DeviceTech | str = "MRAM"
    neuron: NeuronModel | str = "sigmoid"
    interconnect: Interconnect = DEFAULT_INTERCONNECT
    array_rows: int = 32
    array_cols: int = 32
    hp: Optional[Sequence[int]] = None   # horizontal partitions per layer
    vp: Optional[Sequence[int]] = None   # vertical partitions per layer
    vdd: float = 0.8
    vss: float = -0.8
    r_source: float = 100.0
    r_tia: float = 10.0
    parasitics: bool = True
    quantize: bool = True
    gs_iters: Optional[int] = None       # None = suggest from tile size
    sor_omega: float = 1.8
    gs_tol: float = 1e-6                 # early-exit sweep tolerance (V)
    t_sampling: float = 20e-9            # Table II: 20ns (printed as 20nm)
    dtype: jnp.dtype = jnp.float32
    # Optional Monte-Carlo reliability analysis attached to this design
    # point (repro.variability.VariabilitySpec). None = deterministic
    # point evaluation. Does not affect the traced circuit structure —
    # trials stack along the same leading config axis the design-space
    # engine already batches over.
    variability: "Optional[VariabilitySpec]" = None
    # Optional waveform-accurate transient co-simulation attached to this
    # design point (repro.transient.TransientSpec). When set, latency is
    # measured from the integrated .tran waveforms (settling detection)
    # and an integrated energy joins the result, instead of the
    # input-independent analytic Elmore estimate. The spec's static
    # fields shape the traced scan, so it participates in structure_key
    # grouping.
    transient: "Optional[TransientSpec]" = None

    def resolved_tech(self) -> DeviceTech:
        return get_tech(self.tech)

    def resolved_neuron(self) -> NeuronModel:
        n = get_neuron(self.neuron)
        if n.vdd != self.vdd or n.vss != self.vss:
            n = dataclasses.replace(n, vdd=self.vdd, vss=self.vss)
        return n

    def circuit_params(self, rows: int, cols: int) -> CircuitParams:
        iters = self.gs_iters or suggest_iters(rows, cols)
        return CircuitParams(
            r_row=self.interconnect.r_segment,
            r_col=self.interconnect.r_segment,
            r_source=self.r_source,
            r_tia=self.r_tia,
            gs_iters=iters,
            omega=self.sor_omega,
            tol=self.gs_tol,
        )


class LayerStats(NamedTuple):
    power: jax.Array       # (batch,) total layer power (W)
    latency: jax.Array     # scalar, settling latency estimate (s)
    residual: jax.Array    # worst GS residual across tiles
    z: jax.Array           # (batch, fan_out) recovered pre-activations
    sweeps: jax.Array = 0  # GS sweeps the layer's solve actually ran


class TransientStats(NamedTuple):
    """Waveform-derived statistics of one layer's transient integration.

    All arrays carry the leading (C,) stacked-configuration axis of the
    batched integration (repro.transient.engine).
    """

    t_settle: jax.Array    # (C,) measured settling time of the layer (s)
    energy: jax.Array      # (C,) integrated dissipation over the horizon (J)
    settled: jax.Array     # (C,) bool: output nodes in band at the horizon
    dt: jax.Array          # final-pass step size (s) — the time resolution
    waveform: Optional[jax.Array] = None  # (C, P, 2T, steps, N) foot voltages


class IMACLayerOutput(NamedTuple):
    activations: jax.Array
    stats: LayerStats


def build_plans(
    topology: Sequence[int], cfg: IMACConfig
) -> "list[PartitionPlan]":
    n_layers = len(topology) - 1
    hp, vp = cfg.hp, cfg.vp
    if hp is None or vp is None:
        pairs = [
            auto_partition(topology[i], topology[i + 1], cfg.array_rows, cfg.array_cols)
            for i in range(n_layers)
        ]
        hp = [p[0] for p in pairs]
        vp = [p[1] for p in pairs]
    return [
        plan_partition(topology[i], topology[i + 1], hp[i], vp[i])
        for i in range(n_layers)
    ]


def linear_forward(
    g_pos: jax.Array,
    g_neg: jax.Array,
    k: "jax.Array | float",
    v_unit: "jax.Array | float",
    plan: PartitionPlan,
    cp: CircuitParams,
    neuron,
    a: jax.Array,
    *,
    parasitics: bool = True,
    is_output: bool = False,
    solve_options: Optional[SolveOptions] = None,
    noise_key: Optional[jax.Array] = None,
    read_noise_rel: "jax.Array | float" = 0.0,
    noise_per_config: bool = False,
    dtype: jnp.dtype = jnp.float32,
) -> "tuple[jax.Array, jax.Array, jax.Array, jax.Array]":
    """Functional core of one analog layer: crossbar solve + diff amp + neuron.

    Supports stacked-configuration batches: the conductance matrices may
    carry leading config axes — g_pos/g_neg (C, fan_in+1, fan_out) with
    `k`, the electrical fields of `cp` (not `gs_iters`/`tol`) and
    `read_noise_rel` as (C,) arrays — and the whole configuration batch
    then shares ONE circuit solve / ONE compilation
    (see core/evaluate.evaluate_batch). With 2-D conductances and float
    scalars this is exactly the single-configuration layer.

    `noise_per_config` controls how read noise is drawn for stacked
    batches: False (default) shares one draw across the leading config
    axes — a paired design-space comparison; True draws independently
    per stacked entry — what a Monte-Carlo trial axis wants.

    Returns:
      (activations, power, residual, z, sweeps) — power is (..., batch),
      residual (...,), z the recovered pre-activations, sweeps the GS
      trip count of the layer's circuit solve (0 without parasitics).
    """
    # Bias input: driven at v_unit (logical activation 1).
    ones = jnp.ones(a.shape[:-1] + (1,), dtype)
    v = jnp.concatenate([a.astype(dtype), ones], axis=-1) * v_unit

    sweeps = jnp.zeros((), jnp.int32)
    if not parasitics:
        g_diff = (g_pos - g_neg).astype(dtype)
        i_diff = jnp.einsum("...mn,...bm->...bn", g_diff, v)
        p_dev = jnp.einsum("...mn,...bm->...b", g_pos + g_neg, v**2)
        residual = jnp.zeros(g_pos.shape[:-2], dtype)
    else:
        tiles_p = tile_matrix(g_pos.astype(dtype), plan)
        tiles_n = tile_matrix(g_neg.astype(dtype), plan)
        g_all = jnp.concatenate([tiles_p, tiles_n], axis=-3)  # (..., 2T, M, N)
        v_tiles = tile_inputs(v, plan)                        # (..., batch, hp, M)
        # tile t = h*vp + vcol shares the h-th input slice.
        v_per_tile = jnp.repeat(v_tiles, plan.vp, axis=-2)    # (..., batch, T, M)
        v_all = jnp.concatenate([v_per_tile, v_per_tile], axis=-2)  # (..., b, 2T, M)
        # Insert the sample axis into g: (..., 1, 2T, M, N) vs (..., b, 2T, M).
        g_b = g_all[..., None, :, :, :]
        sol = solve_crossbar(g_b, v_all, cp, options=solve_options)
        t = plan.n_tiles
        i_pos = combine_outputs(sol.i_out[..., :t, :], plan)
        i_neg = combine_outputs(sol.i_out[..., t:, :], plan)
        i_diff = i_pos - i_neg
        p_dev = crossbar_power(g_b, v_all, sol, cp).sum(axis=-1)
        residual = jnp.max(sol.residual, axis=(-1, -2))
        sweeps = jnp.asarray(sol.sweeps, jnp.int32)

    if noise_key is not None:
        # Default: one draw shared by every stacked configuration —
        # identical to evaluating each configuration separately with the
        # same key. Per-config: independent noise along the leading axes.
        shape = i_diff.shape if noise_per_config else i_diff.shape[-2:]
        noise = jax.random.normal(noise_key, shape, dtype)
        rel = _align_leading(read_noise_rel, i_diff.ndim, dtype)
        scale = rel * jnp.maximum(jnp.abs(i_diff), 1e-12)
        i_diff = i_diff + scale * noise

    # Differential sense: recover digital pre-activation.
    z = i_diff / (_align_leading(k, i_diff.ndim, dtype) * v_unit)
    z = neuron.clip_preactivation(z)
    act = z if is_output else neuron.activation(z)

    # Interface power: one TIA+amp per tile column, one neuron per output.
    n_amps = plan.hp * plan.vp * plan.cols * 2  # differential pair sensing
    n_neurons = plan.total_cols
    p_iface = n_amps * neuron.p_amp + n_neurons * neuron.p_neuron
    power = p_dev + p_iface
    return act, power, residual, z, sweeps


def layer_latency(plan: PartitionPlan, interconnect: Interconnect, neuron) -> float:
    """Structural settling latency of one layer (input-independent).

    Elmore delay of the row+column lines (1% settling ~ 4.6 tau) + neuron.
    """
    t_line = 4.6 * (
        interconnect.elmore_delay(plan.cols) + interconnect.elmore_delay(plan.rows)
    )
    return t_line + neuron.t_settle


def imac_linear(
    mapped: MappedLayer,
    plan: PartitionPlan,
    a: jax.Array,
    cfg: IMACConfig,
    *,
    is_output: bool = False,
    solve_options: Optional[SolveOptions] = None,
    noise_key: Optional[jax.Array] = None,
) -> IMACLayerOutput:
    """One analog layer: crossbar solve + diff amp + neuron.

    Args:
      mapped: differential conductances (bias row folded).
      plan: tiling over (hp, vp) partitions.
      a: (batch, fan_in) activations in digital units.
      cfg: circuit hyperparameters.
      is_output: last layer — linear readout (no neuron nonlinearity).
      solve_options: solver backend selection (None = process default).
      noise_key: optional key for read noise on the output currents.

    Returns:
      activations (batch, fan_out) and per-layer circuit stats.
    """
    tech = cfg.resolved_tech()
    neuron = cfg.resolved_neuron()
    dtype = cfg.dtype
    if not (noise_key is not None and tech.read_noise_rel > 0.0):
        noise_key = None
    act, power, residual, z, sweeps = linear_forward(
        mapped.g_pos,
        mapped.g_neg,
        mapped.k,
        mapped.v_unit,
        plan,
        cfg.circuit_params(plan.rows, plan.cols),
        neuron,
        a,
        parasitics=cfg.parasitics,
        is_output=is_output,
        solve_options=solve_options,
        noise_key=noise_key,
        read_noise_rel=tech.read_noise_rel,
        dtype=dtype,
    )
    latency = jnp.asarray(layer_latency(plan, cfg.interconnect, neuron), dtype)
    return IMACLayerOutput(
        activations=act,
        stats=LayerStats(
            power=power, latency=latency, residual=residual, z=z, sweeps=sweeps
        ),
    )


class IMACNetwork:
    """The full mapped network (mapIMAC): layers + plans + forward.

    Construction performs mapWB (Module 2) and partition planning
    (Module 3's tiling); `__call__` simulates the concatenated circuit
    (Module 4 + Algorithm 1's SPICE run), returning outputs and stats.
    """

    def __init__(
        self,
        params: "list[tuple[jax.Array, jax.Array]]",
        cfg: IMACConfig,
        *,
        variation_key: Optional[jax.Array] = None,
    ):
        self.cfg = cfg
        tech = cfg.resolved_tech()
        topology = [params[0][0].shape[0]] + [w.shape[1] for w, _ in params]
        self.topology = topology
        self.mapped = map_network(
            params,
            tech,
            v_unit=cfg.vdd,
            quantize=cfg.quantize,
            variation_key=variation_key,
        )
        self.plans = build_plans(topology, cfg)

    @property
    def hp(self) -> "list[int]":
        return [p.hp for p in self.plans]

    @property
    def vp(self) -> "list[int]":
        return [p.vp for p in self.plans]

    def __call__(
        self,
        x: jax.Array,
        *,
        solve_options: Optional[SolveOptions] = None,
        noise_key: Optional[jax.Array] = None,
    ) -> "tuple[jax.Array, list[LayerStats]]":
        """Simulate the full IMAC circuit for a batch of inputs.

        Args:
          x: (batch, n_inputs) in digital activation units ([0,1] for
            sigmoid networks).

        Returns:
          (batch, n_outputs) final pre-activations (linear readout) and
          per-layer stats.
        """
        a = x
        stats: list[LayerStats] = []
        n = len(self.mapped)
        keys = (
            jax.random.split(noise_key, n) if noise_key is not None else [None] * n
        )
        for idx, (mapped, plan) in enumerate(zip(self.mapped, self.plans)):
            out = imac_linear(
                mapped,
                plan,
                a,
                self.cfg,
                is_output=(idx == n - 1),
                solve_options=solve_options,
                noise_key=keys[idx],
            )
            a = out.activations
            stats.append(out.stats)
        return a, stats

    def total_power(self, stats: "list[LayerStats]") -> jax.Array:
        """Mean-over-batch total circuit power (W)."""
        return sum(jnp.mean(s.power) for s in stats)

    def total_latency(self, stats: "list[LayerStats]") -> jax.Array:
        """End-to-end settling latency + sampling time (s)."""
        return sum(s.latency for s in stats) + self.cfg.t_sampling
