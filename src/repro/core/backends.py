"""Named solver-backend registry for the crossbar circuit solve.

The block Gauss–Seidel solve in `repro.core.solver` has two pluggable
levels:

  * the *inner* batched tridiagonal (Thomas) solve — one call per
    half-sweep, materializing the half-sweep voltages between calls
    (`"scan"` = pure lax.scan, `"pallas"` = the Pallas Thomas tile in
    `repro.kernels.tridiag`);
  * the *whole sweep loop* — one fused Pallas kernel that keeps a lane
    block of systems resident in VMEM and iterates
    row-tridiag → transpose → col-tridiag → SOR → residual on-chip
    (`"fused"` = `repro.kernels.gs_fused`), never touching HBM between
    half-sweeps.

Backends are selected by name through `solver.SolveOptions` (or the
``REPRO_SOLVER_BACKEND`` environment variable for a process-wide
default); custom inner solvers register with `register_backend` or are
passed directly as a callable. Off-TPU, Pallas-backed entries fall back
to interpret mode automatically with a single logged notice, so CI and
CPU differential tests exercise the exact kernel code paths.

This module deliberately imports the kernel packages lazily: importing
`repro.core.solver` must stay cheap and cycle-free.
"""
from __future__ import annotations

import dataclasses
import logging
import os
from typing import Callable, Optional

import jax

from repro import obs

logger = logging.getLogger(__name__)

ENV_BACKEND = "REPRO_SOLVER_BACKEND"

#: Batched tridiagonal solve along the last axis: (dl, d, du, b) -> x,
#: with dl[..., 0] and du[..., -1] ignored.
TridiagFn = Callable[[jax.Array, jax.Array, jax.Array, jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class SolverBackend:
    """One registered way to run the crossbar solve.

    Exactly one of the two factories is set:

    Attributes:
      name: registry key (reported in logs and benchmarks).
      make_tridiag: factory returning the inner TridiagFn used by the
        generic sweep loop in `solver.solve_crossbar`. Receives the
        resolved `SolveOptions` (for e.g. the interpret flag).
      make_solve: factory returning a *full-solve* function
        ``(g, v_in, cp, stamps) -> CrossbarSolution`` that replaces the
        sweep loop entirely (the fused kernel).
    """

    name: str
    make_tridiag: Optional[Callable] = None
    make_solve: Optional[Callable] = None


_REGISTRY: "dict[str, SolverBackend]" = {}


def register_backend(backend: SolverBackend) -> SolverBackend:
    """Register (or replace) a named solver backend."""
    _REGISTRY[backend.name] = backend
    return backend


def available_backends() -> "tuple[str, ...]":
    return tuple(sorted(_REGISTRY))


def default_backend_name() -> str:
    """Process-wide default backend: $REPRO_SOLVER_BACKEND or 'scan'."""
    return os.environ.get(ENV_BACKEND, "scan")


def get_backend(spec) -> SolverBackend:
    """Resolve a backend spec: name, SolverBackend, TridiagFn, or None.

    None resolves to `default_backend_name()`. A bare callable is
    wrapped as an anonymous inner-tridiag backend (the supported form of
    the old raw ``tridiag=`` argument).
    """
    if spec is None:
        spec = default_backend_name()
    if isinstance(spec, SolverBackend):
        return spec
    if callable(spec):
        fn = spec
        return SolverBackend(
            name=getattr(fn, "__name__", "custom"),
            make_tridiag=lambda options: fn,
        )
    try:
        return _REGISTRY[spec]
    except KeyError:
        raise KeyError(
            f"unknown solver backend {spec!r}; available: "
            f"{', '.join(available_backends())}"
        ) from None


# ---------------------------------------------------------------------------
# Interpret-mode resolution (Pallas off-TPU fallback).
# ---------------------------------------------------------------------------

_interpret_notice_emitted = False


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: "bool | None") -> bool:
    """Resolve a Pallas interpret flag; None = auto (interpret off-TPU).

    The automatic fallback logs a single per-process notice so CPU runs
    make clear they exercise the kernel in interpret mode (numerically
    identical, not representative of TPU speed).
    """
    global _interpret_notice_emitted
    if interpret is not None:
        return interpret
    if on_tpu():
        return False
    if not _interpret_notice_emitted:
        _interpret_notice_emitted = True
        # Interpret mode is a process-level condition (the device does
        # not change underneath a run), so the structured event fires
        # once per process, mirroring the logger notice it supersedes
        # for observability (backend_fallback_total{cause=...}).
        obs.event(
            "backend_fallback",
            cause="interpret_mode",
            jax_backend=jax.default_backend(),
        )
        logger.warning(
            "Pallas solver backend: no TPU detected (jax backend=%s); "
            "running kernels in interpret mode. Results are identical "
            "but timings are not representative.",
            jax.default_backend(),
        )
    return True


# ---------------------------------------------------------------------------
# Built-in backends. Kernel imports are lazy (inside the factories).
# ---------------------------------------------------------------------------


def _make_scan_tridiag(options):
    from repro.core.solver import tridiag_scan

    return tridiag_scan


def _make_pallas_tridiag(options):
    from repro.kernels.tridiag.ops import tridiag

    interpret = resolve_interpret(getattr(options, "interpret", None))

    def pallas_tridiag(dl, d, du, b):
        return tridiag(dl, d, du, b, interpret=interpret)

    return pallas_tridiag


def _make_fused_solve(options):
    from repro.kernels.gs_fused.ops import fused_solve

    interpret = resolve_interpret(getattr(options, "interpret", None))

    def solve(g, v_in, cp, stamps):
        return fused_solve(g, v_in, cp, stamps, interpret=interpret)

    return solve


register_backend(SolverBackend(name="scan", make_tridiag=_make_scan_tridiag))
register_backend(SolverBackend(name="pallas", make_tridiag=_make_pallas_tridiag))
register_backend(SolverBackend(name="fused", make_solve=_make_fused_solve))
