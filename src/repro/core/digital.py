"""Digital reference MLP — the "trained weights and biases" input to
IMAC-Sim (Algorithm 1). Self-contained (tiny Adam) so the core library
does not depend on the large-model substrate.

The paper's workload is a 400x120x84x10 sigmoid MLP on MNIST; the
container is offline, so `repro.data.digits` provides a deterministic
synthetic 20x20 digit-prototype dataset (see DESIGN.md §9).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = List[Tuple[jax.Array, jax.Array]]


def init_mlp(key: jax.Array, topology: Sequence[int]) -> Params:
    """Glorot-initialised MLP params [(W, b), ...]; W: (fan_in, fan_out)."""
    params = []
    for i in range(len(topology) - 1):
        key, sub = jax.random.split(key)
        fan_in, fan_out = topology[i], topology[i + 1]
        scale = jnp.sqrt(2.0 / (fan_in + fan_out))
        w = scale * jax.random.normal(sub, (fan_in, fan_out), jnp.float32)
        params.append((w, jnp.zeros((fan_out,), jnp.float32)))
    return params


def mlp_forward(params: Params, x: jax.Array, activation: str = "sigmoid") -> jax.Array:
    """Digital forward; sigmoid hidden layers, linear readout (matches the
    analog circuit's diff-amp readout)."""
    act = {"sigmoid": jax.nn.sigmoid, "tanh": jnp.tanh,
           "relu": jax.nn.relu}[activation]
    a = x
    for i, (w, b) in enumerate(params):
        z = a @ w + b
        a = z if i == len(params) - 1 else act(z)
    return a


def train_mlp(
    key: jax.Array,
    topology: Sequence[int],
    x: jax.Array,
    y: jax.Array,
    *,
    steps: int = 300,
    batch_size: int = 128,
    lr: float = 3e-3,
    activation: str = "sigmoid",
) -> Params:
    """Train with Adam + softmax-CE. Returns trained params."""
    params = init_mlp(key, topology)
    flat, tree = jax.tree_util.tree_flatten(params)
    m = [jnp.zeros_like(p) for p in flat]
    v = [jnp.zeros_like(p) for p in flat]

    def loss_fn(params, xb, yb):
        logits = mlp_forward(params, xb, activation)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], axis=1))

    @jax.jit
    def step(carry, idx):
        flat, m, v, t = carry
        params = jax.tree_util.tree_unflatten(tree, flat)
        xb, yb = x[idx], y[idx]
        grads = jax.grad(loss_fn)(params, xb, yb)
        gflat = jax.tree_util.tree_flatten(grads)[0]
        t = t + 1
        b1, b2, eps = 0.9, 0.999, 1e-8
        new_flat, new_m, new_v = [], [], []
        for p, g, mi, vi in zip(flat, gflat, m, v):
            mi = b1 * mi + (1 - b1) * g
            vi = b2 * vi + (1 - b2) * g * g
            mhat = mi / (1 - b1**t)
            vhat = vi / (1 - b2**t)
            new_flat.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
            new_m.append(mi)
            new_v.append(vi)
        return (new_flat, new_m, new_v, t), None

    n = x.shape[0]
    key_idx = jax.random.PRNGKey(17)
    idxs = jax.random.randint(key_idx, (steps, batch_size), 0, n)
    carry = (flat, m, v, jnp.zeros((), jnp.int32))
    carry, _ = jax.lax.scan(step, carry, idxs)
    return jax.tree_util.tree_unflatten(tree, carry[0])


def accuracy(params: Params, x: jax.Array, y: jax.Array, activation: str = "sigmoid") -> float:
    pred = jnp.argmax(mlp_forward(params, x, activation), axis=-1)
    return float(jnp.mean((pred == y).astype(jnp.float32)))
