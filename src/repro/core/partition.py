"""Horizontal / vertical partitioning of IMAC layers (H_P, V_P).

IMAC-Sim's mapLayer splits each layer's crossbar into hp_j x vp_j
subarrays: horizontal partitions divide the *input* (row) dimension,
vertical partitions divide the *output* (column) dimension. Partitioning
shortens the resistive row/column lines, trading interface-circuit power
for IR-drop accuracy (paper Table III).

`auto_partition` reproduces the paper's Table III arithmetic exactly:
hp = ceil((fan_in + 1) / rows), vp = ceil(fan_out / cols) — the +1 is the
bias row, which IMAC-Sim folds into the first layer rows (the published
H_P=[13,4,3] etc. for the 400x120x84x10 MLP on 32x32 arrays follow).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Tiling of one (fan_in+1, fan_out) conductance matrix.

    Attributes:
      hp: number of horizontal partitions (row splits).
      vp: number of vertical partitions (column splits).
      rows: padded rows per tile.
      cols: padded cols per tile.
      total_rows: fan_in + 1 (bias row included).
      total_cols: fan_out.
    """

    hp: int
    vp: int
    rows: int
    cols: int
    total_rows: int
    total_cols: int

    @property
    def n_tiles(self) -> int:
        return self.hp * self.vp

    @property
    def row_pad(self) -> int:
        return self.hp * self.rows - self.total_rows

    @property
    def col_pad(self) -> int:
        return self.vp * self.cols - self.total_cols


def auto_partition(fan_in: int, fan_out: int, array_rows: int, array_cols: int) -> "tuple[int, int]":
    """Paper Table III: minimum partitions for a given subarray size."""
    hp = math.ceil((fan_in + 1) / array_rows)
    vp = math.ceil(fan_out / array_cols)
    return hp, vp


def plan_partition(
    fan_in: int,
    fan_out: int,
    hp: int,
    vp: int,
) -> PartitionPlan:
    """Plan tiling with user-specified (hp, vp), as IMAC-Sim allows any."""
    total_rows = fan_in + 1
    if hp < 1 or vp < 1:
        raise ValueError(f"partitions must be >= 1, got hp={hp} vp={vp}")
    if hp > total_rows or vp > fan_out:
        raise ValueError(
            f"more partitions than rows/cols: hp={hp} rows={total_rows}, "
            f"vp={vp} cols={fan_out}"
        )
    rows = math.ceil(total_rows / hp)
    cols = math.ceil(fan_out / vp)
    return PartitionPlan(
        hp=hp, vp=vp, rows=rows, cols=cols,
        total_rows=total_rows, total_cols=fan_out,
    )


def tile_matrix(g: jnp.ndarray, plan: PartitionPlan, fill: float = 0.0) -> jnp.ndarray:
    """Split (..., total_rows, total_cols) into (..., hp*vp, rows, cols)
    padded tiles; leading axes (e.g. a stacked-config axis) pass through.

    Padding cells get conductance `fill` (an absent/unprogrammed device;
    0 S = no device bridging the wires, the wire grid itself remains).
    """
    if g.shape[-2:] != (plan.total_rows, plan.total_cols):
        raise ValueError(f"matrix {g.shape} != plan {(plan.total_rows, plan.total_cols)}")
    lead = g.shape[:-2]
    pad = [(0, 0)] * len(lead) + [(0, plan.row_pad), (0, plan.col_pad)]
    padded = jnp.pad(g, pad, constant_values=fill)
    tiles = padded.reshape(*lead, plan.hp, plan.rows, plan.vp, plan.cols)
    order = tuple(range(len(lead)))
    tiles = tiles.transpose(
        *order, len(lead), len(lead) + 2, len(lead) + 1, len(lead) + 3
    )
    return tiles.reshape(*lead, plan.n_tiles, plan.rows, plan.cols)


def untile_matrix(tiles: jnp.ndarray, plan: PartitionPlan) -> jnp.ndarray:
    """Inverse of tile_matrix (drops padding)."""
    t = tiles.reshape(plan.hp, plan.vp, plan.rows, plan.cols)
    t = t.transpose(0, 2, 1, 3).reshape(plan.hp * plan.rows, plan.vp * plan.cols)
    return t[: plan.total_rows, : plan.total_cols]


def tile_inputs(v: jnp.ndarray, plan: PartitionPlan) -> jnp.ndarray:
    """Split (..., total_rows) input voltages into (..., hp, rows)."""
    if v.shape[-1] != plan.total_rows:
        raise ValueError(f"inputs {v.shape} != total_rows {plan.total_rows}")
    pad = plan.hp * plan.rows - plan.total_rows
    v = jnp.pad(v, [(0, 0)] * (v.ndim - 1) + [(0, pad)])
    return v.reshape(*v.shape[:-1], plan.hp, plan.rows)


def combine_outputs(per_tile: jnp.ndarray, plan: PartitionPlan) -> jnp.ndarray:
    """Sum partial output currents over horizontal partitions.

    Args:
      per_tile: (..., hp*vp, cols) per-tile column currents.

    Returns:
      (..., total_cols) combined currents (padding columns dropped).
    """
    t = per_tile.reshape(*per_tile.shape[:-2], plan.hp, plan.vp, plan.cols)
    summed = t.sum(axis=-3)  # partial sums over horizontal partitions
    out = summed.reshape(*summed.shape[:-2], plan.vp * plan.cols)
    return out[..., : plan.total_cols]


def plan_topology(
    topology: Sequence[int],
    array_rows: int,
    array_cols: int,
    hp: "Sequence[int] | None" = None,
    vp: "Sequence[int] | None" = None,
) -> "list[PartitionPlan]":
    """Plans for every layer of T_N = [n_0, n_1, ..., n_L].

    If hp/vp are None they are derived from the array size (Table III).
    """
    n_layers = len(topology) - 1
    if hp is None or vp is None:
        auto = [
            auto_partition(topology[i], topology[i + 1], array_rows, array_cols)
            for i in range(n_layers)
        ]
        hp = [a[0] for a in auto]
        vp = [a[1] for a in auto]
    if len(hp) != n_layers or len(vp) != n_layers:
        raise ValueError(
            f"H_P/V_P length mismatch: {len(hp)}/{len(vp)} vs {n_layers} layers"
        )
    return [
        plan_partition(topology[i], topology[i + 1], hp[i], vp[i])
        for i in range(n_layers)
    ]
