"""mapWB — Module 2 of IMAC-Sim (Algorithm 1, line 2).

Maps trained weights/biases to differential conductance pairs
(W+, W-, B+, B-) for a given synaptic technology [R_low, R_high].

Scheme (standard differential mapping used by the IMAC papers):
  w >= 0:  G+ = G_off + (w / w_scale) * (G_on - G_off),   G- = G_off
  w <  0:  G- = G_off + (|w|/ w_scale) * (G_on - G_off),  G+ = G_off
so that (G+ - G-) = w * (G_on - G_off) / w_scale, and the differential
column current is
  I+ - I- = sum_i (G+_ij - G-_ij) V_i = k * (W^T v)   with
  k = (G_on - G_off) / w_scale.

`w_scale` is the per-layer max |w| (biases included), so every layer uses
the full conductance range. The inverse sense factor needed to recover the
digital pre-activation z = W^T a + b from the differential current is
returned as `sense_r` (ohms): z = (I+ - I-) * sense_r / v_unit with inputs
encoded as V = a * v_unit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.devices import DeviceTech


@dataclasses.dataclass
class MappedLayer:
    """Differential conductances for one layer (bias folded as extra row).

    g_pos/g_neg have shape (fan_in + 1, fan_out); row -1 is the bias row,
    driven at `v_unit` volts.
    """

    g_pos: jax.Array
    g_neg: jax.Array
    w_scale: float        # max |w| used for normalisation
    k: float              # (G_on - G_off) / w_scale  [S per unit weight]
    sense_r: float        # ohms; z = I_diff * sense_r / v_unit
    v_unit: float         # volts encoding one unit of activation

    @property
    def fan_in(self) -> int:
        return self.g_pos.shape[0] - 1

    @property
    def fan_out(self) -> int:
        return self.g_pos.shape[1]

    @property
    def g_diff(self) -> jax.Array:
        return self.g_pos - self.g_neg

    def effective_weights(self) -> jax.Array:
        """Recover the (quantised) weight matrix implied by conductances."""
        return self.g_diff / self.k


def map_wb(
    weights: jax.Array,
    biases: jax.Array,
    tech: DeviceTech,
    v_unit: float,
    *,
    quantize: bool = True,
    variation_key: Optional[jax.Array] = None,
    w_scale: Optional[float] = None,
) -> MappedLayer:
    """Map one layer's (fan_in, fan_out) weights + (fan_out,) biases.

    Args:
      weights: (fan_in, fan_out) float weights.
      biases: (fan_out,) float biases.
      tech: synaptic technology defining [G_off, G_on].
      v_unit: input-voltage encoding of one unit of activation (volts).
      quantize: snap to the device's programmable levels.
      variation_key: if given, apply lognormal programming variation.
      w_scale: optional fixed normalisation (default: per-layer max |w|).

    Returns:
      MappedLayer with bias folded in as the last row.
    """
    weights = jnp.asarray(weights)
    biases = jnp.asarray(biases)
    if weights.ndim != 2 or biases.ndim != 1 or biases.shape[0] != weights.shape[1]:
        raise ValueError(
            f"bad shapes: weights {weights.shape}, biases {biases.shape}"
        )
    wb = jnp.concatenate([weights, biases[None, :]], axis=0)
    if w_scale is None:
        w_scale = float(jnp.max(jnp.abs(wb)))
    if w_scale <= 0.0:
        w_scale = 1.0
    wn = wb / w_scale  # in [-1, 1]

    g_pos = tech.g_off + jnp.maximum(wn, 0.0) * tech.g_range
    g_neg = tech.g_off + jnp.maximum(-wn, 0.0) * tech.g_range
    if quantize:
        g_pos = tech.quantize(g_pos)
        g_neg = tech.quantize(g_neg)
    if variation_key is not None:
        kp, kn = jax.random.split(variation_key)
        g_pos = tech.perturb(kp, g_pos)
        g_neg = tech.perturb(kn, g_neg)

    k = tech.g_range / w_scale
    sense_r = 1.0 / (k * 1.0)  # z = I_diff / (k * v_unit) * 1.0; see below
    # With V_i = a_i * v_unit:  I_diff = k * v_unit * z  =>  z = I_diff/(k v_unit)
    return MappedLayer(
        g_pos=g_pos,
        g_neg=g_neg,
        w_scale=w_scale,
        k=k,
        sense_r=sense_r,
        v_unit=v_unit,
    )


def map_network(
    params: "list[tuple[jax.Array, jax.Array]]",
    tech: DeviceTech,
    v_unit: float,
    *,
    quantize: bool = True,
    variation_key: Optional[jax.Array] = None,
) -> "list[MappedLayer]":
    """mapWB over a whole T_N = [L_1 .. L_n] topology."""
    keys = (
        jax.random.split(variation_key, len(params))
        if variation_key is not None
        else [None] * len(params)
    )
    return [
        map_wb(w, b, tech, v_unit, quantize=quantize, variation_key=k)
        for (w, b), k in zip(params, keys)
    ]
