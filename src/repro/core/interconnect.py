"""Interconnect parasitics from physical geometry (Table II of the paper).

IMAC-Sim derives per-segment wire resistance/capacitance from the synapse
bitcell pitch and the interconnect's resistivity / thickness / width. The
paper prints rho = 1.9e9 ohm.m, an obvious exponent typo: a copper-like
back-end-of-line interconnect is ~1.9e-8 ohm.m, which with the printed
geometry gives ~13.8 ohm per bitcell segment — consistent with the IR-drop
results of the paper and of its ref [2], whereas the literal value would
make a single segment ~1e17 ohm and no current would flow at all. We use
1.9e-8; tests/test_substrate.py pins r_segment ~= 13.8 ohm so the
correction cannot silently regress.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Interconnect:
    """Wire parasitics for one metal layer of the crossbar.

    Attributes:
      resistivity: ohm·m.
      thickness: wire thickness (m).
      width: wire width (m).
      pitch: bitcell pitch = segment length between adjacent cells (m).
      cap_per_m: wire capacitance per meter (F/m), for latency estimates.
    """

    resistivity: float = 1.9e-8
    thickness: float = 22e-9
    width: float = 36e-9
    pitch: float = 576e-9  # 64 lambda at 14nm FinFET (Table II)
    cap_per_m: float = 2e-10  # ~0.2 fF/um, typical BEOL

    @property
    def r_segment(self) -> float:
        """Resistance of one bitcell-pitch wire segment (ohms)."""
        return self.resistivity * self.pitch / (self.width * self.thickness)

    @property
    def c_segment(self) -> float:
        """Capacitance of one bitcell-pitch wire segment (farads)."""
        return self.cap_per_m * self.pitch

    def line_resistance(self, n_segments: int) -> float:
        return self.r_segment * n_segments

    def elmore_delay(self, n_segments: int) -> float:
        """Elmore delay of a distributed RC line of n segments (seconds)."""
        r, c = self.r_segment, self.c_segment
        # sum_{k=1..n} (k * r) * c  = r c n(n+1)/2
        return 0.5 * r * c * n_segments * (n_segments + 1)


# Paper Table II defaults (14nm FinFET node).
DEFAULT_INTERCONNECT = Interconnect()


def scaled_interconnect(scale: float, base: Interconnect = DEFAULT_INTERCONNECT) -> Interconnect:
    """Scale wire cross-section (e.g. older/newer node); R ∝ 1/scale²."""
    return dataclasses.replace(
        base,
        thickness=base.thickness * scale,
        width=base.width * scale,
        pitch=base.pitch * scale,
    )
