"""SPICE netlist generation — faithful mapLayer / mapIMAC (Modules 3-4).

IMAC-Sim's defining artifact is the generated SPICE netlist. We keep it:
`map_layer` emits one subcircuit per layer (per-tile resistor grids with
interconnect parasitics, differential memristor pairs, drivers, TIAs and
behavioural neuron sources), and `map_imac` concatenates the layer files
into the main circuit file, exactly as Algorithm 1 describes.

Generation goes through the `repro.spice` Circuit IR: `layer_circuit` /
`imac_circuits` build cards, and the text files are printed by the same
canonical emitter that re-prints parsed netlists — which is what makes
``emit -> parse -> emit`` byte-stable. `repro.spice.lower` turns parsed
netlists (ours or third-party) back into solver structures; the legacy
regex helpers below (`parse_tile_conductances`, ...) remain for
lightweight single-file round trips.
"""
from __future__ import annotations

import re
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence

import numpy as np

from repro.core.imac import IMACConfig
from repro.core.mapping import MappedLayer
from repro.core.partition import PartitionPlan, tile_matrix
from repro.spice.emitter import emit, fmt as _fmt
from repro.spice.ir import (
    BehavioralSource,
    Capacitor,
    Card,
    Circuit,
    Comment,
    Directive,
    Instance,
    Resistor,
    Subckt,
    VSource,
)

if TYPE_CHECKING:  # pragma: no cover - annotation only, avoids a cycle
    from repro.transient.spec import TransientSpec


def layer_circuit(
    layer_idx: int,
    mapped: MappedLayer,
    plan: PartitionPlan,
    cfg: IMACConfig,
    transient: "Optional[TransientSpec]" = None,
) -> Circuit:
    """Module 3 as IR: one layer's subcircuit file as a `Circuit`.

    Nodes:
      in_<i>       — layer input voltages (i in [0, fan_in]; last = bias).
      out_<j>      — neuron outputs.
      t<t>_r_<i>_<j> / t<t>_cp_<i>_<j> / t<t>_cn_<i>_<j> — tile-internal
        row nodes and positive/negative column nodes.

    With a `transient` spec (defaults to `cfg.transient`) the subcircuit
    additionally states the periphery capacitances the time-domain
    integrator assembles: driver output caps on row-head nodes, TIA
    input caps on column-foot nodes.
    """
    transient = transient if transient is not None else cfg.transient
    tech = cfg.resolved_tech()
    neuron = cfg.resolved_neuron()
    r_seg = cfg.interconnect.r_segment
    c_seg = cfg.interconnect.c_segment
    gp = np.asarray(tile_matrix(mapped.g_pos, plan))
    gn = np.asarray(tile_matrix(mapped.g_neg, plan))
    rows, cols = plan.rows, plan.cols

    body: List[Card] = []
    for t in range(plan.n_tiles):
        h, vcol = divmod(t, plan.vp)
        body.append(Comment(f" tile {t} (h={h}, v={vcol}) differential pair"))
        for i in range(rows):
            gi = h * rows + i
            in_node = f"in_{gi}" if gi < plan.total_rows else "0"
            # Driver source resistance into the row head (shared by the
            # differential pair arrays -> emitted per polarity).
            for pol in ("p", "n"):
                body.append(Resistor(
                    f"Rsrc_{t}{pol}_{i}", in_node, f"t{t}_{pol}r_{i}_0",
                    cfg.r_source,
                ))
                if transient is not None:
                    body.append(Capacitor(
                        f"Cdrv_{t}{pol}_{i}", f"t{t}_{pol}r_{i}_0", "0",
                        transient.c_driver,
                    ))
                for j in range(cols):
                    node = f"t{t}_{pol}r_{i}_{j}"
                    if j + 1 < cols:
                        body.append(Resistor(
                            f"Rrw_{t}{pol}_{i}_{j}", node,
                            f"t{t}_{pol}r_{i}_{j+1}", r_seg,
                        ))
                    body.append(Capacitor(
                        f"Crw_{t}{pol}_{i}_{j}", node, "0", c_seg,
                    ))
                    g = gp[t, i, j] if pol == "p" else gn[t, i, j]
                    if g > 0.0:
                        body.append(Resistor(
                            f"Rmem_{t}{pol}_{i}_{j}", node,
                            f"t{t}_c{pol}_{i}_{j}", 1.0 / g,
                        ))
        for pol in ("p", "n"):
            for j in range(cols):
                for i in range(rows):
                    node = f"t{t}_c{pol}_{i}_{j}"
                    if i + 1 < rows:
                        body.append(Resistor(
                            f"Rcw_{t}{pol}_{i}_{j}", node,
                            f"t{t}_c{pol}_{i+1}_{j}", r_seg,
                        ))
                    body.append(Capacitor(
                        f"Ccw_{t}{pol}_{i}_{j}", node, "0", c_seg,
                    ))
                # TIA virtual ground at the column foot; the 0V source
                # senses the column current (standard SPICE idiom).
                if transient is not None:
                    body.append(Capacitor(
                        f"Ctia_{t}{pol}_{j}", f"t{t}_c{pol}_{rows-1}_{j}",
                        "0", transient.c_tia,
                    ))
                body.append(Resistor(
                    f"Rtia_{t}{pol}_{j}", f"t{t}_c{pol}_{rows-1}_{j}",
                    f"t{t}_s{pol}_{j}", cfg.r_tia,
                ))
                body.append(VSource(
                    f"Vsense_{t}{pol}_{j}", f"t{t}_s{pol}_{j}", "0", dc=0.0,
                ))

    # Differential amp + neuron per logical output column: behavioural
    # E-source summing the sensed partial currents of all horizontal
    # partitions (I = I(Vsense)).
    sense_r = mapped.sense_r
    for j in range(plan.total_cols):
        vcol, jj = divmod(j, cols)
        terms = []
        for h in range(plan.hp):
            t = h * plan.vp + vcol
            terms.append(f"(I(Vsense_{t}p_{jj})-I(Vsense_{t}n_{jj}))")
        isum = "+".join(terms)
        zexpr = f"({isum})*{_fmt(sense_r / mapped.v_unit * neuron.z_volt)}"
        if neuron.kind == "sigmoid":
            fexpr = f"{_fmt(neuron.vdd)}/(1+exp(-({zexpr})/{_fmt(neuron.z_volt)}))"
        elif neuron.kind == "tanh":
            fexpr = f"{_fmt(neuron.vdd)}*tanh(({zexpr})/{_fmt(neuron.z_volt)})"
        elif neuron.kind == "relu":
            fexpr = f"max(0,({zexpr}))"
        else:  # linear readout
            fexpr = zexpr
        body.append(BehavioralSource(f"Eneur_{j}", f"out_{j}", "0", fexpr))

    ins = tuple(f"in_{i}" for i in range(plan.total_rows))
    outs = tuple(f"out_{j}" for j in range(plan.total_cols))
    cards: List[Card] = [
        Comment(
            f" Layer {layer_idx}: {plan.total_rows - 1}x{plan.total_cols} "
            f"(+bias row), HP={plan.hp} VP={plan.vp}, tiles {rows}x{cols}"
        ),
        Comment(
            f" tech={tech.name} R_low={_fmt(tech.r_low)} "
            f"R_high={_fmt(tech.r_high)}"
        ),
        Subckt(name=f"layer{layer_idx}", ports=ins + outs, cards=tuple(body)),
    ]
    return Circuit(cards=tuple(cards))


def map_layer(
    layer_idx: int,
    mapped: MappedLayer,
    plan: PartitionPlan,
    cfg: IMACConfig,
    transient: "Optional[TransientSpec]" = None,
) -> str:
    """Module 3: one layer's SPICE subcircuit text (see `layer_circuit`)."""
    return emit(layer_circuit(layer_idx, mapped, plan, cfg, transient=transient))


def imac_circuits(
    mapped_layers: Sequence[MappedLayer],
    plans: Sequence[PartitionPlan],
    cfg: IMACConfig,
    sample: "np.ndarray | None" = None,
    transient: "Optional[TransientSpec]" = None,
) -> Dict[str, Circuit]:
    """Module 4 as IR: {filename: Circuit} for the whole network."""
    transient = transient if transient is not None else cfg.transient
    files: Dict[str, Circuit] = {}
    cards: List[Card] = [
        Comment(" IMAC-Sim-JAX generated netlist"),
        Directive("OPTION", ("POST",)),
        Comment(
            f" topology: {[p.total_rows - 1 for p in plans]} -> "
            f"{plans[-1].total_cols}"
        ),
    ]
    if transient is not None:
        method = "TRAP" if transient.method == "trap" else "GEAR"
        cards.append(Directive("OPTION", (f"METHOD={method}",)))
    for idx, (mapped, plan) in enumerate(zip(mapped_layers, plans)):
        fname = f"layer{idx}.sp"
        files[fname] = layer_circuit(idx, mapped, plan, cfg, transient=transient)
        cards.append(Directive("INCLUDE", (f"'{fname}'",)))

    cards.append(VSource("VDD", "vdd", "0", dc=cfg.vdd))
    cards.append(VSource("VSS", "vss", "0", dc=cfg.vss))
    n_in = plans[0].total_rows - 1
    t_rise = transient.resolved_t_rise() if transient is not None else 0.0
    for i in range(n_in):
        val = 0.0 if sample is None else float(sample[i]) * mapped_layers[0].v_unit
        if transient is not None:
            # The integrator's drive: v(0) = 0, PWL ramp to the sample
            # value over [0, t_rise], held to the horizon.
            cards.append(VSource(
                f"Vin_{i}", f"x0_{i}", "0",
                pwl=((0.0, 0.0), (t_rise, val), (transient.t_stop, val)),
            ))
        else:
            cards.append(VSource(f"Vin_{i}", f"x0_{i}", "0", dc=val))
    # Bias rows driven at v_unit (ramped like every other drive in a
    # transient analysis — the integrator starts all nodes at 0 V).
    for idx, plan in enumerate(plans):
        vb = mapped_layers[idx].v_unit
        bias_node = f"x{idx}_{plan.total_rows - 1}"
        if transient is not None:
            cards.append(VSource(
                f"Vbias_{idx}", bias_node, "0",
                pwl=((0.0, 0.0), (t_rise, vb), (transient.t_stop, vb)),
            ))
        else:
            cards.append(VSource(f"Vbias_{idx}", bias_node, "0", dc=vb))
    # Chain the layer subcircuits: outputs of layer k are inputs of k+1.
    for idx, plan in enumerate(plans):
        ins = tuple(f"x{idx}_{i}" for i in range(plan.total_rows))
        outs = tuple(f"x{idx + 1}_{j}" for j in range(plan.total_cols))
        cards.append(Instance(f"Xlayer{idx}", ins + outs, f"layer{idx}"))
    cards.append(Directive("OP"))
    if transient is not None:
        cards.append(Directive(
            "TRAN", (_fmt(transient.dt), _fmt(transient.t_stop))
        ))
    else:
        cards.append(Directive("TRAN", ("1n", _fmt(cfg.t_sampling))))
    prints = tuple(
        f"V(x{len(plans)}_{j})" for j in range(plans[-1].total_cols)
    )
    cards.append(Directive("PRINT", ("TRAN",) + prints))
    cards.append(Directive("END"))
    files["imac_main.sp"] = Circuit(cards=tuple(cards))
    return files


def map_imac(
    mapped_layers: Sequence[MappedLayer],
    plans: Sequence[PartitionPlan],
    cfg: IMACConfig,
    sample: "np.ndarray | None" = None,
    transient: "Optional[TransientSpec]" = None,
) -> Dict[str, str]:
    """Module 4: concatenate layer subcircuits into the main IMAC file.

    Returns {filename: contents}; `imac_main.sp` instantiates the layer
    chain, drives the inputs (from `sample` if given) and adds the
    analysis directives.

    With a `transient` spec (defaults to `cfg.transient`) the main file
    states exactly what the batched integrator (repro.transient)
    integrates: PWL input ramps 0 -> v over [0, t_rise], a `.TRAN`
    directive with the integrator's coarse step and horizon, the
    integration method option, and the periphery capacitances in the
    layer subcircuits.
    """
    circuits = imac_circuits(
        mapped_layers, plans, cfg, sample=sample, transient=transient
    )
    return {name: emit(circ) for name, circ in circuits.items()}


_RMEM = re.compile(
    r"^Rmem_(?P<tile>\d+)(?P<pol>[pn])_(?P<i>\d+)_(?P<j>\d+)\s+\S+\s+\S+\s+"
    r"(?P<val>[0-9.eE+-]+)\s*$",
    re.M,
)


def parse_tile_conductances(
    subckt: str, plan: PartitionPlan
) -> "tuple[np.ndarray, np.ndarray]":
    """Round-trip: recover (g_pos, g_neg) tile stacks from a layer netlist.

    Absent memristor lines (padding) parse as 0 S.
    """
    gp = np.zeros((plan.n_tiles, plan.rows, plan.cols))
    gn = np.zeros_like(gp)
    for m in _RMEM.finditer(subckt):
        t, i, j = int(m["tile"]), int(m["i"]), int(m["j"])
        g = 1.0 / float(m["val"])
        if m["pol"] == "p":
            gp[t, i, j] = g
        else:
            gn[t, i, j] = g
    return gp, gn


_TRAN_DIRECTIVE = re.compile(
    r"^\.TRAN\s+(?P<step>[0-9.eE+-]+n?)\s+(?P<stop>[0-9.eE+-]+n?)\s*$",
    re.M | re.I,
)
_PWL_SOURCE = re.compile(
    r"^Vin_(?P<i>\d+)\s+\S+\s+\S+\s+PWL\((?P<pts>[^)]*)\)\s*$", re.M
)
_METHOD_OPT = re.compile(r"^\.OPTION\s+METHOD=(?P<m>\w+)\s*$", re.M | re.I)


def _spice_num(tok: str) -> float:
    """Parse a SPICE number with an optional 'n' (nano) suffix."""
    if tok.lower().endswith("n"):
        return float(tok[:-1]) * 1e-9
    return float(tok)


def parse_transient_directives(main: str) -> Dict[str, object]:
    """Round-trip: recover the transient analysis a main file states.

    Returns {'t_step', 't_stop', 'method', 'pwl'} where `pwl` maps input
    index -> [(t, v), ...] breakpoints; inputs driven by DC sources
    yield an empty pwl dict and method None when absent.
    """
    out: Dict[str, object] = {"t_step": None, "t_stop": None, "method": None}
    m = _TRAN_DIRECTIVE.search(main)
    if m:
        out["t_step"] = _spice_num(m["step"])
        out["t_stop"] = _spice_num(m["stop"])
    m = _METHOD_OPT.search(main)
    if m:
        out["method"] = m["m"].lower()
    pwl: Dict[int, list] = {}
    for m in _PWL_SOURCE.finditer(main):
        toks = m["pts"].split()
        pwl[int(m["i"])] = [
            (_spice_num(toks[k]), _spice_num(toks[k + 1]))
            for k in range(0, len(toks) - 1, 2)
        ]
    out["pwl"] = pwl
    return out


def netlist_stats(files: Dict[str, str]) -> Dict[str, int]:
    """Element counts (for tests and reporting)."""
    text = "".join(files.values())
    return {
        "resistors": len(re.findall(r"^R", text, re.M)),
        "capacitors": len(re.findall(r"^C", text, re.M)),
        "vsources": len(re.findall(r"^V", text, re.M)),
        "esources": len(re.findall(r"^E", text, re.M)),
        "subckts": len(re.findall(r"^\.SUBCKT", text, re.M)),
    }
