"""Memristive device technologies for IMAC crossbars.

Mirrors IMAC-Sim's "Synaptic Technology [R_low, R_high]" hyperparameter
(Table I) and the four technologies of Table IV. A device is programmed to
a conductance G ∈ [G_off, G_on] = [1/R_high, 1/R_low]; real devices have a
finite number of programmable levels and cycle-to-cycle / device-to-device
variation, both modelled here.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DeviceTech:
    """A memristive synaptic technology.

    Attributes:
      name: human-readable technology name.
      r_low: low (ON) resistance in ohms -> G_on = 1/r_low.
      r_high: high (OFF) resistance in ohms -> G_off = 1/r_high.
      levels: number of programmable conductance levels (0 = continuous).
      sigma_rel: relative lognormal programming variation (0 = none).
      read_noise_rel: relative Gaussian read-current noise per access.
    """

    name: str
    r_low: float
    r_high: float
    levels: int = 0
    sigma_rel: float = 0.0
    read_noise_rel: float = 0.0

    @property
    def g_on(self) -> float:
        return 1.0 / self.r_low

    @property
    def g_off(self) -> float:
        return 1.0 / self.r_high

    @property
    def g_range(self) -> float:
        return self.g_on - self.g_off

    @property
    def on_off_ratio(self) -> float:
        return self.r_high / self.r_low

    def quantize(self, g: jax.Array) -> jax.Array:
        """Snap conductances to the device's programmable levels."""
        g = jnp.clip(g, self.g_off, self.g_on)
        if self.levels and self.levels > 1:
            step = self.g_range / (self.levels - 1)
            g = self.g_off + jnp.round((g - self.g_off) / step) * step
        return g

    def perturb(self, key: jax.Array, g: jax.Array) -> jax.Array:
        """Apply lognormal device-to-device programming variation."""
        if self.sigma_rel <= 0.0:
            return g
        noise = jax.random.normal(key, g.shape, dtype=g.dtype)
        return jnp.clip(
            g * jnp.exp(self.sigma_rel * noise), self.g_off, self.g_on
        )

    def perturb_trials(self, keys: jax.Array, g: jax.Array) -> jax.Array:
        """Vectorized `perturb`: one independent draw per stacked trial key.

        `keys` is a (T, ...) stack of PRNG keys; the result is (T, *g.shape)
        with trial t bitwise-identical to ``perturb(keys[t], g)`` — a
        batched Monte-Carlo run therefore reproduces a per-trial loop
        exactly for the same keys.
        """
        keys = jnp.asarray(keys)
        n_trials = keys.shape[0]
        if self.sigma_rel <= 0.0:
            return jnp.broadcast_to(g, (n_trials,) + g.shape)
        return jax.vmap(lambda k: self.perturb(k, g))(keys)


def sample_stuck_faults(
    key: jax.Array,
    shape: "tuple[int, ...]",
    p_stuck_on: float,
    p_stuck_off: float,
) -> "tuple[jax.Array, jax.Array]":
    """Draw disjoint stuck-at fault masks for one device array.

    Each device is independently stuck at G_on with probability
    `p_stuck_on`, stuck at G_off with probability `p_stuck_off`, and
    healthy otherwise (one uniform draw per device, so the two masks are
    disjoint by construction).

    Returns:
      (stuck_on, stuck_off) boolean masks of the given shape.
    """
    if not (0.0 <= p_stuck_on <= 1.0 and 0.0 <= p_stuck_off <= 1.0):
        raise ValueError(
            f"fault rates must be probabilities, got {p_stuck_on}, {p_stuck_off}"
        )
    if p_stuck_on + p_stuck_off > 1.0:
        raise ValueError(
            f"p_stuck_on + p_stuck_off must be <= 1, got "
            f"{p_stuck_on} + {p_stuck_off}"
        )
    u = jax.random.uniform(key, shape)
    stuck_on = u < p_stuck_on
    stuck_off = jnp.logical_and(u >= p_stuck_on, u < p_stuck_on + p_stuck_off)
    return stuck_on, stuck_off


def apply_stuck_faults(
    g: jax.Array,
    stuck_on: jax.Array,
    stuck_off: jax.Array,
    g_on: float,
    g_off: float,
) -> jax.Array:
    """Clamp faulty devices to their stuck conductance level."""
    return jnp.where(stuck_on, g_on, jnp.where(stuck_off, g_off, g))


# Table IV of the paper -----------------------------------------------------
MRAM = DeviceTech("MRAM", r_low=8.5e3, r_high=25.5e3)    # ref [4]
RRAM = DeviceTech("RRAM", r_low=2.5e3, r_high=100e3)     # ref [5]
CBRAM = DeviceTech("CBRAM", r_low=5e3, r_high=1e6)       # ref [6]
PCM = DeviceTech("PCM", r_low=50e3, r_high=1e6)          # ref [7]

TECHNOLOGIES: dict[str, DeviceTech] = {
    t.name: t for t in (MRAM, RRAM, CBRAM, PCM)
}


def get_tech(name_or_tech: "str | DeviceTech") -> DeviceTech:
    if isinstance(name_or_tech, DeviceTech):
        return name_or_tech
    try:
        return TECHNOLOGIES[name_or_tech.upper()]
    except KeyError as e:
        raise KeyError(
            f"unknown device technology {name_or_tech!r}; "
            f"known: {sorted(TECHNOLOGIES)}"
        ) from e


def custom_tech(
    r_low: float,
    r_high: float,
    name: str = "custom",
    levels: int = 0,
    sigma_rel: float = 0.0,
    read_noise_rel: float = 0.0,
) -> DeviceTech:
    if not (0.0 < r_low < r_high):
        raise ValueError(f"need 0 < r_low < r_high, got {r_low}, {r_high}")
    return DeviceTech(name, r_low, r_high, levels, sigma_rel, read_noise_rel)
