"""Behavioural analog neuron models (Table I: "Neuron Circuit Model").

IMAC-Sim's neurons are transistor-level circuits (e.g. the MRAM-based
analog sigmoid of ref [3]); here they are behavioural transfer functions
with the circuit-level artefacts that matter for system accuracy/power:

  * a differential amplifier (gain G_j, Table I) converting the
    differential column current into a voltage of `z_volt` volts per
    digital pre-activation unit,
  * rail clipping at [VSS, VDD] — pre-activations beyond ±VDD/z_volt
    saturate,
  * a per-neuron static power and settling-latency cost model.

The composition is calibrated (see core/imac.py) so that the *ideal*
circuit (no parasitics, continuous conductances) reproduces the digital
network exactly; the circuit non-idealities then degrade it.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class NeuronModel:
    """Behavioural neuron + differential-amp model.

    Attributes:
      kind: 'sigmoid' | 'tanh' | 'relu' | 'linear'.
      vdd / vss: supply rails (volts).
      amp_gain: differential amplifier voltage gain G_j (used by the
        netlist generator and the power model; the end-to-end scale is
        fixed by calibration, so accuracy depends on it only through
        z_volt).
      z_volt: volts per digital pre-activation unit at the amp output.
        The rails then clip |z| at vdd / z_volt (default 0.8/0.1 = 8).
      p_neuron: static power per neuron circuit (watts).
      p_amp: static power per differential amplifier (watts).
      t_settle: settling time per neuron stage (seconds).
    """

    kind: str = "sigmoid"
    vdd: float = 0.8
    vss: float = -0.8
    amp_gain: float = 10.0
    z_volt: float = 0.1
    p_neuron: float = 30e-6   # uW-scale, ref [3]
    p_amp: float = 50e-6
    t_settle: float = 1e-9

    @property
    def z_lim(self) -> float:
        """Largest |pre-activation| representable between the rails."""
        return self.vdd / self.z_volt

    def activation(self, z: jax.Array) -> jax.Array:
        if self.kind == "sigmoid":
            return jax.nn.sigmoid(z)
        if self.kind == "tanh":
            return jnp.tanh(z)
        if self.kind == "relu":
            return jnp.maximum(z, 0.0)
        if self.kind == "linear":
            return z
        raise ValueError(f"unknown neuron kind {self.kind!r}")

    def clip_preactivation(self, z: jax.Array) -> jax.Array:
        return jnp.clip(z, -self.z_lim, self.z_lim)

    def __call__(self, z: jax.Array) -> jax.Array:
        return self.activation(self.clip_preactivation(z))


SIGMOID = NeuronModel(kind="sigmoid")
TANH = NeuronModel(kind="tanh")
RELU = NeuronModel(kind="relu")
LINEAR = NeuronModel(kind="linear")

NEURONS = {"sigmoid": SIGMOID, "tanh": TANH, "relu": RELU, "linear": LINEAR}


def get_neuron(kind_or_model: "str | NeuronModel") -> NeuronModel:
    if isinstance(kind_or_model, NeuronModel):
        return kind_or_model
    try:
        return NEURONS[kind_or_model.lower()]
    except KeyError as e:
        raise KeyError(
            f"unknown neuron {kind_or_model!r}; known: {sorted(NEURONS)}"
        ) from e
