"""IMAC-Sim-JAX core: the paper's contribution as composable JAX modules.

Public API:
  devices.DeviceTech / MRAM / RRAM / CBRAM / PCM
  interconnect.Interconnect
  mapping.map_wb / map_network          (Module 2: mapWB)
  partition.auto_partition / plan_partition / tile_matrix
  solver.solve_crossbar / solve_dense_mna (the "SPICE engine")
  solver.Stamps / SolveOptions           (stamp pytree + backend choice)
  backends.register_backend / available_backends / get_backend
  neurons.NeuronModel
  imac.IMACConfig / IMACNetwork / imac_linear (Modules 3-4)
  netlist.map_layer / map_imac          (SPICE netlist generation)
  evaluate.test_imac / evaluate_batch / evaluate_netlist / sweep
                                        (Module 1: testIMAC)
"""
from repro.core.devices import (
    CBRAM,
    MRAM,
    PCM,
    RRAM,
    TECHNOLOGIES,
    DeviceTech,
    custom_tech,
    get_tech,
)
from repro.core.evaluate import (
    IMACResult,
    evaluate_batch,
    evaluate_netlist,
    structure_key,
    sweep,
    test_imac,
)
from repro.core.imac import (
    IMACConfig,
    IMACNetwork,
    TransientStats,
    imac_linear,
    linear_forward,
)
from repro.core.interconnect import DEFAULT_INTERCONNECT, Interconnect
from repro.core.mapping import MappedLayer, map_network, map_wb
from repro.core.netlist import (
    map_imac,
    map_layer,
    netlist_stats,
    parse_transient_directives,
)
from repro.core.neurons import NeuronModel, get_neuron
from repro.core.partition import PartitionPlan, auto_partition, plan_partition
from repro.core.backends import (
    SolverBackend,
    available_backends,
    default_backend_name,
    get_backend,
    register_backend,
)
from repro.core.solver import (
    CircuitParams,
    SolveOptions,
    Stamps,
    crossbar_power,
    solve_crossbar,
    solve_dense_mna,
    solve_ideal,
)

__all__ = [
    "CBRAM",
    "CircuitParams",
    "DEFAULT_INTERCONNECT",
    "DeviceTech",
    "IMACConfig",
    "IMACNetwork",
    "IMACResult",
    "Interconnect",
    "MRAM",
    "MappedLayer",
    "NeuronModel",
    "PCM",
    "PartitionPlan",
    "RRAM",
    "SolveOptions",
    "SolverBackend",
    "Stamps",
    "TECHNOLOGIES",
    "auto_partition",
    "available_backends",
    "crossbar_power",
    "custom_tech",
    "default_backend_name",
    "evaluate_batch",
    "evaluate_netlist",
    "get_backend",
    "get_neuron",
    "get_tech",
    "imac_linear",
    "linear_forward",
    "map_imac",
    "map_layer",
    "map_network",
    "map_wb",
    "netlist_stats",
    "parse_transient_directives",
    "plan_partition",
    "register_backend",
    "TransientStats",
    "solve_crossbar",
    "solve_dense_mna",
    "solve_ideal",
    "structure_key",
    "sweep",
    "test_imac",
]
