"""IMAC-Sim-JAX core: the paper's contribution as composable JAX modules.

Public API:
  devices.DeviceTech / MRAM / RRAM / CBRAM / PCM
  interconnect.Interconnect
  mapping.map_wb / map_network          (Module 2: mapWB)
  partition.auto_partition / plan_partition / tile_matrix
  solver.solve_crossbar / solve_dense_mna (the "SPICE engine")
  neurons.NeuronModel
  imac.IMACConfig / IMACNetwork / imac_linear (Modules 3-4)
  netlist.map_layer / map_imac          (SPICE netlist generation)
  evaluate.test_imac / sweep            (Module 1: testIMAC)
"""
from repro.core.devices import (  # noqa: F401
    CBRAM,
    MRAM,
    PCM,
    RRAM,
    TECHNOLOGIES,
    DeviceTech,
    custom_tech,
    get_tech,
)
from repro.core.evaluate import IMACResult, sweep, test_imac  # noqa: F401
from repro.core.imac import IMACConfig, IMACNetwork, imac_linear  # noqa: F401
from repro.core.interconnect import DEFAULT_INTERCONNECT, Interconnect  # noqa: F401
from repro.core.mapping import MappedLayer, map_network, map_wb  # noqa: F401
from repro.core.netlist import map_imac, map_layer, netlist_stats  # noqa: F401
from repro.core.neurons import NeuronModel, get_neuron  # noqa: F401
from repro.core.partition import PartitionPlan, auto_partition, plan_partition  # noqa: F401
from repro.core.solver import (  # noqa: F401
    CircuitParams,
    crossbar_power,
    solve_crossbar,
    solve_dense_mna,
    solve_ideal,
)
