"""testIMAC — Module 1 / Algorithm 1: deploy a trained DNN on an IMAC
configuration and report error rate, average power and latency.

The paper loops SPICE once per test sample (Algorithm 1 line 3); here the
whole test set is one batched, jitted circuit solve — same semantics,
TPU-native execution. Chunking keeps peak memory bounded for large
N_S x tiles products.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.digital import Params, mlp_forward
from repro.core.imac import IMACConfig, IMACNetwork


class IMACResult(NamedTuple):
    accuracy: float
    error_rate: float
    avg_power: float          # W, averaged over samples (paper's P_average)
    latency: float            # s, settling + sampling estimate
    digital_accuracy: float   # reference accuracy of the float model
    per_layer_power: tuple    # W per layer (batch mean)
    worst_residual: float     # solver convergence check
    n_samples: int
    hp: tuple
    vp: tuple


def test_imac(
    params: Params,
    x: jax.Array,
    y: jax.Array,
    cfg: IMACConfig,
    *,
    n_samples: Optional[int] = None,
    chunk: int = 256,
    variation_key: Optional[jax.Array] = None,
    noise_key: Optional[jax.Array] = None,
    activation: str = "sigmoid",
) -> IMACResult:
    """Evaluate the IMAC deployment of `params` on (x, y).

    Args:
      params: trained digital weights/biases [(W, b), ...].
      x: (N, fan_in) inputs in [0, 1] digital units.
      y: (N,) integer labels.
      cfg: IMAC hyperparameters (Table I).
      n_samples: N_S — number of test samples (default: all).
      chunk: samples per jitted circuit solve.
      variation_key: optional device-variation Monte-Carlo draw.
      noise_key: optional read-noise draw.

    Returns:
      IMACResult with accuracy/power/latency (Algorithm 1 lines 21-22).
    """
    n = n_samples or x.shape[0]
    x, y = x[:n], y[:n]
    net = IMACNetwork(params, cfg, variation_key=variation_key)

    @jax.jit
    def run_chunk(xb, key):
        out, stats = net(xb, noise_key=key)
        pred = jnp.argmax(out, axis=-1)
        return (
            pred,
            jnp.stack([jnp.mean(s.power) for s in stats]),
            jnp.stack([s.residual for s in stats]),
        )

    preds, powers, residuals = [], [], []
    n_chunks = (n + chunk - 1) // chunk
    keys = (
        jax.random.split(noise_key, n_chunks)
        if noise_key is not None
        else [None] * n_chunks
    )
    for ci in range(n_chunks):
        xb = x[ci * chunk : (ci + 1) * chunk]
        pred, pwr, res = run_chunk(xb, keys[ci])
        preds.append(pred)
        powers.append(pwr * xb.shape[0])  # weight by chunk size
        residuals.append(res)
    pred = jnp.concatenate(preds)
    per_layer_power = jnp.sum(jnp.stack(powers), axis=0) / n
    worst_res = float(jnp.max(jnp.stack(residuals)))

    errors = int(jnp.sum((pred != y).astype(jnp.int32)))
    acc = 1.0 - errors / n
    # Latency is input-independent (structural): take from one forward.
    _, stats = net(x[:1])
    latency = float(net.total_latency(stats))

    dig_pred = jnp.argmax(mlp_forward(params, x, activation), axis=-1)
    dig_acc = float(jnp.mean((dig_pred == y).astype(jnp.float32)))

    return IMACResult(
        accuracy=acc,
        error_rate=errors / n,
        avg_power=float(jnp.sum(per_layer_power)),
        latency=latency,
        digital_accuracy=dig_acc,
        per_layer_power=tuple(float(p) for p in per_layer_power),
        worst_residual=worst_res,
        n_samples=n,
        hp=tuple(net.hp),
        vp=tuple(net.vp),
    )


def sweep(
    params: Params,
    x: jax.Array,
    y: jax.Array,
    cfgs: "Sequence[tuple[str, IMACConfig]]",
    **kw,
) -> "list[tuple[str, IMACResult]]":
    """Design-space sweep: evaluate many IMAC configurations (the paper's
    Tables III/IV are sweeps over partitioning / device technology)."""
    return [(name, test_imac(params, x, y, cfg, **kw)) for name, cfg in cfgs]
