"""testIMAC — Module 1 / Algorithm 1: deploy a trained DNN on an IMAC
configuration and report error rate, average power and latency.

The paper loops SPICE once per test sample (Algorithm 1 line 3); here the
whole test set is one batched, jitted circuit solve — same semantics,
TPU-native execution. Chunking keeps peak memory bounded for large
N_S x tiles products.

`evaluate_batch` is the functional core: it evaluates a *batch of
structurally-compatible configurations* in a single vmapped circuit solve
by stacking each configuration's conductance matrices and electrical
scalars along a leading axis. The single-config path (`test_imac`) and the
design-space engine (repro.explore) both go through it; the engine groups
arbitrary configuration lists into compatible batches via `structure_key`.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.obs import prof as obs_prof
from repro.core.digital import Params, mlp_forward
from repro.core.imac import IMACConfig, build_plans, layer_latency, linear_forward
from repro.core.mapping import MappedLayer, map_network
from repro.core.solver import CircuitParams, SolveOptions, suggest_iters
from repro.distributed.compat import shard_map_compat
from repro.distributed.sweep import (
    MeshPlan,
    pad_count,
    pad_stacked,
    stacked_spec,
)


class IMACResult(NamedTuple):
    accuracy: float
    error_rate: float
    avg_power: float          # W, averaged over samples (paper's P_average)
    latency: float            # s, settling + sampling (waveform-measured
                              # when cfg.transient is set, else analytic)
    digital_accuracy: float   # reference accuracy of the float model
    per_layer_power: tuple    # W per layer (batch mean)
    worst_residual: float     # solver convergence check
    n_samples: int
    hp: tuple
    vp: tuple
    # Energy per inference (J). Waveform-integrated over the transient
    # horizon when cfg.transient is set; otherwise the avg_power x
    # latency estimate, so energy-aware Pareto objectives
    # (explore.pareto.TRANSIENT_OBJECTIVES) work on mixed sweeps.
    energy: float = 0.0
    # The input-independent Elmore estimate, always reported — the
    # crossvalidation path compares it against the measured latency.
    latency_analytic: float = 0.0
    latency_source: str = "analytic"   # 'analytic' | 'transient'
    settled: bool = True               # waveform in band at the horizon

    # Degenerate-distribution aliases: a deterministic evaluation is a
    # single-trial Monte-Carlo run (every accuracy quantile collapses to
    # the point value, worst-case power is the only power). These mirror
    # repro.variability.ReliabilityReport's fields so mixed
    # deterministic + Monte-Carlo sweeps share Pareto objectives
    # (explore.pareto.RELIABILITY_OBJECTIVES) and report code.
    @property
    def n_trials(self) -> int:
        return 1

    @property
    def acc_mean(self) -> float:
        return self.accuracy

    @property
    def acc_std(self) -> float:
        return 0.0

    @property
    def acc_min(self) -> float:
        return self.accuracy

    @property
    def acc_max(self) -> float:
        return self.accuracy

    @property
    def acc_q05(self) -> float:
        return self.accuracy

    @property
    def acc_q25(self) -> float:
        return self.accuracy

    @property
    def acc_q50(self) -> float:
        return self.accuracy

    @property
    def acc_q75(self) -> float:
        return self.accuracy

    @property
    def acc_q95(self) -> float:
        return self.accuracy

    @property
    def power_mean(self) -> float:
        return self.avg_power

    @property
    def power_worst(self) -> float:
        return self.avg_power


def structure_key(topology: Sequence[int], cfg: IMACConfig) -> tuple:
    """Hashable key of everything that shapes the traced computation.

    Two configurations with equal keys differ only in *numeric leaves*
    (device conductances, wire/periphery resistances, SOR factor, read
    noise) and can therefore share one compiled, vmapped solve — the
    leaves are stacked along a leading config axis. Everything that
    changes array shapes (partition plans), loop bounds (`gs_iters`,
    `gs_tol`) or traced-code structure (parasitics, neuron model) must
    match.
    """
    plans = build_plans(topology, cfg)
    iters = tuple(
        cfg.gs_iters or suggest_iters(p.rows, p.cols) for p in plans
    )
    return (
        tuple(topology),
        tuple((p.hp, p.vp, p.rows, p.cols) for p in plans),
        bool(cfg.parasitics),
        iters,
        float(cfg.gs_tol),
        cfg.resolved_neuron(),
        jnp.dtype(cfg.dtype).name,
        # The transient spec shapes the traced scan (step count, method,
        # GS budget, horizon...), so configurations only batch together
        # when they request the same one (or none).
        cfg.transient,
    )


def lift_mapped(mapped: "Sequence[MappedLayer]") -> "list[MappedLayer]":
    """One configuration's mapping as a leading-axis-1 stacked mapping
    (the `mapped_stacked` form of `evaluate_batch`)."""
    return [
        dataclasses.replace(
            m,
            g_pos=m.g_pos[None],
            g_neg=m.g_neg[None],
            k=jnp.asarray([m.k]),
        )
        for m in mapped
    ]


def concat_mapped(
    stacks: "Sequence[Sequence[MappedLayer]]",
) -> "list[MappedLayer]":
    """Concatenate stacked mappings along the leading config axis.

    Each element of `stacks` is a per-layer list of MappedLayer whose
    arrays carry a leading (C_i,) axis (see `lift_mapped` /
    repro.variability.expand_trials); the result stacks sum(C_i) entries.
    """
    stacks = list(stacks)
    if len(stacks) == 1:
        return list(stacks[0])
    n_layers = len(stacks[0])
    return [
        dataclasses.replace(
            stacks[0][layer],
            g_pos=jnp.concatenate([s[layer].g_pos for s in stacks]),
            g_neg=jnp.concatenate([s[layer].g_neg for s in stacks]),
            k=jnp.concatenate(
                [jnp.atleast_1d(jnp.asarray(s[layer].k)) for s in stacks]
            ),
        )
        for layer in range(n_layers)
    ]


def stack_mapped(
    mapped_all: "Sequence[Sequence[MappedLayer]]", dtype
) -> "tuple[tuple, tuple, tuple]":
    """Stack per-config mapWB outputs along a leading config axis.

    Returns per-layer tuples (g_pos, g_neg, k): (C, fan_in+1, fan_out)
    conductances and (C,) sense scales — the stacked form both
    `evaluate_batch` and the transient engine consume.
    """
    n_layers = len(mapped_all[0])
    g_pos = tuple(
        jnp.stack([m[layer].g_pos for m in mapped_all])
        for layer in range(n_layers)
    )
    g_neg = tuple(
        jnp.stack([m[layer].g_neg for m in mapped_all])
        for layer in range(n_layers)
    )
    k = tuple(
        jnp.asarray([m[layer].k for m in mapped_all], dtype)
        for layer in range(n_layers)
    )
    return g_pos, g_neg, k


def evaluate_batch(
    params: Params,
    x: jax.Array,
    y: jax.Array,
    cfgs: "Sequence[IMACConfig]",
    *,
    n_samples: Optional[int] = None,
    chunk: int = 256,
    variation_key: Optional[jax.Array] = None,
    noise_key: Optional[jax.Array] = None,
    noise_per_config: bool = False,
    activation: str = "sigmoid",
    mapped: Optional[list] = None,
    mapped_stacked: Optional[list] = None,
    solve_options: Optional[SolveOptions] = None,
    mesh_plan: Optional[MeshPlan] = None,
) -> "list[IMACResult]":
    """Evaluate many structurally-compatible IMAC configurations at once.

    All configurations must share a `structure_key` (same partition plans,
    solver iteration counts, neuron model, parasitics flag, dtype); their
    conductance matrices and electrical scalars are stacked along a
    leading axis and the whole circuit simulation runs as one vmapped,
    jitted solve per sample chunk — one XLA compilation for the entire
    group instead of one per configuration.

    When the configurations carry a `TransientSpec` (cfg.transient —
    identical across the batch by structure_key), the same stacked
    tensors additionally run through ONE batched time-domain integration
    (repro.transient), and the returned results report waveform-measured
    `latency` and integrated `energy` (latency_source='transient')
    instead of the analytic Elmore estimate, which stays available as
    `latency_analytic`.

    Args:
      params: trained digital weights/biases [(W, b), ...].
      x: (N, fan_in) inputs in [0, 1] digital units.
      y: (N,) integer labels.
      cfgs: structurally-compatible configurations (see `structure_key`).
      n_samples: N_S — number of test samples (default: all).
      chunk: samples per jitted circuit solve.
      variation_key: optional device-variation Monte-Carlo draw (the same
        draw is applied to every configuration, as in a paired sweep).
      noise_key: optional read-noise draw (shared across configurations
        unless `noise_per_config`).
      noise_per_config: draw read noise independently per stacked
        configuration instead of sharing one draw — used by the
        Monte-Carlo reliability engine (repro.variability), where each
        stacked entry is an independent trial.
      activation: digital reference activation.
      mapped: optional pre-computed mapWB output per configuration (one
        map_network list per config); lets a sweep engine share mappings
        between configurations that differ only in circuit parameters.
      mapped_stacked: optional pre-STACKED mapping — one MappedLayer per
        layer whose g_pos/g_neg carry a leading (C,) config axis and
        whose k is a (C,) array. Bypasses the per-config stacking below;
        the Monte-Carlo engine samples its trials directly in this form
        (see repro.variability) so trial tensors are never materialized
        twice. Mutually exclusive with `mapped`.
      solve_options: circuit-solver backend selection
        (`core.solver.SolveOptions`); None = the process default
        ($REPRO_SOLVER_BACKEND, else "scan").
      mesh_plan: shard the stacked config axis across a device mesh
        (`repro.distributed.sweep.MeshPlan`). The batch is padded to a
        multiple of the mesh axis by replicating entry 0 and the chunk
        solve runs under `shard_map` with a cross-shard convergence
        pmax — the circuit-solve path (parasitics=True) is
        bitwise-identical to the unsharded batch; the ideal-MVM path
        (parasitics=False) reproduces predictions/accuracy bitwise but
        its power einsum is shape-sensitive on XLA CPU, so power agrees
        only to float32 reassociation (~1e-7 relative).
        Falls back to single-device execution when the batch is smaller
        than `mesh_plan.min_group` or when per-config read-noise draws
        (`noise_per_config` with a `noise_key`) would change under
        sharding. The transient integration (cfg.transient) always runs
        unsharded. None = no sharding (default).

    Returns:
      One IMACResult per configuration, in input order.

    Raises:
      ValueError: if the configurations are not structurally compatible.
        Use repro.explore.run_sweep to group arbitrary configuration
        lists into compatible batches automatically.
    """
    cfgs = list(cfgs)
    if not cfgs:
        return []
    with obs.trace("evaluate_batch", {"configs": len(cfgs)}):
        return _evaluate_batch(
            params,
            x,
            y,
            cfgs,
            n_samples=n_samples,
            chunk=chunk,
            variation_key=variation_key,
            noise_key=noise_key,
            noise_per_config=noise_per_config,
            activation=activation,
            mapped=mapped,
            mapped_stacked=mapped_stacked,
            solve_options=solve_options,
            mesh_plan=mesh_plan,
        )


def _resolve_shard(mesh_plan, n_cfgs, noise_per_config, noise_key):
    """(mesh, axis, n_shards) when the batch should shard, else None.

    Per-config read-noise draws (`noise_per_config` + a key) depend on
    the full stacked shape — a shard would re-draw per local lane and
    diverge from the unsharded batch, so those fall back (recorded as a
    `shard_fallback` event).
    """
    if mesh_plan is None:
        return None
    if noise_per_config and noise_key is not None:
        obs.event("shard_fallback", cause="noise_per_config")
        return None
    if n_cfgs < mesh_plan.min_group:
        return None
    mesh = mesh_plan.build()
    return mesh, mesh_plan.axis, mesh.shape[mesh_plan.axis]


def _evaluate_batch(
    params,
    x,
    y,
    cfgs,
    *,
    n_samples,
    chunk,
    variation_key,
    noise_key,
    noise_per_config,
    activation,
    mapped,
    mapped_stacked,
    solve_options,
    mesh_plan=None,
) -> "list[IMACResult]":
    """`evaluate_batch` body (the wrapper holds the root span).

    Stage spans follow the pipeline: map (mapWB / stacking) → stamp
    (electrical-scalar assembly) → solve (chunked circuit solves, with
    compile-vs-run split via obs.instrument_jit) → measure (host-side
    reduction to IMACResults + solver-telemetry histograms).
    """
    topology = [params[0][0].shape[0]] + [w.shape[1] for w, _ in params]
    key0 = structure_key(topology, cfgs[0])
    for c in cfgs[1:]:
        if structure_key(topology, c) != key0:
            raise ValueError(
                "evaluate_batch needs structurally-compatible configs "
                "(equal structure_key); got a mismatch — group them with "
                "repro.explore.run_sweep instead"
            )

    cfg0 = cfgs[0]
    plans = build_plans(topology, cfg0)
    neuron = cfg0.resolved_neuron()
    dtype = cfg0.dtype
    parasitics = cfg0.parasitics
    tol = cfg0.gs_tol
    v_unit = cfg0.vdd
    iters = [cfg0.gs_iters or suggest_iters(p.rows, p.cols) for p in plans]
    n_layers = len(plans)

    n = n_samples or x.shape[0]
    x, y = x[:n], y[:n]

    # mapWB per configuration (outside the trace, identical to the
    # single-config path), then stack: per layer (C, M, N) conductances
    # and (C,) sense scales; electrical scalars as (C,) vectors.
    with obs.trace("map", {"layers": n_layers}):
        if mapped_stacked is not None:
            if mapped is not None:
                raise ValueError(
                    "pass either mapped or mapped_stacked, not both"
                )
            for m in mapped_stacked:
                if m.g_pos.shape[0] != len(cfgs):
                    raise ValueError(
                        f"mapped_stacked leading axis {m.g_pos.shape[0]} != "
                        f"{len(cfgs)} configurations"
                    )
            g_pos = tuple(m.g_pos for m in mapped_stacked)
            g_neg = tuple(m.g_neg for m in mapped_stacked)
            k = tuple(jnp.asarray(m.k, dtype) for m in mapped_stacked)
        else:
            mapped_all = mapped if mapped is not None else [
                map_network(
                    params,
                    c.resolved_tech(),
                    v_unit=c.vdd,
                    quantize=c.quantize,
                    variation_key=variation_key,
                )
                for c in cfgs
            ]
            g_pos, g_neg, k = stack_mapped(mapped_all, dtype)
    obs_prof.sample_memory("map")
    with obs.trace("stamp"):
        scal = dict(
            r_seg=jnp.asarray(
                [c.interconnect.r_segment for c in cfgs], dtype
            ),
            r_source=jnp.asarray([c.r_source for c in cfgs], dtype),
            r_tia=jnp.asarray([c.r_tia for c in cfgs], dtype),
            omega=jnp.asarray([c.sor_omega for c in cfgs], dtype),
            read_noise=jnp.asarray(
                [c.resolved_tech().read_noise_rel for c in cfgs], dtype
            ),
        )

    # Waveform-accurate timing/energy: the whole stacked configuration
    # batch (sweep points or Monte-Carlo trials) integrates as ONE
    # batched transient — structure_key guarantees a shared spec.
    tspec = cfg0.transient
    transient_res = None
    if tspec is not None:
        from repro.transient.engine import network_transient_stacked

        if not parasitics:
            raise ValueError(
                "cfg.transient needs parasitics=True (the node "
                "capacitances live on the parasitic wire grid)"
            )
        tr_scal = dict(
            scal,
            c_seg=jnp.asarray(
                [c.interconnect.c_segment for c in cfgs], dtype
            ),
            t_samp=jnp.asarray([c.t_sampling for c in cfgs], dtype),
        )
        with obs.trace("transient", {"n_probe": tspec.n_probe}):
            transient_res = network_transient_stacked(
                g_pos, g_neg, k, tr_scal, plans, neuron, tspec,
                jnp.asarray(x[: tspec.n_probe], dtype), v_unit, iters, tol,
                dtype=dtype, solve_options=solve_options,
            )

    # Sharded execution: pad the stacked config axis to a multiple of
    # the mesh axis (replicating entry 0 — trip-count-neutral, see
    # distributed/sweep.pad_stacked) and place every stacked tensor with
    # its `config`-axis sharding. Pre-staged inputs (explore's
    # double-buffered shard_put) pass through as no-ops here.
    c_real = len(cfgs)
    shard = _resolve_shard(mesh_plan, c_real, noise_per_config, noise_key)
    if shard is not None:
        s_mesh, s_axis, n_shards = shard
        c_pad = pad_count(c_real, n_shards)
        with obs.trace(
            "shard_stage", {"devices": n_shards, "pad": c_pad - c_real}
        ):
            def _stage(t):
                t = pad_stacked(jnp.asarray(t), n_shards)
                return jax.device_put(
                    t, NamedSharding(s_mesh, stacked_spec(t, s_mesh, s_axis))
                )

            g_pos = tuple(_stage(t) for t in g_pos)
            g_neg = tuple(_stage(t) for t in g_neg)
            k = tuple(_stage(t) for t in k)
            scal = {name: _stage(v) for name, v in scal.items()}
        # The tol early-exit must see the *global* residual max inside
        # shard_map; shard_axis routes a lax.pmax into the cond.
        solve_options = dataclasses.replace(
            solve_options if solve_options is not None else SolveOptions(),
            shard_axis=s_axis,
        )

    def forward_all(gp, gn, kk, sc, xb, nkey):
        """Forward every stacked configuration over a chunk of samples.

        The config axis is an ordinary leading batch axis: each layer is
        ONE crossbar solve over (C, batch, tiles) with per-config
        electrical scalars broadcast inside the solver — a single
        while_loop, no per-lane masking.
        """
        a = xb  # (batch, F); becomes (C, batch, F) after the first layer.
        keys = (
            jax.random.split(nkey, n_layers)
            if nkey is not None
            else [None] * n_layers
        )
        powers, residuals, sweeps = [], [], []
        for layer, plan in enumerate(plans):
            cp = CircuitParams(
                r_row=sc["r_seg"],
                r_col=sc["r_seg"],
                r_source=sc["r_source"],
                r_tia=sc["r_tia"],
                gs_iters=iters[layer],
                omega=sc["omega"],
                tol=tol,
            )
            a, power, residual, _, swp = linear_forward(
                gp[layer],
                gn[layer],
                kk[layer],
                v_unit,
                plan,
                cp,
                neuron,
                a,
                parasitics=parasitics,
                is_output=(layer == n_layers - 1),
                solve_options=solve_options,
                noise_key=keys[layer],
                read_noise_rel=sc["read_noise"],
                noise_per_config=noise_per_config,
                dtype=dtype,
            )
            powers.append(jnp.mean(power, axis=-1))   # (C,)
            residuals.append(residual)                # (C,)
            sweeps.append(swp)                        # scalar per layer
        pred = jnp.argmax(a, axis=-1)                 # (C, batch)
        return (
            pred,
            jnp.stack(powers, axis=-1),
            jnp.stack(residuals, axis=-1),
            jnp.stack(sweeps),                        # (L,)
        )

    obs_prof.sample_memory("stamp")

    # prof.instrument_jit = the tracer's compile-vs-run span split plus
    # opt-in HLO cost analysis (hlo_flops / achieved_flops_per_s).
    if shard is not None:
        stacked_specs = jax.tree_util.tree_map(
            lambda t: stacked_spec(t, s_mesh, s_axis), (g_pos, g_neg, k, scal)
        )
        # Samples and the shared noise key replicate; the per-config
        # outputs (pred, powers, residuals) concatenate back along the
        # config axis, while the sweep counts — identical on every
        # shard thanks to the global-pmax cond — come out replicated.
        out_specs = (P(s_axis), P(s_axis), P(s_axis), P())
        if noise_key is None:
            def forward_nokey(gp, gn, kk, sc, xb):
                return forward_all(gp, gn, kk, sc, xb, None)

            inner = obs_prof.instrument_jit(
                jax.jit(shard_map_compat(
                    forward_nokey,
                    mesh=s_mesh,
                    in_specs=stacked_specs + (P(),),
                    out_specs=out_specs,
                )),
                "solve_chunk",
            )

            def run_chunk(gp, gn, kk, sc, xb, nk):
                return inner(gp, gn, kk, sc, xb)
        else:
            run_chunk = obs_prof.instrument_jit(
                jax.jit(shard_map_compat(
                    forward_all,
                    mesh=s_mesh,
                    in_specs=stacked_specs + (P(), P()),
                    out_specs=out_specs,
                )),
                "solve_chunk",
            )
    else:
        run_chunk = obs_prof.instrument_jit(
            jax.jit(forward_all), "solve_chunk"
        )

    n_chunks = (n + chunk - 1) // chunk
    keys = (
        jax.random.split(noise_key, n_chunks)
        if noise_key is not None
        else [None] * n_chunks
    )
    preds, powers, residuals, layer_sweeps = [], [], [], None
    solve_attrs = {"chunks": n_chunks, "n_samples": n}
    if shard is not None:
        solve_attrs["devices"] = n_shards
    with obs.trace("solve", solve_attrs):
        for ci in range(n_chunks):
            xb = x[ci * chunk : (ci + 1) * chunk]
            pred, pwr, res, swp = run_chunk(
                g_pos, g_neg, k, scal, xb, keys[ci]
            )
            preds.append(pred)                 # (C, B)
            powers.append(pwr * xb.shape[0])   # weight by chunk size
            residuals.append(res)
            layer_sweeps = swp                 # (L,), batch-wide per layer
    obs_prof.sample_memory("solve")
    pred = jnp.concatenate(preds, axis=1)                      # (C, n)
    per_layer_power = jnp.sum(jnp.stack(powers), axis=0) / n   # (C, L)
    worst_res = jnp.max(jnp.stack(residuals), axis=0)          # (C, L)
    if shard is not None:
        # Drop the pad lanes (replicas of config 0) before measurement.
        pred = pred[:c_real]
        per_layer_power = per_layer_power[:c_real]
        worst_res = worst_res[:c_real]

    if obs.enabled():
        # Solver convergence telemetry, recorded on the host from the
        # aux outputs that already leave the jit (no callbacks inside).
        h_sw = obs.histogram("solver_sweeps", buckets=obs.SWEEPS_BUCKETS)
        h_res = obs.histogram(
            "solver_residual", buckets=obs.RESIDUAL_BUCKETS
        )
        for layer in range(n_layers):
            h_sw.observe(int(layer_sweeps[layer]))
        for value in jnp.ravel(worst_res):
            h_res.observe(float(value))
        obs.counter("solver_chunks_total").inc(n_chunks)

    with obs.trace("measure"):
        dig_pred = jnp.argmax(mlp_forward(params, x, activation), axis=-1)
        dig_acc = float(jnp.mean((dig_pred == y).astype(jnp.float32)))

        results = []
        latency_memo: dict = {}
        for i, cfg in enumerate(cfgs):
            errors = int(jnp.sum((pred[i] != y).astype(jnp.int32)))
            # The analytic latency is input-independent (structural).
            # Memoized by the fields it actually depends on — keying by
            # id(cfg) would alias distinct configs when CPython reuses the
            # address of a garbage-collected one.
            memo_key = (cfg.interconnect, cfg.resolved_neuron(), cfg.t_sampling)
            if memo_key not in latency_memo:
                latency_memo[memo_key] = float(
                    sum(
                        jnp.asarray(
                            layer_latency(p, cfg.interconnect, cfg.resolved_neuron()),
                            dtype,
                        )
                        for p in plans
                    )
                    + cfg.t_sampling
                )
            latency_an = latency_memo[memo_key]
            plp = per_layer_power[i]
            avg_power = float(jnp.sum(plp))
            if transient_res is not None:
                latency = float(transient_res.latency[i])
                energy = float(transient_res.energy[i])
                source = "transient"
                settled = bool(transient_res.settled[i])
            else:
                latency = latency_an
                energy = avg_power * latency_an
                source = "analytic"
                settled = True
            results.append(
                IMACResult(
                    accuracy=1.0 - errors / n,
                    error_rate=errors / n,
                    avg_power=avg_power,
                    latency=latency,
                    digital_accuracy=dig_acc,
                    per_layer_power=tuple(float(p) for p in plp),
                    worst_residual=float(jnp.max(worst_res[i])),
                    n_samples=n,
                    hp=tuple(p.hp for p in plans),
                    vp=tuple(p.vp for p in plans),
                    energy=energy,
                    latency_analytic=latency_an,
                    latency_source=source,
                    settled=settled,
                )
            )
    obs_prof.sample_memory("measure")
    return results


def test_imac(
    params: Params,
    x: jax.Array,
    y: jax.Array,
    cfg: IMACConfig,
    *,
    n_samples: Optional[int] = None,
    chunk: int = 256,
    variation_key: Optional[jax.Array] = None,
    noise_key: Optional[jax.Array] = None,
    activation: str = "sigmoid",
) -> IMACResult:
    """Evaluate the IMAC deployment of `params` on (x, y).

    Thin wrapper over `evaluate_batch` with a single-configuration batch.

    Args:
      params: trained digital weights/biases [(W, b), ...].
      x: (N, fan_in) inputs in [0, 1] digital units.
      y: (N,) integer labels.
      cfg: IMAC hyperparameters (Table I).
      n_samples: N_S — number of test samples (default: all).
      chunk: samples per jitted circuit solve.
      variation_key: optional device-variation Monte-Carlo draw.
      noise_key: optional read-noise draw.

    Returns:
      IMACResult with accuracy/power/latency (Algorithm 1 lines 21-22).
    """
    return evaluate_batch(
        params,
        x,
        y,
        [cfg],
        n_samples=n_samples,
        chunk=chunk,
        variation_key=variation_key,
        noise_key=noise_key,
        activation=activation,
    )[0]


def evaluate_netlist(
    netlist,
    x: jax.Array,
    y: jax.Array,
    *,
    main: "str | None" = None,
    cfg_overrides: "Optional[dict]" = None,
    **kw,
):
    """Evaluate a SPICE netlist on (x, y) — the netlist *is* the model.

    `netlist` is either the ``{filename: contents}`` dict `map_imac`
    returns (or any equivalent multi-file deck) or an already-parsed
    `repro.spice.Circuit`. The netlist is lowered back to engine
    structures (`repro.spice.lower_network`): conductances, partition
    plans, neuron models, electrical parameters and — when the deck
    states a ``.TRAN`` — a `TransientSpec`, then evaluated through the
    same `evaluate_batch` path as a trained-parameter deployment.

    Args:
      netlist: {filename: contents} dict or a parsed Circuit.
      x, y: test inputs (digital units) and integer labels.
      main: top file name for multi-file dicts (default `imac_main.sp`).
      cfg_overrides: IMACConfig field overrides applied after lowering
        (e.g. ``{"gs_iters": 96}`` — engine tuning the netlist cannot
        state).
      **kw: forwarded to `evaluate_batch` (chunk, noise_key,
        solve_options, ...).

    Returns:
      (IMACResult, LoweredNetwork) — the result plus the lowered
      structures for inspection (recovered sample, conductances, spec).

    Raises:
      repro.spice.NonCrossbarError: the netlist is not a generated-form
        IMAC network. Flat third-party crossbars go through
        `repro.spice.lower_crossbar` + `solve_crossbar` instead.
    """
    from repro.spice.lower import lower_network

    with obs.trace("evaluate_netlist"):
        net = lower_network(netlist, main=main)
        params = [
            (jnp.asarray(w), jnp.asarray(b)) for w, b in net.to_params()
        ]
        cfg = net.to_config(**(cfg_overrides or {}))
        result = evaluate_batch(
            params, x, y, [cfg], mapped=[net.to_mapped()], **kw
        )[0]
    return result, net


def sweep(
    params: Params,
    x: jax.Array,
    y: jax.Array,
    cfgs: "Sequence[tuple[str, IMACConfig]]",
    **kw,
) -> "list[tuple[str, IMACResult]]":
    """Design-space sweep, one configuration at a time (the paper's
    Tables III/IV are sweeps over partitioning / device technology).

    This is the reference per-config loop: every configuration re-traces
    and re-compiles its own solve. Prefer repro.explore.run_sweep, which
    groups structurally-compatible configurations into single vmapped
    solves and memoizes results on disk.
    """
    return [(name, test_imac(params, x, y, cfg, **kw)) for name, cfg in cfgs]
