"""IMAC-Sim-JAX: circuit-level simulation of in-memory analog computing,
plus the multi-architecture distributed training/serving substrate it is
embedded in. See DESIGN.md for the system map."""

__version__ = "1.0.0"
