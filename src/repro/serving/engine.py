"""Slot-based continuous-batching serving engine.

A fixed pool of B slots shares one decode step (static shapes — XLA
compiles exactly two programs: prefill-into-slot and batched decode).
New requests prefill into free slots while other slots keep decoding;
finished slots (EOS or max_tokens) are immediately reusable. Per-slot
cache writes go through the per-sequence `length` indices, so ragged
occupancy needs no re-compilation. On real TPUs the decode einsum is the
kernels/decode_attention flash-decoding kernel; cache updates donate.

The analog_mvm config flag routes projection matmuls through the paper's
ideal-analog crossbar simulation (kernels/imac_mvm.analog_linear) — IMAC
as an inference accelerator, paper ref [1].
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: "np.ndarray"           # (S,) int32
    max_tokens: int = 32
    eos_id: int = -1               # -1: never
    # filled by the engine:
    output: "list[int]" = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 4
    cache_len: int = 512
    greedy: bool = True


class ServingEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.cache = model.init_cache(cfg.slots, cfg.cache_len)
        self.slot_req: "list[Optional[Request]]" = [None] * cfg.slots
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self._last_tok = jnp.zeros((cfg.slots, 1), jnp.int32)
        self._queue: "list[Request]" = []

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request):
        self._queue.append(req)

    def _free_slots(self) -> "list[int]":
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self):
        """Prefill queued requests into free slots (one at a time keeps
        the prefill program single-shape; batched admission would also
        work with bucketing)."""
        for slot in self._free_slots():
            if not self._queue:
                break
            req = self._queue.pop(0)
            tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, cache1 = self.model.prefill(
                self.params, {"tokens": tokens}, cache_len=self.cfg.cache_len
            )
            # Copy the single-sequence cache into the slot.
            def place(big, small):
                # leading dims: (layers..., batch, ...) — batch is the dim
                # matching cfg.slots; caches are built with batch axis
                # after the stacked layer axis.
                return jax.tree_util.tree_map(
                    lambda b, s: _set_slot(b, s, slot), big, small
                )

            self.cache = place(self.cache, cache1)
            tok = int(jnp.argmax(logits[0, -1]))
            req.output.append(tok)
            self.slot_req[slot] = req
            self._last_tok = self._last_tok.at[slot, 0].set(tok)

    # -- decode ------------------------------------------------------------

    def step(self):
        """One engine tick: admit, batched-decode, retire."""
        self._admit()
        if all(r is None for r in self.slot_req):
            return
        logits, self.cache = self._decode(
            self.params, self.cache, self._last_tok
        )
        nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        nxt_host = np.asarray(nxt)
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            tok = int(nxt_host[slot])
            req.output.append(tok)
            if tok == req.eos_id or len(req.output) >= req.max_tokens:
                req.done = True
                self.slot_req[slot] = None
        self._last_tok = nxt[:, None]

    def run(self, requests: "list[Request]", max_ticks: int = 1000):
        for r in requests:
            self.submit(r)
        for _ in range(max_ticks):
            if not self._queue and all(r is None for r in self.slot_req):
                break
            self.step()
        return requests


def _set_slot(big: jax.Array, small: jax.Array, slot: int) -> jax.Array:
    """Copy `small` (batch=1 cache leaf) into slot `slot` of `big`.

    Cache leaves are either (layers, batch, ...) stacked or (batch, ...)
    at the root (e.g. pos); we detect the batch axis as the one where
    shapes differ by slots-vs-1.
    """
    if big.ndim == 0 or big.shape == small.shape:
        return small.astype(big.dtype)
    for axis in range(big.ndim):
        if small.shape[axis] == 1 and big.shape[axis] != 1:
            idx = [slice(None)] * big.ndim
            idx[axis] = slice(slot, slot + 1)
            return big.at[tuple(idx)].set(small.astype(big.dtype))
    # Same shape (e.g. scalars broadcast per batch): overwrite slot on
    # the first axis if it matches slots.
    if big.shape and big.shape[0] == small.shape[0]:
        return big  # layer-stacked non-batch leaf; nothing slot-specific
    return big
