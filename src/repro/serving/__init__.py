from repro.serving.engine import Request, ServeConfig, ServingEngine  # noqa: F401
