"""Unified model: embeddings + staged blocks + heads, for all 10 archs.

API (all pure functions of explicit params — pjit-ready):
  model.init(key)            -> params
  model.param_axes()         -> logical-axis tree matching params
  model.loss_fn(params, batch)          -> (loss, metrics)   [train]
  model.prefill(params, batch, cache_len) -> (logits, cache) [prefill]
  model.decode_step(params, cache, tokens) -> (logits, cache) [decode]
  model.init_cache(batch, cache_len)    -> cache pytree

Batches:
  train:   {tokens (B,S), labels (B,S), loss_mask? (B,S),
            vision_embeds (B,P,E)?  [vlm stub frontend],
            frames (B,T,E)?         [audio stub frontend]}
  prefill: {tokens (B,S), vision_embeds?/frames?}
  decode:  tokens (B,1) + the cache pytree (donated).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import blocks as blocks_mod
from repro.models import mamba as mamba_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.blocks import LayerKind, Stage, build_stages, encoder_stages
from repro.models.common import (
    Builder,
    build_axes,
    build_params,
    cross_entropy,
    dtype_of,
    embed_lookup,
    embed_params,
    lm_logits,
    rmsnorm,
    rmsnorm_params,
    stacked_axes,
    stacked_init,
)
from repro.sharding.rules import shard_activation

MOE_AUX_COEF = 0.01
MTP_COEF = 0.3


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    stages: "list[Stage]"
    enc_stages: "Optional[list[Stage]]"
    remat: bool = True

    # ------------------------------------------------------------- params

    def init(self, key: jax.Array):
        cfg = self.cfg
        keys = jax.random.split(key, 8 + len(self.stages))
        p: "dict[str, Any]" = {
            "embed": build_params(embed_params, cfg, keys[0]),
            "final_ln": build_params(
                lambda b, c: rmsnorm_params(b, c.d_model), cfg, keys[1]
            ),
            "stages": [
                stacked_init(
                    blocks_mod.stage_params_fn(s), cfg, keys[2 + i], s.repeats
                )
                for i, s in enumerate(self.stages)
            ],
        }
        off = 2 + len(self.stages)
        if cfg.family == "vlm":
            p["vision_proj"] = build_params(
                lambda b, c: {
                    "w": b.param((c.d_model, c.d_model), ("embed", "ff"))
                },
                cfg, keys[off],
            )
        if cfg.is_encoder_decoder:
            p["frames_proj"] = build_params(
                lambda b, c: {
                    "w": b.param((c.d_model, c.d_model), ("embed", "ff"))
                },
                cfg, keys[off + 1],
            )
            p["encoder"] = [
                stacked_init(
                    blocks_mod.stage_params_fn(s), cfg, keys[off + 2], s.repeats
                )
                for s in self.enc_stages
            ]
            p["enc_ln"] = build_params(
                lambda b, c: rmsnorm_params(b, c.d_model), cfg, keys[off + 3]
            )
        if cfg.mtp:
            kind = LayerKind("attn", "mlp")
            p["mtp"] = {
                "proj": build_params(
                    lambda b, c: {
                        "w": b.param(
                            (2 * c.d_model, c.d_model), ("ff", "embed")
                        )
                    },
                    cfg, keys[off + 4],
                ),
                "ln_h": build_params(
                    lambda b, c: rmsnorm_params(b, c.d_model), cfg, keys[off + 5]
                ),
                "ln_e": build_params(
                    lambda b, c: rmsnorm_params(b, c.d_model), cfg, keys[off + 6]
                ),
                "block": build_params(
                    lambda b, c: blocks_mod.layer_params(b, c, kind),
                    cfg, keys[off + 7],
                ),
            }
        return p

    def param_axes(self):
        cfg = self.cfg
        ax: "dict[str, Any]" = {
            "embed": build_axes(embed_params, cfg),
            "final_ln": build_axes(lambda b, c: rmsnorm_params(b, c.d_model), cfg),
            "stages": [
                stacked_axes(blocks_mod.stage_params_fn(s), cfg)
                for s in self.stages
            ],
        }
        if cfg.family == "vlm":
            ax["vision_proj"] = build_axes(
                lambda b, c: {"w": b.param((c.d_model, c.d_model), ("embed", "ff"))},
                cfg,
            )
        if cfg.is_encoder_decoder:
            ax["frames_proj"] = build_axes(
                lambda b, c: {"w": b.param((c.d_model, c.d_model), ("embed", "ff"))},
                cfg,
            )
            ax["encoder"] = [
                stacked_axes(blocks_mod.stage_params_fn(s), cfg)
                for s in self.enc_stages
            ]
            ax["enc_ln"] = build_axes(
                lambda b, c: rmsnorm_params(b, c.d_model), cfg
            )
        if cfg.mtp:
            kind = LayerKind("attn", "mlp")
            ax["mtp"] = {
                "proj": build_axes(
                    lambda b, c: {
                        "w": b.param((2 * c.d_model, c.d_model), ("ff", "embed"))
                    },
                    cfg,
                ),
                "ln_h": build_axes(lambda b, c: rmsnorm_params(b, c.d_model), cfg),
                "ln_e": build_axes(lambda b, c: rmsnorm_params(b, c.d_model), cfg),
                "block": build_axes(
                    lambda b, c: blocks_mod.layer_params(b, c, kind), cfg
                ),
            }
        return ax

    # ------------------------------------------------------------- embed

    def _embed(self, params, batch, *, positions_offset=None):
        cfg = self.cfg
        cdt = dtype_of(cfg.compute_dtype)
        tokens = batch["tokens"]
        b = tokens.shape[0]
        x = embed_lookup(params["embed"], tokens, cfg, cdt)
        if cfg.family == "vlm" and "vision_embeds" in batch:
            ve = batch["vision_embeds"].astype(cdt)
            ve = jnp.einsum(
                "bpe,ef->bpf", ve, params["vision_proj"]["w"].astype(cdt)
            )
            x = jnp.concatenate([ve, x], axis=1)
        s = x.shape[1]
        if positions_offset is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        else:
            positions = positions_offset[:, None] + jnp.arange(
                s, dtype=jnp.int32
            )
        x = shard_activation(x, ("act_batch", "act_seq", None))
        return x, positions

    def _encode(self, params, batch):
        """Audio/enc-dec encoder over stub frame embeddings."""
        cfg = self.cfg
        cdt = dtype_of(cfg.compute_dtype)
        frames = batch["frames"].astype(cdt)
        x = jnp.einsum("bte,ef->btf", frames, params["frames_proj"]["w"].astype(cdt))
        x = shard_activation(x, ("act_batch", "act_seq", None))
        b, t, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
        for sp, st in zip(params["encoder"], self.enc_stages):
            x, _, _ = blocks_mod.stage_apply(
                sp, x, cfg, st, positions, mode="train", remat=self.remat
            )
        return rmsnorm(params["enc_ln"], x, cfg.norm_eps)

    # ------------------------------------------------------------- train

    def _backbone(self, params, batch, mode="train"):
        cfg = self.cfg
        x, positions = self._embed(params, batch)
        enc_hidden = None
        if cfg.is_encoder_decoder:
            enc_hidden = self._encode(params, batch)
        aux_total = jnp.zeros((), jnp.float32)
        for sp, st in zip(params["stages"], self.stages):
            x, aux, _ = blocks_mod.stage_apply(
                sp, x, cfg, st, positions,
                mode="train", enc_kv=enc_hidden, remat=self.remat,
            )
            aux_total = aux_total + aux
        x = rmsnorm(params["final_ln"], x, cfg.norm_eps)
        return x, aux_total, positions

    def forward(self, params, batch):
        x, aux, _ = self._backbone(params, batch)
        return lm_logits(params["embed"], x, self.cfg), aux

    def loss_fn(self, params, batch):
        cfg = self.cfg
        x, aux, positions = self._backbone(params, batch)
        logits = lm_logits(params["embed"], x, cfg)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if cfg.family == "vlm":
            # Text tokens only: the vision prefix produced no labels.
            n_prefix = x.shape[1] - labels.shape[1]
            logits = logits[:, n_prefix:]
        ce = cross_entropy(logits, labels, mask)
        loss = ce + MOE_AUX_COEF * aux

        metrics = {"ce": ce, "aux": aux}
        if cfg.mtp:
            mtp_loss = self._mtp_loss(params, x, batch, positions)
            loss = loss + MTP_COEF * mtp_loss
            metrics["mtp"] = mtp_loss
        metrics["loss"] = loss
        return loss, metrics

    def _mtp_loss(self, params, h, batch, positions):
        """DeepSeek-V3 multi-token prediction (depth 1): predict t+2."""
        cfg = self.cfg
        cdt = dtype_of(cfg.compute_dtype)
        p = params["mtp"]
        tokens = batch["tokens"]
        # Next-token embeddings aligned with h_t.
        nxt = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        e = embed_lookup(params["embed"], nxt, cfg, cdt)
        hh = rmsnorm(p["ln_h"], h, cfg.norm_eps)
        ee = rmsnorm(p["ln_e"], e, cfg.norm_eps)
        z = jnp.concatenate([hh, ee], axis=-1)
        z = jnp.einsum("bsf,fe->bse", z, p["proj"]["w"].astype(cdt))
        kind = LayerKind("attn", "mlp")
        z, _, _ = blocks_mod.apply_layer(p["block"], z, cfg, kind, positions)
        logits = lm_logits(params["embed"], z, cfg)
        # Labels shifted one further: h_t predicts token t+2.
        lab2 = jnp.pad(batch["labels"][:, 1:], ((0, 0), (0, 1)))
        mask = jnp.ones_like(lab2, jnp.float32).at[:, -1].set(0.0)
        if batch.get("loss_mask") is not None:
            mask = mask * batch["loss_mask"].astype(jnp.float32)
        return cross_entropy(logits, lab2, mask)

    # ----------------------------------------------------------- serving

    def prefill(self, params, batch, cache_len: int):
        cfg = self.cfg
        x, positions = self._embed(params, batch)
        enc_hidden = None
        if cfg.is_encoder_decoder:
            enc_hidden = self._encode(params, batch)
        caches = []
        for sp, st in zip(params["stages"], self.stages):
            x, _, cache_s = blocks_mod.stage_apply(
                sp, x, cfg, st, positions,
                mode="prefill", cache_len=cache_len, enc_kv=enc_hidden,
                remat=False,
            )
            caches.append(cache_s)
        x = rmsnorm(params["final_ln"], x, cfg.norm_eps)
        logits = lm_logits(params["embed"], x[:, -1:], cfg)
        cache = {
            "stages": caches,
            "pos": jnp.full((x.shape[0],), x.shape[1], jnp.int32),
        }
        return logits, cache

    def decode_step(self, params, cache, tokens):
        """tokens: (B, 1) -> (logits (B,1,V), updated cache)."""
        cfg = self.cfg
        x, positions = self._embed(
            params, {"tokens": tokens}, positions_offset=cache["pos"]
        )
        new_stage_caches = []
        for sp, st, cache_s in zip(params["stages"], self.stages, cache["stages"]):
            x, _, new_c = blocks_mod.stage_apply(
                sp, x, cfg, st, positions,
                mode="decode", caches=cache_s, remat=False,
            )
            new_stage_caches.append(new_c)
        x = rmsnorm(params["final_ln"], x, cfg.norm_eps)
        logits = lm_logits(params["embed"], x, cfg)
        new_cache = {"stages": new_stage_caches, "pos": cache["pos"] + 1}
        return logits, new_cache

    def init_cache(self, batch: int, cache_len: int):
        """Zeroed cache pytree (use under jax.eval_shape for dry-runs)."""
        cfg = self.cfg
        cdt = dtype_of(cfg.compute_dtype)

        def layer_cache(kind: LayerKind):
            c = {}
            if kind.mixer == "attn":
                c["self"] = attn_mod.init_cache(cfg, batch, cache_len, cdt)
            elif kind.mixer == "mamba":
                c["ssm"] = mamba_mod.init_mamba_state(cfg, batch, cdt)
            elif kind.mixer == "rwkv":
                c["rwkv"] = rwkv_mod.init_rwkv_state(cfg, batch, cdt)
            if kind.cross:
                kv = cfg.n_kv_heads
                c["cross"] = (
                    jnp.zeros((batch, cfg.encoder_seq, kv, cfg.head_dim), cdt),
                    jnp.zeros((batch, cfg.encoder_seq, kv, cfg.head_dim), cdt),
                )
            return c

        def stage_cache(st: Stage):
            one = {
                f"l{i}": layer_cache(k)
                for i, k in enumerate(st.kinds)
                if layer_cache(k)
            }
            # Stack over repeats (leading scan dim).
            return jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (st.repeats,) + a.shape), one
            )

        return {
            "stages": [stage_cache(s) for s in self.stages],
            "pos": jnp.zeros((batch,), jnp.int32),
        }

    def cache_axes(self):
        """Logical sharding axes matching init_cache's pytree."""
        cfg = self.cfg

        def layer_axes(kind: LayerKind):
            c = {}
            if kind.mixer == "attn":
                c["self"] = attn_mod.KVCache(
                    k=("cache_batch", "cache_seq", None, None),
                    v=("cache_batch", "cache_seq", None, None),
                    length=("cache_batch",),
                )
            elif kind.mixer == "mamba":
                c["ssm"] = mamba_mod.MambaState(
                    ssm=("cache_batch", "act_ff", None),
                    conv=("cache_batch", None, "act_ff"),
                )
            elif kind.mixer == "rwkv":
                c["rwkv"] = rwkv_mod.RWKVState(
                    wkv=("cache_batch", "act_heads", None, None),
                    shift_tm=("cache_batch", None),
                    shift_cm=("cache_batch", None),
                )
            if kind.cross:
                c["cross"] = (
                    ("cache_batch", "cache_seq", None, None),
                    ("cache_batch", "cache_seq", None, None),
                )
            return c

        def stage_axes(st: Stage):
            one = {
                f"l{i}": layer_axes(k)
                for i, k in enumerate(st.kinds)
                if layer_axes(k)
            }
            return jax.tree_util.tree_map(
                lambda a: ("layers",) + a,
                one,
                is_leaf=lambda x: isinstance(x, tuple)
                and all(isinstance(e, (str, type(None))) for e in x),
            )

        return {
            "stages": [stage_axes(s) for s in self.stages],
            "pos": ("cache_batch",),
        }


def build_model(cfg: ModelConfig, *, remat: bool = True) -> Model:
    stages = build_stages(cfg)
    enc = encoder_stages(cfg) if cfg.is_encoder_decoder else None
    assert sum(s.n_layers for s in stages) == cfg.n_layers, (
        stages, cfg.n_layers,
    )
    return Model(cfg=cfg, stages=stages, enc_stages=enc, remat=remat)
