"""Layer kinds, stage grouping, and the scanned block machinery.

A layer is (mixer, ffn, cross?) where mixer ∈ {attn, mamba, rwkv} and
ffn ∈ {mlp, moe, rwkv_cm}. Heterogeneous stacks (DeepSeek's leading
dense layers, Jamba's 8-layer attn/mamba/MoE pattern) are expressed as
*stages*: a group of explicitly-listed layer kinds scanned `repeats`
times with stacked parameters — the group body is unrolled inside the
scan, so the HLO stays compact (one group body per stage) even for
61-72 layer models. Remat wraps the group body.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.common import Builder, residual_scale, rmsnorm, rmsnorm_params
from repro.models.mlp import mlp_apply, mlp_params
from repro.models.moe import moe_apply, moe_params


@dataclasses.dataclass(frozen=True)
class LayerKind:
    mixer: str             # attn | mamba | rwkv
    ffn: Optional[str]     # mlp | moe | rwkv_cm | None
    cross: bool = False    # add cross-attention (enc-dec decoder)
    causal: bool = True


@dataclasses.dataclass(frozen=True)
class Stage:
    kinds: Tuple[LayerKind, ...]
    repeats: int

    @property
    def n_layers(self) -> int:
        return len(self.kinds) * self.repeats


def build_stages(cfg: ModelConfig) -> "list[Stage]":
    """Derive the stage structure from a ModelConfig."""
    if cfg.ssm_type == "rwkv6":
        return [Stage((LayerKind("rwkv", "rwkv_cm"),), cfg.n_layers)]
    if cfg.attn_period:  # jamba-style hybrid
        kinds = []
        for i in range(cfg.attn_period):
            mixer = "attn" if cfg.is_attn_layer(i) else "mamba"
            ffn = "moe" if cfg.is_moe_layer(i) else "mlp"
            kinds.append(LayerKind(mixer, ffn))
        assert cfg.n_layers % cfg.attn_period == 0
        return [Stage(tuple(kinds), cfg.n_layers // cfg.attn_period)]
    if cfg.moe_enabled:
        stages = []
        if cfg.first_k_dense:
            stages.append(Stage((LayerKind("attn", "mlp"),), cfg.first_k_dense))
        n_rest = cfg.n_layers - cfg.first_k_dense
        if cfg.moe_every == 1:
            stages.append(Stage((LayerKind("attn", "moe"),), n_rest))
        else:
            kinds = tuple(
                LayerKind(
                    "attn",
                    "moe" if (i % cfg.moe_every) == cfg.moe_offset else "mlp",
                )
                for i in range(cfg.moe_every)
            )
            assert n_rest % cfg.moe_every == 0
            stages.append(Stage(kinds, n_rest // cfg.moe_every))
        return stages
    cross = cfg.is_encoder_decoder
    return [Stage((LayerKind("attn", "mlp", cross=cross),), cfg.n_layers)]


def encoder_stages(cfg: ModelConfig) -> "list[Stage]":
    return [
        Stage((LayerKind("attn", "mlp", causal=False),), cfg.n_encoder_layers)
    ]


# ------------------------------------------------------------- parameters


def layer_params(b: Builder, cfg: ModelConfig, kind: LayerKind):
    p: "dict[str, Any]" = {"ln1": rmsnorm_params(b, cfg.d_model)}
    if kind.mixer == "attn":
        p["mix"] = attn_mod.attn_params(b, cfg)
    elif kind.mixer == "mamba":
        p["mix"] = mamba_mod.mamba_params(b, cfg)
    elif kind.mixer == "rwkv":
        p["mix"] = rwkv_mod.rwkv_params(b, cfg)
    else:
        raise ValueError(kind.mixer)
    if kind.cross:
        p["ln_cross"] = rmsnorm_params(b, cfg.d_model)
        p["cross"] = attn_mod.gqa_params(b, cfg)
    if kind.ffn is not None:
        p["ln2"] = rmsnorm_params(b, cfg.d_model)
        if kind.ffn == "mlp":
            p["ffn"] = mlp_params(b, cfg)
        elif kind.ffn == "moe":
            p["ffn"] = moe_params(b, cfg)
        elif kind.ffn == "rwkv_cm":
            pass  # channel-mix weights live inside the rwkv mixer dict
        else:
            raise ValueError(kind.ffn)
    return p


def stage_params_fn(stage: Stage):
    def fn(b: Builder, cfg: ModelConfig):
        return {
            f"l{i}": layer_params(b, cfg, kind)
            for i, kind in enumerate(stage.kinds)
        }
    return fn


# ------------------------------------------------------------------ apply


def apply_layer(
    p,
    x: jax.Array,
    cfg: ModelConfig,
    kind: LayerKind,
    positions: jax.Array,
    *,
    cache=None,
    make_cache: bool = False,
    cache_len: int = 0,
    enc_kv=None,
    enc_mask=None,
):
    """One layer. Returns (x, aux_loss, new_cache)."""
    rs = residual_scale(cfg)
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}

    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind.mixer == "attn":
        out, c = attn_mod.attn_apply(
            p["mix"], h, cfg, positions,
            causal=kind.causal,
            cache=None if cache is None else cache.get("self"),
            make_cache=make_cache,
            cache_len=cache_len,
        )
        if c is not None:
            new_cache["self"] = c
    elif kind.mixer == "mamba":
        state = None if cache is None else cache.get("ssm")
        if state is None and make_cache:
            state = mamba_mod.init_mamba_state(cfg, x.shape[0], x.dtype)
        out, c = mamba_mod.mamba_apply(p["mix"], h, cfg, state)
        if c is not None:
            new_cache["ssm"] = c
    elif kind.mixer == "rwkv":
        state = None if cache is None else cache.get("rwkv")
        if state is None and make_cache:
            state = rwkv_mod.init_rwkv_state(cfg, x.shape[0], x.dtype)
        out, c = rwkv_mod.time_mix(p["mix"], h, cfg, state)
        if c is not None:
            new_cache["rwkv"] = c
    else:
        raise ValueError(kind.mixer)
    x = x + rs * out

    if kind.cross:
        h = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        if cache is not None and "cross" in cache:
            k_all, v_all = cache["cross"]
        else:
            # enc_kv is the encoder hidden state (B, T_enc, E).
            k_all = jnp.einsum(
                "bte,ehd->bthd", enc_kv, p["cross"]["wk"].astype(x.dtype)
            )
            v_all = jnp.einsum(
                "bte,ehd->bthd", enc_kv, p["cross"]["wv"].astype(x.dtype)
            )
        out, _ = attn_mod.gqa_apply(
            p["cross"], h, cfg, positions,
            causal=False,
            kv_override=(k_all, v_all, enc_mask),
        )
        x = x + rs * out
        if make_cache or cache is not None:
            new_cache["cross"] = (k_all, v_all)

    if kind.ffn == "rwkv_cm":
        state = None if cache is None else cache.get("rwkv")
        state = new_cache.get("rwkv", state)  # shift state updated by time mix
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        out, c = rwkv_mod.channel_mix(p["mix"], h, state)
        if c is not None:
            new_cache["rwkv"] = c
        x = x + rs * out
    elif kind.ffn is not None:
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if kind.ffn == "mlp":
            out = mlp_apply(p["ffn"], h, cfg)
        else:
            out, aux = moe_apply(p["ffn"], h, cfg)
        x = x + rs * out

    return x, aux, (new_cache if new_cache else None)


def _group_body(
    layer_ps,
    x,
    caches,
    cfg: ModelConfig,
    stage: Stage,
    positions,
    mode: str,
    cache_len: int,
    enc_kv,
    enc_mask,
):
    """Apply one group of stage.kinds layers. caches: dict l{i} -> cache."""
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {}
    for i, kind in enumerate(stage.kinds):
        x, aux, nc = apply_layer(
            layer_ps[f"l{i}"], x, cfg, kind, positions,
            cache=None if caches is None else caches.get(f"l{i}"),
            make_cache=(mode == "prefill"),
            cache_len=cache_len,
            enc_kv=enc_kv,
            enc_mask=enc_mask,
        )
        aux_total = aux_total + aux
        if nc is not None:
            new_caches[f"l{i}"] = nc
    return x, aux_total, (new_caches if new_caches else None)


def stage_apply(
    stage_ps,
    x: jax.Array,
    cfg: ModelConfig,
    stage: Stage,
    positions: jax.Array,
    *,
    mode: str = "train",          # train | prefill | decode
    caches=None,                  # stacked over repeats for decode
    cache_len: int = 0,
    enc_kv=None,                  # (k, v) encoder cross KV (B, T, H, D)
    enc_mask=None,
    remat: bool = True,
):
    """Scan the stage over its repeats. Returns (x, aux, new_caches)."""

    def body(carry, xs):
        x, aux = carry
        layer_ps, caches_l = xs
        x, aux_i, new_c = _group_body(
            layer_ps, x, caches_l, cfg, stage, positions, mode, cache_len,
            enc_kv, enc_mask,
        )
        return (x, aux + aux_i), new_c

    body_fn = jax.checkpoint(body) if (remat and mode == "train") else body

    caches_xs = caches  # stacked pytree or None
    (x, aux), new_caches = jax.lax.scan(
        body_fn, (x, jnp.zeros((), jnp.float32)), (stage_ps, caches_xs),
        length=stage.repeats,
    )
    return x, aux, new_caches
