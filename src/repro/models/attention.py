"""Attention: GQA (+RoPE) and MLA (DeepSeek), train/prefill/decode paths.

Sharding: query heads shard over the model axis when divisible; otherwise
the resolver falls back and the sequence dim carries the model axis
(sequence-parallel attention with KV gathered by XLA). Decode caches are
context-parallel: (batch->data, cache_seq->model), which XLA turns into
flash-decoding-style partial softmax + combine. On real TPUs the serving
engine swaps the decode einsum for kernels/decode_attention; the einsum
path keeps the dry-run HLO clean on the CPU backend (see DESIGN.md).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Builder, rope
from repro.sharding.rules import attn_q_axes, shard_activation

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array       # (B, S, KV, Dk)  [MLA: KV=1, Dk=kv_lora+rope]
    v: jax.Array       # (B, S, KV, Dv)  [MLA: unused placeholder dims ok]
    length: jax.Array  # (B,) int32 — tokens currently in the cache


# ----------------------------------------------------------------- GQA


def gqa_params(b: Builder, cfg: ModelConfig):
    e, h, kv, d = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": b.param((e, h, d), ("embed", "heads", "head_dim")),
        "wk": b.param((e, kv, d), ("embed", "kv_heads", "head_dim")),
        "wv": b.param((e, kv, d), ("embed", "kv_heads", "head_dim")),
        "wo": b.param((h, d, e), ("heads", "head_dim", "embed")),
    }


def _sdpa(q, k, v, mask, scale):
    """q (B,Sq,H,D), k/v (B,Sk,KV,D'), mask: None | (q_pos, k_pos) lazy
    causal pair | (B, Sk) boolean KV validity | (B, Sq, Sk) boolean.

    The causal mask is folded into the select as broadcast iota
    comparisons so no (B,Sq,Sk) buffer is materialised per head.
    """
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qf = q.astype(jnp.float32).reshape(b, sq, kvh, g, d)
    scores = jnp.einsum("bqngd,bknd->bnqgk", qf, k.astype(jnp.float32)) * scale
    if mask is not None:
        if isinstance(mask, tuple):
            q_pos, k_pos = mask  # lazy causal: (B,Sq), (B,Sk)
            ok = q_pos[:, None, :, None, None] >= k_pos[:, None, None, None, :]
        elif mask.ndim == 2:  # (B, Sk) validity
            ok = mask[:, None, None, None, :]
        else:  # (B, Sq, Sk)
            ok = mask[:, None, :, None, :]
        scores = jnp.where(ok, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bnqgk,bknd->bqngd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


ATTN_CHUNK = 1024
CHUNKED_ATTN = False  # opt-in; see gqa_apply note + EXPERIMENTS §Perf it.5


def _sdpa_chunked(q, k, v, positions, scale, causal=True, chunk=ATTN_CHUNK):
    """Online-softmax attention over KV chunks (flash-style in pure JAX).

    Streams K/V in (chunk,)-blocks with running (m, l, acc) statistics,
    so no (Sq, Sk) score tensor ever exists in HBM — per-chunk scores
    live only inside the scan body (remat'd in backward). §Perf
    iteration 5. Shapes as _sdpa; positions: (B, S) for causal masking.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    dv = v.shape[-1]
    f32 = jnp.float32
    qf = q.astype(f32).reshape(b, sq, kvh, g, d)
    n_chunks = sk // chunk

    ks = jnp.moveaxis(k.reshape(b, n_chunks, chunk, kvh, d), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, n_chunks, chunk, kvh, dv), 1, 0)
    kpos = jnp.moveaxis(positions.reshape(b, n_chunks, chunk), 1, 0)

    def body(carry, inp):
        m, l, acc = carry
        k_c, v_c, kp = inp
        s = jnp.einsum("bqngd,bknd->bnqgk", qf, k_c.astype(f32)) * scale
        if causal:
            ok = positions[:, None, :, None, None] >= kp[:, None, None, None, :]
            s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        pexp = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(pexp, axis=-1)
        acc = alpha[..., None] * acc + jnp.einsum(
            "bnqgk,bknd->bnqgd", pexp, v_c.astype(f32)
        )
        return (m_new, l, acc), None

    m0 = jnp.full((b, kvh, sq, g), NEG_INF, f32)
    l0 = jnp.zeros((b, kvh, sq, g), f32)
    a0 = jnp.zeros((b, kvh, sq, g, dv), f32)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0), (ks, vs, kpos)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).reshape(b, sq, h, dv).astype(q.dtype)


def gqa_apply(
    p,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    causal: bool = True,
    cache: Optional[KVCache] = None,
    make_cache: bool = False,
    cache_len: int = 0,
    kv_override: Optional[tuple] = None,
):
    """Unified attention path.

    Modes:
      train:   cache=None, make_cache=False -> (out, None)
      prefill: make_cache=True -> (out, KVCache) [cache_len = allocation]
      decode:  cache=KVCache, x is (B, 1, E) -> (out, updated KVCache)
      cross:   kv_override=(k_src, v_src, src_mask) from encoder output
    """
    b, s, e = x.shape
    h, kvh, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    scale = d ** -0.5

    q_axes = attn_q_axes(h)
    q = jnp.einsum("bse,ehd->bshd", x, p["wq"].astype(x.dtype))
    q = shard_activation(q, q_axes)

    if kv_override is not None:
        # Cross-attention: no RoPE, KV comes from the encoder output.
        k_all, v_all, src_mask = kv_override
        out = _sdpa(q, k_all, v_all, src_mask, scale)
        new_cache = None
    elif cache is None:
        k = jnp.einsum("bse,ehd->bshd", x, p["wk"].astype(x.dtype))
        v = jnp.einsum("bse,ehd->bshd", x, p["wv"].astype(x.dtype))
        if causal:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
            q = shard_activation(q, q_axes)
        k = shard_activation(k, ("act_batch", None, "act_kv_heads", None))
        v = shard_activation(v, ("act_batch", None, "act_kv_heads", None))
        # Chunked (flash-style) attention is opt-in: §Perf iteration 5
        # measured it NEUTRAL-to-slightly-worse on the HBM-traffic metric
        # once iterations 1-4 had removed the score-softmax passes from
        # the critical set (the f32 online-softmax carries offset the
        # saved passes); it still bounds *peak* score memory, so serving
        # configs with very long prefills may enable it.
        if CHUNKED_ATTN and s >= 2 * ATTN_CHUNK and s % ATTN_CHUNK == 0:
            out = _sdpa_chunked(q, k, v, positions, scale, causal=causal)
        else:
            mask = (positions, positions) if causal else None
            out = _sdpa(q, k, v, mask, scale)
        out = shard_activation(out, q_axes)
        new_cache = None
        if make_cache:
            pad = cache_len - s
            kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            kc = shard_activation(kc, ("cache_batch", "cache_seq", None, None))
            vc = shard_activation(vc, ("cache_batch", "cache_seq", None, None))
            new_cache = KVCache(
                k=kc, v=vc, length=jnp.full((b,), s, jnp.int32)
            )
    else:
        # Decode: s == 1 new token at per-sequence position cache.length.
        q = rope(q, positions, cfg.rope_theta)
        k_new = jnp.einsum("bse,ehd->bshd", x, p["wk"].astype(x.dtype))
        v_new = jnp.einsum("bse,ehd->bshd", x, p["wv"].astype(x.dtype))
        k_new = rope(k_new, positions, cfg.rope_theta)
        bidx = jnp.arange(b)
        kc = cache.k.at[bidx, cache.length].set(
            k_new[:, 0].astype(cache.k.dtype)
        )
        vc = cache.v.at[bidx, cache.length].set(
            v_new[:, 0].astype(cache.v.dtype)
        )
        kc = shard_activation(kc, ("cache_batch", "cache_seq", None, None))
        vc = shard_activation(vc, ("cache_batch", "cache_seq", None, None))
        new_len = cache.length + 1
        s_cache = kc.shape[1]
        kv_mask = (
            jnp.arange(s_cache)[None, :] < new_len[:, None]
        )  # (B, S_cache)
        out = _sdpa(q, kc, vc, kv_mask, scale)
        new_cache = KVCache(k=kc, v=vc, length=new_len)

    out = jnp.einsum("bshd,hde->bse", out, p["wo"].astype(x.dtype))
    return shard_activation(out, ("act_batch", "act_seq", None)), new_cache


# ----------------------------------------------------------------- MLA


def mla_params(b: Builder, cfg: ModelConfig):
    e, h = cfg.d_model, cfg.n_heads
    ql, kvl = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    p = {
        "w_dkv": b.param((e, kvl), ("embed", "kv_lora")),
        "kv_norm": b.param((kvl,), ("norm",), init="ones"),
        "w_kr": b.param((e, dr), ("embed", None)),
        "w_uk": b.param((kvl, h, dn), ("kv_lora", "heads", "head_dim")),
        "w_uv": b.param((kvl, h, dv), ("kv_lora", "heads", "head_dim")),
        "w_o": b.param((h, dv, e), ("heads", "head_dim", "embed")),
    }
    if ql:
        p["w_dq"] = b.param((e, ql), ("embed", "q_lora"))
        p["q_norm"] = b.param((ql,), ("norm",), init="ones")
        p["w_uq"] = b.param((ql, h, dn + dr), ("q_lora", "heads", "head_dim"))
    else:
        p["w_q"] = b.param((e, h, dn + dr), ("embed", "heads", "head_dim"))
    return p


def _mla_q(p, x, cfg):
    from repro.models.common import rmsnorm

    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        cq = jnp.einsum("bse,er->bsr", x, p["w_dq"].astype(x.dtype))
        cq = rmsnorm({"scale": p["q_norm"]}, cq, cfg.norm_eps)
        q = jnp.einsum("bsr,rhd->bshd", cq, p["w_uq"].astype(x.dtype))
    else:
        q = jnp.einsum("bse,ehd->bshd", x, p["w_q"].astype(x.dtype))
    return q[..., :dn], q[..., dn:]  # nope, rope parts


def mla_apply(
    p,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    causal: bool = True,
    cache: Optional[KVCache] = None,
    make_cache: bool = False,
    cache_len: int = 0,
    kv_override=None,
):
    """MLA attention. Cache stores the compressed (c_kv | k_rope) stream
    as a single-"head" KV (MQA-like); decode uses weight absorption."""
    from repro.models.common import rmsnorm

    b, s, e = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvl = cfg.kv_lora_rank
    scale = (dn + dr) ** -0.5

    q_nope, q_rope = _mla_q(p, x, cfg)
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    q_nope = shard_activation(q_nope, ("act_batch", "act_seq", "act_heads", None))

    c_kv = jnp.einsum("bse,er->bsr", x, p["w_dkv"].astype(x.dtype))
    c_kv = rmsnorm({"scale": p["kv_norm"]}, c_kv, cfg.norm_eps)
    k_rope = jnp.einsum("bse,ed->bsd", x, p["w_kr"].astype(x.dtype))
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    if cache is None:
        # Train/prefill: expand per-head keys/values from the compressed kv.
        k_nope = jnp.einsum("bsr,rhd->bshd", c_kv, p["w_uk"].astype(x.dtype))
        v = jnp.einsum("bsr,rhd->bshd", c_kv, p["w_uv"].astype(x.dtype))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))],
            axis=-1,
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        mask = (positions, positions) if causal else None
        out = _sdpa(q, k, v, mask, scale)
        new_cache = None
        if make_cache:
            comp = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None, :]
            pad = cache_len - s
            comp = jnp.pad(comp, ((0, 0), (0, pad), (0, 0), (0, 0)))
            comp = shard_activation(comp, ("cache_batch", "cache_seq", None, None))
            new_cache = KVCache(
                k=comp,
                v=jnp.zeros((b, 0, 0, 0), comp.dtype),  # folded into k
                length=jnp.full((b,), s, jnp.int32),
            )
    else:
        # Decode with weight absorption: score against the compressed cache.
        comp_new = jnp.concatenate([c_kv, k_rope], axis=-1)  # (B, 1, kvl+dr)
        bidx = jnp.arange(b)
        kc = cache.k.at[bidx, cache.length].set(
            comp_new[:, 0, :][:, None, :].astype(cache.k.dtype)
        )
        kc = shard_activation(kc, ("cache_batch", "cache_seq", None, None))
        new_len = cache.length + 1
        s_cache = kc.shape[1]
        c_cache = kc[:, :, 0, :kvl]          # (B, S, kvl)
        r_cache = kc[:, :, 0, kvl:]          # (B, S, dr)
        # Absorb W_uk into q: q_c (B,1,H,kvl).
        q_c = jnp.einsum("bshd,rhd->bshr", q_nope, p["w_uk"].astype(x.dtype))
        scores = (
            jnp.einsum("bshr,bkr->bshk", q_c, c_cache.astype(x.dtype))
            + jnp.einsum("bshd,bkd->bshk", q_rope, r_cache.astype(x.dtype))
        ).astype(jnp.float32) * scale
        kv_mask = jnp.arange(s_cache)[None, :] < new_len[:, None]
        scores = jnp.where(kv_mask[:, None, None, :], scores, NEG_INF)
        pr = jax.nn.softmax(scores, axis=-1)
        ctx_c = jnp.einsum("bshk,bkr->bshr", pr.astype(x.dtype), c_cache.astype(x.dtype))
        out = jnp.einsum("bshr,rhd->bshd", ctx_c, p["w_uv"].astype(x.dtype))
        new_cache = KVCache(k=kc, v=cache.v, length=new_len)

    out = jnp.einsum("bshd,hde->bse", out, p["w_o"].astype(x.dtype))
    return shard_activation(out, ("act_batch", "act_seq", None)), new_cache


def attn_params(b: Builder, cfg: ModelConfig):
    return mla_params(b, cfg) if cfg.attn_type == "mla" else gqa_params(b, cfg)


def attn_apply(p, x, cfg, positions, **kw):
    fn = mla_apply if cfg.attn_type == "mla" else gqa_apply
    return fn(p, x, cfg, positions, **kw)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> KVCache:
    """Empty cache for one attention layer."""
    if cfg.attn_type == "mla":
        dk = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        return KVCache(
            k=jnp.zeros((batch, cache_len, 1, dk), dtype),
            v=jnp.zeros((batch, 0, 0, 0), dtype),
            length=jnp.zeros((batch,), jnp.int32),
        )
    return KVCache(
        k=jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        v=jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )
