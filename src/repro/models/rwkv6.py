"""RWKV-6 "Finch" blocks — attention-free, data-dependent decay
(arXiv:2404.05892). Implements the time-mix (ddlerp token shift, LoRA
decay, per-head wkv state recurrence with bonus u) and channel-mix
halves. The recurrence runs as a lax.scan over time in training (compact
HLO for the dry-run) and as a single state update in decoding — O(1)
state means the long_500k serving shape is trivial for this arch.

State per layer: wkv (B, H, Dk, Dv) + the last-token shift buffers.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Builder
from repro.sharding.rules import shard_activation

LORA_MIX = 32
LORA_DECAY = 64


class RWKVState(NamedTuple):
    wkv: jax.Array        # (B, H, Dk, Dv)
    shift_tm: jax.Array   # (B, E) previous token (time mix)
    shift_cm: jax.Array   # (B, E) previous token (channel mix)


def rwkv_params(b: Builder, cfg: ModelConfig):
    e = cfg.d_model
    h, d = cfg.n_heads, cfg.head_dim
    f = cfg.d_ff
    return {
        # time mix
        "mu_base": b.param((5, e), (None, "embed"), init="zeros"),
        "tm_w1": b.param((e, 5 * LORA_MIX), ("embed", None), scale=0.01),
        "tm_w2": b.param((5, LORA_MIX, e), (None, None, "embed"), scale=0.01),
        "w0": b.param((e,), ("embed",), init="zeros"),
        "td_w1": b.param((e, LORA_DECAY), ("embed", None), scale=0.01),
        "td_w2": b.param((LORA_DECAY, e), (None, "embed"), scale=0.01),
        "u": b.param((h, d), ("heads", None), scale=0.5),
        "wr": b.param((e, e), ("embed", "ff")),
        "wk": b.param((e, e), ("embed", "ff")),
        "wv": b.param((e, e), ("embed", "ff")),
        "wg": b.param((e, e), ("embed", "ff")),
        "wo": b.param((e, e), ("ff", "embed")),
        "ln_x_scale": b.param((e,), ("norm",), init="ones"),
        # channel mix
        "cm_mu_k": b.param((e,), ("embed",), init="zeros"),
        "cm_mu_r": b.param((e,), ("embed",), init="zeros"),
        "cm_wk": b.param((e, f), ("embed", "ff")),
        "cm_wv": b.param((f, e), ("ff", "embed")),
        "cm_wr": b.param((e, e), ("embed", None)),
    }


def _ddlerp(p, x: jax.Array, x_prev: jax.Array):
    """Data-dependent lerp producing the 5 mixed streams (r,k,v,w,g)."""
    dx = x_prev - x
    base = x + dx * jax.nn.sigmoid(p["mu_base"]).astype(x.dtype)[:, None, :].swapaxes(0, 1) \
        if False else None
    # (B, S, 5*LORA) -> (B, S, 5, LORA)
    mixed = jnp.tanh(
        jnp.einsum("bse,el->bsl", x + 0.5 * dx, p["tm_w1"].astype(x.dtype))
    )
    b_, s_, _ = x.shape
    mixed = mixed.reshape(b_, s_, 5, LORA_MIX)
    delta = jnp.einsum("bsnl,nle->bsne", mixed, p["tm_w2"].astype(x.dtype))
    mu = jax.nn.sigmoid(p["mu_base"].astype(jnp.float32)).astype(x.dtype)
    mix = mu[None, None] + delta                       # (B, S, 5, E)
    return x[:, :, None, :] + dx[:, :, None, :] * mix


SCAN_UNROLL = 16
WKV_CHUNK = 16


def _wkv_chunked(r, k, v, logw, u, state):
    """Chunk-parallel WKV (flash-linear-attention style), §Perf iter 2.

    Derivation (per head, channel d; step form: o_t = r_t·S_{t-1} +
    (r_t·(u⊙k_t))v_t;  S_t = w_t⊙S_{t-1} + k_t⊗v_t):
      cl[t]  = Σ_{j<=t} log w_j   (cumulative, <= 0)
      o_t    = r_t·(e^{cl[t-1]}⊙S0)                      [inter, matmul]
             + Σ_{i<t} (Σ_d r_t k_i e^{cl[t-1]-cl[i]}) v_i [intra, einsum]
             + (r_t·(u⊙k_t)) v_t                          [diagonal]
      S_end  = e^{cl[L-1]}⊙S0 + Σ_i e^{cl[L-1]-cl[i]}⊙(k_i⊗v_i)
    Every exponent is a *difference of later-minus-earlier* cumulative
    log-decays, so every factor is <= 1 — no overflow, unlike the
    r'=r·e^{cl}, k'=k·e^{-cl} factorisation. Intra-chunk terms for all
    chunks compute as batched einsums (MXU); only the tiny state update
    scans across chunks, so the (B,H,Dk,Dv) state crosses HBM once per
    chunk instead of every timestep.

    r/k/v/logw: (B,T,H,D) f32; u: (H,D); state: (B,H,Dk,Dv).
    """
    b, t, h, d = r.shape
    L = WKV_CHUNK
    n = t // L
    rs = r.reshape(b, n, L, h, d)
    ks = k.reshape(b, n, L, h, d)
    vs = v.reshape(b, n, L, h, d)
    wl = logw.reshape(b, n, L, h, d)

    cl = jnp.cumsum(wl, axis=2)                     # (B,n,L,H,D), <= 0
    clm1 = jnp.pad(cl, ((0, 0), (0, 0), (1, 0), (0, 0), (0, 0)))[:, :, :-1]
    cl_tot = cl[:, :, -1]                           # (B,n,H,D)

    # Intra-chunk: pairwise decays (strictly lower-triangular in (t,i)).
    diff = clm1[:, :, :, None] - cl[:, :, None]     # (B,n,L,L,H,D)
    tri = (
        jnp.arange(L)[:, None] > jnp.arange(L)[None, :]
    )[None, None, :, :, None, None]
    d_pair = jnp.where(tri, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    a_mat = jnp.einsum("bnthd,bntihd,bnihd->bntih", rs, d_pair, ks)
    o_intra = jnp.einsum("bntih,bnihv->bnthv", a_mat, vs)
    diag = jnp.einsum("bnthd,hd,bnthd->bnth", rs, u, ks)
    o_intra = o_intra + diag[..., None] * vs

    # Inter-chunk: sequential state pass (small per-chunk einsums).
    r2 = rs * jnp.exp(clm1)                         # decays <= 1
    k2 = ks * jnp.exp(cl_tot[:, :, None] - cl)      # decays <= 1
    s_delta = jnp.einsum("bnlhd,bnlhv->bnhdv", k2, vs)
    decay_tot = jnp.exp(cl_tot)                     # (B,n,H,D)

    def chunk_step(s, inp):
        r2_c, sd_c, dt_c = inp                      # per-chunk slices
        o = jnp.einsum("blhd,bhdv->blhv", r2_c, s)
        s = dt_c[..., None] * s + sd_c
        return s, o

    xs = (
        jnp.moveaxis(r2, 1, 0),
        jnp.moveaxis(s_delta, 1, 0),
        jnp.moveaxis(decay_tot, 1, 0),
    )
    s_final, o_inter = jax.lax.scan(chunk_step, state, xs)
    o_inter = jnp.moveaxis(o_inter, 0, 1)           # (B,n,L,H,Dv)

    out = (o_intra + o_inter).reshape(b, t, h, d)
    return out, s_final


def _wkv_scan(r, k, v, w, u, state):
    """Recurrence: S_t = diag(w_t) S + k_t^T v_t; o_t = r_t (S + u k_t^T v_t).

    r/k/w: (B, T, H, Dk); v: (B, T, H, Dv); u: (H, Dk);
    state: (B, H, Dk, Dv). Returns (out (B,T,H,Dv), new state).

    unroll=16 so the (B,H,Dk,Dv) wkv state and the per-step outer
    products stay fused inside one loop body per 16 timesteps (§Perf
    iteration 1); state sharded over heads.
    """
    from repro.sharding.rules import shard_activation

    state = shard_activation(state, ("act_batch", "act_heads", None, None))

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp  # (B, H, D*)
        a = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        o = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * a)
        s = w_t[..., None] * s + a
        return s, o

    rs, ks, vs, ws = (jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    t_len = r.shape[1]
    unroll = SCAN_UNROLL if t_len % SCAN_UNROLL == 0 else 1
    new_state, outs = jax.lax.scan(step, state, (rs, ks, vs, ws), unroll=unroll)
    return jnp.moveaxis(outs, 0, 1), new_state


def time_mix(
    p,
    x: jax.Array,
    cfg: ModelConfig,
    state: Optional[RWKVState],
) -> Tuple[jax.Array, Optional[RWKVState]]:
    b, s, e = x.shape
    h, d = cfg.n_heads, cfg.head_dim
    f32 = jnp.float32

    if state is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        shift_out = x[:, -1, :]
    else:
        x_prev = jnp.concatenate([state.shift_tm[:, None, :], x[:, :-1]], axis=1)
        shift_out = x[:, -1, :]

    streams = _ddlerp(p, x, x_prev)                    # (B, S, 5, E)
    xr, xk, xv, xw, xg = (streams[:, :, i, :] for i in range(5))

    r = jnp.einsum("bse,ef->bsf", xr, p["wr"].astype(x.dtype)).reshape(b, s, h, d)
    k = jnp.einsum("bse,ef->bsf", xk, p["wk"].astype(x.dtype)).reshape(b, s, h, d)
    v = jnp.einsum("bse,ef->bsf", xv, p["wv"].astype(x.dtype)).reshape(b, s, h, d)
    g = jnp.einsum("bse,ef->bsf", xg, p["wg"].astype(x.dtype))
    r = shard_activation(r, ("act_batch", "act_seq", "act_heads", None))
    k = shard_activation(k, ("act_batch", "act_seq", "act_heads", None))
    v = shard_activation(v, ("act_batch", "act_seq", "act_heads", None))

    # Data-dependent decay in (0, 1): w = exp(-exp(w0 + lora(xw))).
    dw = jnp.einsum(
        "bsl,le->bse",
        jnp.tanh(jnp.einsum("bse,el->bsl", xw, p["td_w1"].astype(x.dtype))),
        p["td_w2"].astype(x.dtype),
    )
    logw = p["w0"].astype(f32)[None, None] + dw.astype(f32)
    log_decay = -jnp.exp(logw).reshape(b, s, h, d)  # log w_t <= 0

    wkv0 = (
        state.wkv if state is not None else jnp.zeros((b, h, d, d), f32)
    )
    from repro.sharding.rules import shard_activation as _sa

    wkv0 = _sa(wkv0, ("act_batch", "act_heads", None, None))
    if s % WKV_CHUNK == 0 and s >= 2 * WKV_CHUNK:
        out, wkv1 = _wkv_chunked(
            r.astype(f32), k.astype(f32), v.astype(f32), log_decay,
            p["u"].astype(f32), wkv0,
        )
    else:
        out, wkv1 = _wkv_scan(
            r.astype(f32), k.astype(f32), v.astype(f32),
            jnp.exp(log_decay), p["u"].astype(f32), wkv0,
        )
    out = out.reshape(b, s, e).astype(x.dtype)

    # Per-head group norm, then gate and project.
    out = out.reshape(b, s, h, d)
    mean = jnp.mean(out, axis=-1, keepdims=True)
    var = jnp.var(out, axis=-1, keepdims=True)
    out = ((out - mean) * jax.lax.rsqrt(var + 1e-5)).reshape(b, s, e)
    out = out * p["ln_x_scale"].astype(x.dtype)
    out = out * jax.nn.silu(g)
    out = jnp.einsum("bse,ef->bsf", out, p["wo"].astype(x.dtype))
    out = shard_activation(out, ("act_batch", "act_seq", None))

    new_state = None
    if state is not None:
        new_state = RWKVState(
            wkv=wkv1, shift_tm=shift_out, shift_cm=state.shift_cm
        )
    return out, new_state


def channel_mix(
    p, x: jax.Array, state: Optional[RWKVState]
) -> Tuple[jax.Array, Optional[RWKVState]]:
    if state is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        x_prev = jnp.concatenate([state.shift_cm[:, None, :], x[:, :-1]], axis=1)
    dx = x_prev - x
    mu_k = jax.nn.sigmoid(p["cm_mu_k"].astype(jnp.float32)).astype(x.dtype)
    mu_r = jax.nn.sigmoid(p["cm_mu_r"].astype(jnp.float32)).astype(x.dtype)
    xk = x + dx * mu_k
    xr = x + dx * mu_r
    k = jnp.einsum("bse,ef->bsf", xk, p["cm_wk"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    k = shard_activation(k, ("act_batch", "act_seq", "act_ff"))
    kv = jnp.einsum("bsf,fe->bse", k, p["cm_wv"].astype(x.dtype))
    r = jax.nn.sigmoid(
        jnp.einsum("bse,ef->bsf", xr, p["cm_wr"].astype(x.dtype))
    )
    out = r * kv
    new_state = None
    if state is not None:
        new_state = state._replace(shift_cm=x[:, -1, :])
    return shard_activation(out, ("act_batch", "act_seq", None)), new_state


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> RWKVState:
    h, d, e = cfg.n_heads, cfg.head_dim, cfg.d_model
    return RWKVState(
        wkv=jnp.zeros((batch, h, d, d), jnp.float32),
        shift_tm=jnp.zeros((batch, e), dtype),
        shift_cm=jnp.zeros((batch, e), dtype),
    )
