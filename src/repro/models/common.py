"""Shared model building blocks + the dual-mode parameter Builder.

Every parameter is declared once via Builder.param(shape, axes): in
"init" mode it returns an initialised array, in "axes" mode the logical
axis tuple — so the sharding spec tree can never drift from the param
tree (tests assert they match for every arch).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(name: str) -> jnp.dtype:
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


class Builder:
    """Dual-mode parameter factory (init arrays / logical axes)."""

    def __init__(self, mode: str, key: Optional[jax.Array], dtype: jnp.dtype):
        assert mode in ("init", "axes")
        self.mode = mode
        self._key = key
        self.dtype = dtype

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(
        self,
        shape: Sequence[int],
        axes: Sequence[Optional[str]],
        *,
        init: str = "normal",
        scale: Optional[float] = None,
    ):
        assert len(shape) == len(axes), (shape, axes)
        if self.mode == "axes":
            return tuple(axes)
        if init == "zeros":
            return jnp.zeros(tuple(shape), self.dtype)
        if init == "ones":
            return jnp.ones(tuple(shape), self.dtype)
        if init == "normal":
            if scale is None:
                scale = shape[0] ** -0.5  # fan-in scaling
            x = scale * jax.random.normal(
                self._next_key(), tuple(shape), jnp.float32
            )
            return x.astype(self.dtype)
        raise ValueError(init)


def build_params(fn, cfg: ModelConfig, key: jax.Array):
    """Run a param-declaring fn in init mode."""
    return fn(Builder("init", key, dtype_of(cfg.param_dtype)), cfg)


def build_axes(fn, cfg: ModelConfig):
    """Run the same fn in axes mode (no keys, no allocation)."""
    return fn(Builder("axes", None, dtype_of(cfg.param_dtype)), cfg)


def stacked_init(fn, cfg: ModelConfig, key: jax.Array, n: int):
    """vmap-init n stacked copies (for lax.scan over layers)."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: build_params(fn, cfg, k))(keys)


def stacked_axes(fn, cfg: ModelConfig):
    """Axes for stacked params: prepend the scanned 'layers' dim."""
    axes = build_axes(fn, cfg)
    return jax.tree_util.tree_map(
        lambda a: ("layers",) + a,
        axes,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


# ------------------------------------------------------------------ layers


def rmsnorm_params(b: Builder, dim: int):
    return {"scale": b.param((dim,), ("norm",), init="ones")}


def rmsnorm(p, x: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, S, H, D) [D even]; positions: (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def embed_params(b: Builder, cfg: ModelConfig):
    v = cfg.vocab_padded
    p = {"tok": b.param((v, cfg.d_model), ("vocab", "embed"), scale=1.0)}
    if not cfg.tie_embeddings:
        p["head"] = b.param(
            (cfg.d_model, v), ("embed", "vocab"),
            scale=cfg.d_model ** -0.5,
        )
    return p


def embed_lookup(p, tokens: jax.Array, cfg: ModelConfig, compute_dtype) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0).astype(compute_dtype)
    return x * cfg.scale_emb


def lm_logits(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    from repro.sharding.rules import shard_activation

    if cfg.tie_embeddings:
        w = p["tok"].astype(x.dtype).T
    else:
        w = p["head"].astype(x.dtype)
    logits = cfg.scale_logits * jnp.einsum("bse,ev->bsv", x, w).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab:
        # Mask pad entries so CE/argmax never see them.
        pad = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = logits + jnp.where(pad, -1e30, 0.0)
    # Keep logits vocab-sharded: at 100k+ vocabs an unsharded fp32 logits
    # tensor is tens of GB per device (see EXPERIMENTS §Perf iteration 1).
    return shard_activation(logits, ("act_batch", "act_seq", "act_vocab"))


def residual_scale(cfg: ModelConfig) -> float:
    """MiniCPM-style depth-scaled residual branches."""
    if cfg.scale_depth > 0:
        return cfg.scale_depth / (cfg.n_layers ** 0.5)
    return 1.0


def cross_entropy(
    logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None
) -> jax.Array:
    """Mean next-token CE in fp32. logits (B,S,V), labels (B,S) int.

    Shard-friendly formulation: the target logit is extracted with a
    one-hot product (reduces over the vocab dim, which may be sharded)
    instead of take_along_axis (whose gather would force an all-gather
    of the logits when vocab is model-sharded).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    tgt = jnp.sum(logits * onehot, axis=-1)
    ll = tgt - lse
    if mask is None:
        return -jnp.mean(ll)
    mask = mask.astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
