"""SwiGLU feed-forward (+ the paper's analog-crossbar execution mode).

With cfg.analog_mvm the projections run through the ideal-analog
crossbar simulation (kernels/imac_mvm.analog_linear: DAC quantisation,
differential conductance levels, optional read noise) — IMAC as an
inference accelerator for the FFN weights, paper ref [1].
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Builder
from repro.sharding.rules import shard_activation


def mlp_params(b: Builder, cfg: ModelConfig, d_ff: int = 0):
    e = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "w_gate": b.param((e, f), ("embed", "ff")),
        "w_up": b.param((e, f), ("embed", "ff")),
        "w_down": b.param((f, e), ("ff", "embed")),
    }


def _proj(x, w, cfg: Optional[ModelConfig]):
    if cfg is not None and cfg.analog_mvm:
        from repro.kernels.imac_mvm.ops import analog_linear

        lead = x.shape[:-1]
        y = analog_linear(
            x.reshape(-1, x.shape[-1]), w.astype(jnp.float32), None,
            tech=cfg.analog_tech,
        )
        return y.reshape(*lead, w.shape[-1]).astype(x.dtype)
    return jnp.einsum("bse,ef->bsf", x, w.astype(x.dtype))


def mlp_apply(p, x: jax.Array, cfg: Optional[ModelConfig] = None) -> jax.Array:
    g = _proj(x, p["w_gate"], cfg)
    u = _proj(x, p["w_up"], cfg)
    h = jax.nn.silu(g) * u
    h = shard_activation(h, ("act_batch", "act_seq", "act_ff"))
    out = _proj(h, p["w_down"], cfg)
    return shard_activation(out, ("act_batch", "act_seq", None))
