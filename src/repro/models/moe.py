"""Mixture-of-Experts with grouped, sort-based capacity dispatch.

Shardability is the design driver: a *global* argsort/gather over all
tokens cannot be partitioned along the token dim (indices span every
shard), which at deepseek scale materialises (tokens*k, d_model) buffers
per device. Instead tokens are split into G groups laid out on the DP
mesh axes — the same "route within your data-parallel rank" semantics
real EP systems use — and every routing op (top-k, argsort, rank-within-
expert, gather, scatter) is batched over G, so XLA shards them as plain
batched ops: G over (pod, data), experts over model (EP), d_model over
data (FSDP weight gather at use).

Capacity is per (expert, group): C = ceil(T_g * k / E * capacity_factor)
— tokens beyond it drop (standard dropping semantics; the aux
load-balance loss keeps drops rare).

Includes a DeepSeek-style shared-expert branch and load-balance +
router-z auxiliary losses.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Builder
from repro.models.mlp import mlp_apply, mlp_params
from repro.sharding.rules import shard_activation


def moe_params(b: Builder, cfg: ModelConfig):
    e, x, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    p = {
        "router": b.param((x, e), ("embed", "experts"), scale=0.02),
        "w_gate": b.param((e, x, f), ("experts", "embed", "moe_ff")),
        "w_up": b.param((e, x, f), ("experts", "embed", "moe_ff")),
        "w_down": b.param((e, f, x), ("experts", "moe_ff", "embed")),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_params(b, cfg, d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    return p


def _n_groups(n_tokens: int) -> int:
    """Largest DP-friendly group count dividing the token count."""
    for g in (32, 16, 8, 4, 2):
        if n_tokens % g == 0 and n_tokens // g >= 1:
            return g
    return 1


def _capacity(tokens_per_group: int, cfg: ModelConfig) -> int:
    c = math.ceil(
        tokens_per_group * cfg.experts_per_token / cfg.n_experts
        * cfg.capacity_factor
    )
    return max(4, int(c))


def moe_apply(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, E) -> (out, aux_loss)."""
    bsz, seq, d = x.shape
    n_tok = bsz * seq
    ne, k = cfg.n_experts, cfg.experts_per_token
    g = _n_groups(n_tok)
    tg = n_tok // g
    cap = _capacity(tg, cfg)

    xt = x.reshape(g, tg, d)
    xt = shard_activation(xt, ("act_batch", None, None))

    # ---- routing (fp32) --------------------------------------------------
    logits = jnp.einsum(
        "gtd,de->gte", xt.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)              # (g, tg, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # ---- grouped sort-based dispatch ------------------------------------
    flat_e = top_e.reshape(g, tg * k)
    sidx = jnp.argsort(flat_e, axis=-1)                  # (g, tg*k)
    sorted_e = jnp.take_along_axis(flat_e, sidx, axis=-1)
    # rank of each sorted entry within its expert run
    iota = jnp.broadcast_to(jnp.arange(tg * k), (g, tg * k))
    starts = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(ne), side="left")
    )(sorted_e)                                          # (g, ne)
    rank = iota - jnp.take_along_axis(
        starts, sorted_e, axis=-1
    )
    keep = rank < cap
    dest = jnp.where(keep, sorted_e * cap + rank, ne * cap)  # (g, tg*k)
    token_of = sidx // k

    # Batched row gather/scatter with (g, n) index vectors — NOT
    # take_along_axis, whose index broadcast over d materialises a
    # (g, n, d) u32 tensor (15 GB/device at deepseek scale).
    picked = jax.vmap(lambda x_, t_: x_[t_])(xt, token_of)  # (g, tg*k, d)
    buf = jnp.zeros((g, ne * cap + 1, d), x.dtype)
    buf = jax.vmap(lambda b_, d_, s_: b_.at[d_].set(s_, mode="drop"))(
        buf, dest, picked
    )
    buf = buf[:, : ne * cap].reshape(g, ne, cap, d)
    buf = shard_activation(buf, ("act_batch", "act_experts", None, None))

    # ---- expert FFNs (EP over model, groups over data) -------------------
    h_g = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(x.dtype))
    h_u = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(h_g) * h_u
    h = shard_activation(h, ("act_batch", "act_experts", None, None))
    out_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    out_e = out_e.reshape(g, ne * cap, d)
    out_e = jnp.concatenate(
        [out_e, jnp.zeros((g, 1, d), x.dtype)], axis=1
    )

    # ---- combine ---------------------------------------------------------
    # Fold the router weighting and the sum over k into an expert-side
    # scatter-add so only a (g, tg, d) tensor crosses the EP sharding
    # boundary (one psum over the model axis per layer). Gathering the
    # k=8 expert outputs per token first and reducing after moved a
    # (g, tg*k, d) tensor through the combine all-reduce — 8x the
    # intrinsic traffic (§Perf iteration 3: 872 GB -> ~110 GB).
    slot_token = jnp.full((g, ne * cap + 1), tg, jnp.int32)
    slot_token = jax.vmap(lambda st, de, tf: st.at[de].set(tf, mode="drop"))(
        slot_token, dest, token_of.astype(jnp.int32)
    )[:, : ne * cap]
    flat_p = jnp.take_along_axis(top_p.reshape(g, tg * k), sidx, axis=-1)
    slot_prob = jnp.zeros((g, ne * cap + 1), jnp.float32)
    slot_prob = jax.vmap(lambda sp, de, fp: sp.at[de].set(fp, mode="drop"))(
        slot_prob, dest, flat_p
    )[:, : ne * cap]
    weighted = out_e[:, : ne * cap] * slot_prob.astype(x.dtype)[..., None]
    out = jax.vmap(
        lambda acc, st, wv: acc.at[st].add(wv, mode="drop")
    )(jnp.zeros((g, tg + 1, d), x.dtype), slot_token, weighted)[:, :tg]
    out = out.reshape(bsz, seq, d)
    out = shard_activation(out, ("act_batch", "act_seq", None))

    if cfg.n_shared_experts:
        out = out + mlp_apply(p["shared"], x, cfg)

    # ---- aux losses ------------------------------------------------------
    me = jnp.mean(probs, axis=(0, 1))
    onehot_top1 = jax.nn.one_hot(top_e[..., 0], ne, dtype=jnp.float32)
    fe = jnp.mean(onehot_top1, axis=(0, 1))
    lb = ne * jnp.sum(fe * me)
    zl = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = lb + 1e-3 * zl

    return out, aux
