"""Mamba (S6 selective SSM) blocks for the Jamba hybrid.

in_proj -> (x, z); causal depthwise conv; data-dependent (Δ, B, C);
h_t = exp(Δ⊙A) h_{t-1} + Δ⊙(B x); y = C·h + D⊙x; out = y * silu(z).
Training scans over time with lax.scan (compact HLO); decode keeps a
(d_inner, d_state) SSM state + a (d_conv-1, d_inner) conv tail per layer.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Builder
from repro.sharding.rules import shard_activation


class MambaState(NamedTuple):
    ssm: jax.Array    # (B, d_inner, d_state) fp32
    conv: jax.Array   # (B, d_conv-1, d_inner)


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, cfg.d_model // 16)


def mamba_params(b: Builder, cfg: ModelConfig):
    e = cfg.d_model
    di = cfg.ssm_expand * e
    ds, dc = cfg.d_state, cfg.d_conv
    dtr = _dt_rank(cfg)
    return {
        "in_proj": b.param((e, 2 * di), ("embed", "ff")),
        "conv_w": b.param((dc, di), ("conv", "ff"), scale=0.2),
        "conv_b": b.param((di,), ("ff",), init="zeros"),
        "x_bc": b.param((di, 2 * ds), ("ff", None)),
        "x_dt": b.param((di, dtr), ("ff", None)),
        "dt_proj": b.param((dtr, di), (None, "ff"), scale=0.1),
        "dt_bias": b.param((di,), ("ff",), init="zeros"),
        "a_log": b.param((di, ds), ("ff", "state"), init="zeros"),
        "d_skip": b.param((di,), ("ff",), init="ones"),
        "out_proj": b.param((di, e), ("ff", "embed")),
    }


SCAN_UNROLL = 16


def _ssm_scan(x, dt, bmat, cmat, a, state0):
    """x/dt: (B,T,Di); bmat/cmat: (B,T,Ds); a: (Di,Ds); state0 (B,Di,Ds).

    unroll=16: the recurrence is elementwise, so XLA fuses each unrolled
    group into one loop body and the (B, Di, Ds) state crosses the HBM
    while-loop boundary once per 16 timesteps instead of every step —
    §Perf iteration 1 measured this cutting the jamba train memory term
    ~an order of magnitude. The state is kept sharded over the model
    axis (Di dim) via the constraint below.
    """
    from repro.sharding.rules import shard_activation

    state0 = shard_activation(state0, ("act_batch", "act_ff", None))

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        da = jnp.exp(dt_t[..., None] * a[None])              # (B,Di,Ds)
        h = da * h + dt_t[..., None] * (
            b_t[:, None, :] * x_t[..., None]
        )
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (x, dt, bmat, cmat))
    t_len = x.shape[1]
    unroll = SCAN_UNROLL if t_len % SCAN_UNROLL == 0 else 1
    h, ys = jax.lax.scan(step, state0, xs, unroll=unroll)
    return jnp.moveaxis(ys, 0, 1), h


def mamba_apply(
    p,
    x: jax.Array,
    cfg: ModelConfig,
    state: Optional[MambaState] = None,
) -> Tuple[jax.Array, Optional[MambaState]]:
    b, s, e = x.shape
    di = cfg.ssm_expand * e
    ds, dc = cfg.d_state, cfg.d_conv
    f32 = jnp.float32

    xz = jnp.einsum("bse,ef->bsf", x, p["in_proj"].astype(x.dtype))
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = shard_activation(xs, ("act_batch", "act_seq", "act_ff"))

    # Causal depthwise conv along time.
    if state is None:
        tail = jnp.zeros((b, dc - 1, di), xs.dtype)
    else:
        tail = state.conv.astype(xs.dtype)
    xpad = jnp.concatenate([tail, xs], axis=1)          # (B, S+dc-1, Di)
    conv_w = p["conv_w"].astype(xs.dtype)               # (dc, Di)
    xc = sum(
        xpad[:, i : i + s, :] * conv_w[i][None, None, :] for i in range(dc)
    ) + p["conv_b"].astype(xs.dtype)
    xc = jax.nn.silu(xc)
    new_tail = xpad[:, s:, :]                            # last dc-1 raw inputs

    bc = jnp.einsum("bsd,dn->bsn", xc, p["x_bc"].astype(xs.dtype))
    bmat, cmat = jnp.split(bc, 2, axis=-1)               # (B,S,Ds) each
    dt_r = jnp.einsum("bsd,dr->bsr", xc, p["x_dt"].astype(xs.dtype))
    dt = jnp.einsum("bsr,rd->bsd", dt_r, p["dt_proj"].astype(xs.dtype))
    dt = jax.nn.softplus(dt.astype(f32) + p["dt_bias"].astype(f32))

    a = -jnp.exp(p["a_log"].astype(f32))                 # (Di, Ds), negative
    h0 = state.ssm if state is not None else jnp.zeros((b, di, ds), f32)
    y, h1 = _ssm_scan(xc.astype(f32), dt, bmat.astype(f32), cmat.astype(f32), a, h0)
    y = (y + p["d_skip"].astype(f32)[None, None] * xc.astype(f32)).astype(x.dtype)

    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"].astype(x.dtype))
    out = shard_activation(out, ("act_batch", "act_seq", None))

    new_state = None
    if state is not None:
        new_state = MambaState(ssm=h1, conv=new_tail.astype(state.conv.dtype))
    return out, new_state


def init_mamba_state(cfg: ModelConfig, batch: int, dtype) -> MambaState:
    di = cfg.ssm_expand * cfg.d_model
    return MambaState(
        ssm=jnp.zeros((batch, di, cfg.d_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.d_conv - 1, di), dtype),
    )
