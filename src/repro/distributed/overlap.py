"""Ring collective matmul: all-gather overlapped with partial matmuls.

For an FSDP-sharded weight W = concat_k(W_k) along the contraction dim,
y = x @ W can hide the gather latency: each of the N ring steps multiplies
the locally-resident shard while the next shard is in flight
(collective_permute), instead of waiting for a full all-gather. This is
the TPU analogue of overlapped FSDP unsharding, expressed in shard_map
so XLA schedules the permute concurrently with the dot.

On real hardware the win is the gather latency (bounded by ICI link
time); the dry-run's HLO shows N collective-permutes of 1/N size instead
of one all-gather, which the §Perf log uses to reason about overlap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compat import shard_map_compat


def ring_allgather_matmul(
    x: jax.Array,
    w: jax.Array,
    mesh: Mesh,
    axis_name: str = "data",
):
    """y = x @ W with W row-sharded over `axis_name` and x row-local.

    Args:
      x: (..., K) activations, replicated over `axis_name`.
      w: (K, N) weight, sharded (K/axis,) on dim 0 across `axis_name`.

    Returns:
      y: (..., N) replicated over `axis_name`.
    """
    n = mesh.shape[axis_name]
    k = w.shape[0]
    assert k % n == 0, (k, n)
    shard_k = k // n

    def body(x_l, w_l):
        # x_l: full x (replicated); w_l: (shard_k, N) local shard.
        idx = jax.lax.axis_index(axis_name)

        def step(i, carry):
            acc, w_cur = carry
            # Which global shard does w_cur correspond to at step i?
            src = (idx + i) % n
            x_piece = jax.lax.dynamic_slice_in_dim(
                x_l, src * shard_k, shard_k, axis=-1
            )
            acc = acc + jnp.einsum("...k,kn->...n", x_piece, w_cur)
            # Rotate shards around the ring (overlaps with next matmul).
            w_nxt = jax.lax.ppermute(
                w_cur, axis_name,
                perm=[(j, (j - 1) % n) for j in range(n)],
            )
            return acc, w_nxt

        acc0 = jnp.zeros(x_l.shape[:-1] + (w_l.shape[-1],), x_l.dtype)
        acc, _ = jax.lax.fori_loop(0, n, step, (acc0, w_l))
        return acc

    fn = shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(P(), P(axis_name, None)),
        out_specs=P(),
    )
    return fn(x, w)
