"""jax version compatibility shims for the distributed tricks.

`jax.shard_map` (with `check_vma`) only exists in newer jax releases; on
older ones the same transform lives at `jax.experimental.shard_map` and
the replication-check flag is spelled `check_rep`. Route through one
helper so the call sites stay version-agnostic.
"""
from __future__ import annotations

import jax


def shard_map_compat(body, *, mesh, in_specs, out_specs):
    """`jax.shard_map` with replication checking off, on any jax version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map

    return shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
