from repro.distributed.compression import (  # noqa: F401
    CompressionState,
    compressed_allreduce,
    ef_state_init,
)
from repro.distributed.overlap import ring_allgather_matmul  # noqa: F401
from repro.distributed.sweep import (  # noqa: F401
    MeshPlan,
    as_mesh_plan,
    pad_stacked,
    shard_put,
    stage_pipeline,
)
