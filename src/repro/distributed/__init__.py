from repro.distributed.compression import (  # noqa: F401
    CompressionState,
    compressed_allreduce,
    ef_state_init,
)
from repro.distributed.overlap import ring_allgather_matmul  # noqa: F401
