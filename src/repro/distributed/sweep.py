"""Multi-device sharded execution of stacked sweep groups.

A design-space sweep (repro.explore) evaluates each structure group as
ONE stacked solve over a leading config axis. That axis is
embarrassingly parallel: this module shards it across a 1-D device mesh
with `shard_map`, padding the group to a multiple of the mesh axis by
replicating entry 0 — a duplicate of a real config leaves the
batch-global convergence max unchanged, so together with the solver's
cross-shard `pmax` (SolveOptions.shard_axis) the sharded solve runs the
exact same sweep count and returns bitwise-identical results on the
circuit-solve path. (The ideal-MVM path — parasitics=False — keeps
predictions bitwise but its power einsum is reduction-order-sensitive
to the local batch shape, so power matches only to ~1e-7 relative.)

`MeshPlan` is the user-facing knob (`run_sweep(..., shard=...)` /
`SweepSpec(shard=...)`): which devices, whether to overlap host→device
staging of the next group with compute of the current one
(double-buffered `device_put`), and whether to schedule largest groups
first so the tail of the sweep is short.

Everything here is mechanism only — policy (when to fall back to the
unsharded path: tiny groups, per-config noise draws, transient groups)
lives with the callers in core/evaluate and explore/engine.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_sweep_mesh
from repro.sharding.rules import DEFAULT_RULES, logical_to_spec

#: Logical name of the stacked leading config axis (sharding/rules.py
#: maps it onto the mesh `data` axis when the dim size divides).
CONFIG_AXIS = "config"


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """How a sweep shards its structure groups across devices.

    Attributes:
      devices: number of devices for the 1-D sweep mesh (None = all).
      axis: mesh axis name the config axis shards over.
      min_group: smallest stacked-entry count worth sharding; smaller
        groups run the ordinary single-device path (shard_map overhead
        beats the win on tiny groups).
      overlap: double-buffer host→device staging — issue the next
        group's `device_put` before computing the current one.
      largest_first: schedule groups by descending stacked size so the
        sweep tail is short and devices drain together.
      mesh: explicit Mesh override; None builds one from `devices`.
    """

    devices: Optional[int] = None
    axis: str = "data"
    min_group: int = 2
    overlap: bool = True
    largest_first: bool = True
    mesh: Optional[Mesh] = None

    @classmethod
    def auto(cls) -> "MeshPlan":
        """All visible devices, defaults everywhere."""
        return cls()

    @classmethod
    def host(cls, n_devices: int) -> "MeshPlan":
        """First `n_devices` devices (forced-host-device experiments)."""
        return cls(devices=n_devices)

    def build(self) -> Mesh:
        if self.mesh is not None:
            return self.mesh
        return make_sweep_mesh(self.devices, axis=self.axis)

    def axis_size(self) -> int:
        return self.build().shape[self.axis]

    def shape_str(self) -> str:
        """Compact mesh description for ledger metadata ("data8")."""
        mesh = self.build()
        return "".join(f"{a}{mesh.shape[a]}" for a in mesh.axis_names)


def as_mesh_plan(shard) -> Optional[MeshPlan]:
    """Coerce the `shard=` argument: None/False, True, int, MeshPlan."""
    if shard is None or shard is False:
        return None
    if shard is True:
        return MeshPlan()
    if isinstance(shard, MeshPlan):
        return shard
    if isinstance(shard, int):
        return MeshPlan(devices=shard)
    raise TypeError(
        f"shard= must be a MeshPlan, bool, int or None; got {shard!r}"
    )


def pad_count(c: int, n: int) -> int:
    """Smallest multiple of `n` that is >= `c`."""
    return -(-c // n) * n


def pad_stacked(x: jax.Array, n: int) -> jax.Array:
    """Pad the leading axis to a multiple of `n` by replicating entry 0.

    Replicating a *real* entry (rather than zero-filling) means the pad
    lanes converge exactly like their original: the batch-global
    residual max — and therefore the while_loop trip count — is
    unchanged, which is what keeps padded sharded solves
    bitwise-identical to the unsharded batch.
    """
    c = x.shape[0]
    target = pad_count(c, n)
    if target == c:
        return x
    fill = jnp.broadcast_to(x[:1], (target - c,) + x.shape[1:])
    return jnp.concatenate([x, fill])


def stacked_spec(x, mesh: Mesh, axis: str = "data") -> P:
    """PartitionSpec for a stacked tensor: config axis over the mesh.

    Resolved through sharding/rules.py so divisibility is checked the
    same way as every other logical axis: a leading dim the mesh axis
    does not divide comes back unsharded (replicated) instead of
    erroring — the best-effort semantics `shard_put` staging relies on.
    """
    rules = DEFAULT_RULES
    if axis != "data":
        rules = rules.extend({CONFIG_AXIS: ((axis,), None)})
    logical = (CONFIG_AXIS,) + (None,) * (x.ndim - 1)
    return logical_to_spec(logical, x.shape, mesh, rules)


def shard_put(tree, mesh: Mesh, axis: str = "data"):
    """Best-effort async staging of stacked tensors onto the mesh.

    Leaves whose leading dim the mesh axis divides are placed sharded;
    the rest replicated (stacked_spec's divisibility fallback). Returns
    as soon as the transfers are *issued* — `device_put` is async — so
    a caller can stage group i+1 while group i computes.
    """

    def put(x):
        if not hasattr(x, "ndim") or getattr(x, "ndim", 0) == 0:
            return x
        return jax.device_put(
            x, NamedSharding(mesh, stacked_spec(x, mesh, axis))
        )

    return jax.tree_util.tree_map(put, tree)


def stage_pipeline(
    groups: "list", stage
) -> "Iterator[tuple[int, object]]":
    """Double-buffered iteration: stage group i+1, then yield group i.

    `stage` issues (async) host→device transfers for one group and
    returns the staged value. Because staging i+1 is dispatched before
    the caller computes i, transfer and compute overlap — the spirit of
    distributed/overlap.py's ring matmul, at group granularity.
    """
    if not groups:
        return
    staged = stage(groups[0])
    for i in range(len(groups)):
        current = staged
        if i + 1 < len(groups):
            staged = stage(groups[i + 1])
        yield i, current


def device_counts(mesh: Mesh) -> int:
    return int(mesh.devices.size)


__all__ = [
    "CONFIG_AXIS",
    "MeshPlan",
    "as_mesh_plan",
    "pad_count",
    "pad_stacked",
    "stacked_spec",
    "shard_put",
    "stage_pipeline",
    "device_counts",
]
