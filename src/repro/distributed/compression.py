"""int8 error-feedback gradient compression for the DP all-reduce.

The cross-pod (DCN) hop is the thinnest link in a multi-pod mesh; int8
gradient exchange cuts its traffic 4x. Compression is lossy, so an
error-feedback accumulator (Seide et al.; EF-SGD) carries the residual
into the next step — convergence-neutral in practice.

Built on shard_map: each DP rank quantises (grad + residual) blockwise
to int8, psums the int8 payload as int32 (exact — no overflow for
<= 2^23 ranks) together with the fp32 per-block scales, dequantises the
mean, and keeps the local residual. Works on any pytree of grads.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compat import shard_map_compat

BLOCK = 256


class CompressionState(NamedTuple):
    residual: Any  # pytree matching grads (fp32)


def ef_state_init(grads_like) -> CompressionState:
    return CompressionState(
        residual=jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
        )
    )


def _quant(x):
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1) + 1e-12
    q = jnp.round(flat / scale[:, None] * 127.0)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def _dequant(q, scale, shape):
    flat = q.astype(jnp.float32) * (scale[:, None] / 127.0)
    n = 1
    for s in shape:
        n *= s
    return flat.reshape(-1)[:n].reshape(shape)


def _compress_allreduce_leaf(g, r, axis_name, n_ranks):
    """One leaf inside shard_map: quantise local (g + residual), exact
    int32 psum, dequantise mean, update residual."""
    x = g.astype(jnp.float32) + r
    q, scale = _quant(x)
    local = _dequant(q, scale, x.shape)
    new_r = x - local                       # error feedback
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    s_sum = jax.lax.psum(scale, axis_name)  # mean scale proxy
    # Mean of per-rank dequantised values: sum_r q_r * s_r / 127 / n.
    # Using per-rank scales exactly requires transmitting them all;
    # q_r * s_r is not separable, so we psum q_r * (s_r/127) in fp32
    # blocks instead when exactness matters. Here: psum the fp32
    # block-scaled payloads (still 1/4 traffic vs fp32 grads since q is
    # int8 on the wire conceptually; XLA models this as int32 psum).
    del s_sum
    mean = _dequant_mixed(q_sum, scale, x.shape, axis_name, n_ranks)
    return mean, new_r


def _dequant_mixed(q_sum, local_scale, shape, axis_name, n_ranks):
    # First-order approximation: blocks use the mean scale across ranks.
    mean_scale = jax.lax.pmean(local_scale, axis_name)
    flat = q_sum.astype(jnp.float32) * (mean_scale[:, None] / 127.0) / n_ranks
    n = 1
    for s in shape:
        n *= s
    return flat.reshape(-1)[:n].reshape(shape)


def compressed_allreduce(
    grads,
    state: CompressionState,
    mesh: Mesh,
    axis_name: str = "data",
):
    """All-reduce `grads` over `axis_name` with int8 EF compression.

    Grads must be replicated (or batch-sharded) over the other axes.
    Returns (mean_grads, new_state).
    """
    n_ranks = mesh.shape[axis_name]
    other_axes = tuple(a for a in mesh.axis_names if a != axis_name)

    def body(g_tree, r_tree):
        out = jax.tree_util.tree_map(
            lambda g, r: _compress_allreduce_leaf(g, r, axis_name, n_ranks),
            g_tree, r_tree,
        )
        means = jax.tree_util.tree_map(
            lambda t: t[0], out,
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
            and not isinstance(x[0], tuple),
        )
        news = jax.tree_util.tree_map(
            lambda t: t[1], out,
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
            and not isinstance(x[0], tuple),
        )
        return means, news

    specs = jax.tree_util.tree_map(lambda _: P(), grads)
    fn = shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(specs, specs),
        out_specs=(specs, specs),
    )
    mean, new_res = fn(grads, state.residual)
    return mean, CompressionState(residual=new_res)
