"""Serving launcher: continuous-batching engine for --arch <id>.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \
      --requests 6 --analog

--analog routes FFN projections through the simulated IMAC crossbars
(the paper's inference-accelerator mode).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import Request, ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--analog", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(
            cfg, param_dtype="float32", compute_dtype="float32"
        )
    if args.analog:
        cfg = dataclasses.replace(cfg, analog_mvm=True)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(
        model, params, ServeConfig(slots=args.slots, cache_len=args.cache_len)
    )

    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, size=(8 + i % 5,)),
            max_tokens=args.max_tokens,
        )
        for i in range(args.requests)
    ]
    t0 = time.time()
    eng.run(reqs, max_ticks=10_000)
    dt = time.time() - t0
    n_tok = sum(len(r.output) for r in reqs)
    for r in reqs:
        print(f"[serve] req {r.rid}: {len(r.output)} tokens "
              f"{'done' if r.done else 'INCOMPLETE'}")
    print(f"[serve] {n_tok} tokens in {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s, slots={args.slots}, "
          f"analog={'on' if args.analog else 'off'})")


if __name__ == "__main__":
    main()
