"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this
module touches no jax device state — jax locks the device count on first
backend initialisation, and only launch/dryrun.py (which sets XLA_FLAGS
before any import) should ever see 512 placeholder devices.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips single pod, or 2 pods x 16 x 16 = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_sweep_mesh(n_devices: "int | None" = None, axis: str = "data"):
    """1-D mesh over the sweep `data` axis (stacked config batches).

    The sharded sweep engine (repro.distributed.sweep.MeshPlan) splits
    each structure group's leading config axis across this mesh; a
    single named axis keeps the shard_map specs and the solver's
    cross-shard convergence pmax trivially aligned.
    """
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n,), (axis,))


def make_dev_mesh(n_devices: "int | None" = None):
    """Small mesh over whatever devices exist (tests/examples)."""
    n = n_devices or len(jax.devices())
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
