"""Collective-traffic extraction from compiled HLO text.

cost_analysis() gives FLOPs/bytes but not collective traffic, so we parse
the (post-SPMD-partitioning) HLO: every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction's operand
bytes are summed. Collectives inside `while` bodies (lax.scan over
layers, decode loops) execute trip-count times but appear once in text,
so each computation's byte count is scaled by its call multiplicity:
while-body/condition multiplicities come from the trip count parsed out
of the loop condition's comparison constant, and multiplicities compose
through nested calls.

Output also includes per-op-kind byte/count breakdowns — §Perf uses the
breakdown to find redundant gathers and layout-change reshards.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMPUTATION_RE = re.compile(r"^(%?[\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_CALLSITE_RE = re.compile(
    r"(?:condition|body|to_apply|calls|branch_computations)="
    r"[{]?(%?[\w\.\-]+(?:,\s*%?[\w\.\-]+)*)[}]?"
)
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    def to_dict(self):
        return {
            "total_bytes": self.total_bytes,
            "bytes_by_kind": dict(self.bytes_by_kind),
            "count_by_kind": dict(self.count_by_kind),
        }


def _split_computations(hlo: str) -> "Dict[str, list[str]]":
    """computation name (no % prefix) -> its instruction lines."""
    comps: Dict[str, list[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            cur = m.group(1) if m else None
            if "ENTRY" in stripped:
                cur = "__entry__"
            if cur is not None:
                comps[cur] = []
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _callees(lines: "list[str]") -> "list[tuple[str, str]]":
    """(callee computation, relation) pairs referenced by these lines."""
    out = []
    for line in lines:
        for m in re.finditer(
            r"(condition|body|to_apply|calls)=(%?[\w\.\-]+)", line
        ):
            out.append((m.group(2), m.group(1)))
    return out


def _trip_count(cond_lines: "list[str]") -> int:
    """Heuristic scan/while trip count: the largest comparison constant
    in the loop condition (xla canonical counted loops compare an
    induction variable against a constant)."""
    best = 1
    for line in cond_lines:
        if "compare" in line or "constant" in line:
            for m in _CONST_RE.finditer(line):
                best = max(best, int(m.group(1)))
    return best


def _line_collective_bytes(line: str) -> "tuple[Optional[str], float]":
    for kind in _COLLECTIVES:
        # match the opcode, not result/var names (start or after '= ').
        if re.search(rf"=\s*(\([^)]*\)\s*)?{kind}(-start)?\(", line):
            # Operand shapes: shape tokens inside the call parentheses.
            call = line.split(f"{kind}(", 1)[-1] if f"{kind}(" in line else line
            shapes = _SHAPE_RE.findall(call)
            if not shapes:  # fall back to the result shape
                shapes = _SHAPE_RE.findall(line)[:1]
            total = sum(_shape_bytes(d, dims) for d, dims in shapes)
            return kind, float(total)
    return None, 0.0


def collective_stats(hlo: str) -> CollectiveStats:
    mod = _Module(hlo)
    bytes_by = defaultdict(float)
    count_by = defaultdict(int)
    for name, lines in mod.comps.items():
        m = mod.mult.get(name, 0.0)
        if m == 0.0:
            continue
        for line in lines:
            kind, nbytes = _line_collective_bytes_resolved(mod, name, line)
            if kind is not None:
                bytes_by[kind] += nbytes * m
                count_by[kind] += 1
    return CollectiveStats(dict(bytes_by), dict(count_by))


def _line_collective_bytes_resolved(mod, comp: str, line: str):
    """Collective operand bytes with operand shapes resolved through the
    symbol table (optimized HLO prints operands by name only)."""
    for kind in _COLLECTIVES:
        if re.search(rf"=\s*(\([^)]*\)\s*)?{kind}(-start)?\(", line):
            call = line.split("(", 1)[-1]
            call = call.split(")", 1)[0]
            total = 0.0
            for op in call.split(","):
                nm = _OPERAND_RE.match(op.strip())
                if nm:
                    shape = mod.symbols[comp].get(nm.group(1))
                    if shape:
                        total += _shape_str_bytes(shape)
            if total == 0.0:  # fall back to inline / result shapes
                shapes = _SHAPE_RE.findall(line)
                total = float(
                    sum(_shape_bytes(d, dims) for d, dims in shapes[:1])
                )
            return kind, total
    return None, 0.0


def count_op(hlo: str, opname: str) -> int:
    return len(re.findall(rf"=\s*(?:\([^)]*\)\s*)?{opname}", hlo))


# ---------------------------------------------------------------------------
# Multiplicity-aware FLOPs and fusion-aware HBM bytes.
#
# XLA's HloCostAnalysis visits while bodies ONCE (verified empirically:
# a 10-step lax.scan reports 1/10 the flops of its unrolled twin), so for
# scanned-layer models we parse the HLO ourselves: every computation's
# dot-FLOPs are scaled by its call multiplicity (while trip counts come
# from the backend_config known_trip_count, falling back to the loop
# condition's comparison constant). Bytes are counted fusion-aware: only
# instructions in non-fusion computations contribute their operand+result
# sizes (a fusion's internals stay in registers/VMEM; its boundary
# traffic is what touches HBM); operand shapes are resolved through a
# per-computation symbol table since optimized HLO prints operands by
# name only.
# ---------------------------------------------------------------------------

_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\])")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*([a-z0-9]+\[[0-9,]*\])")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%?([\w\.\-]+)")


def _shape_str_bytes(s: str) -> float:
    """bytes of 'f32[1,2,3]' or a '(tuple, of, shapes)' string."""
    return float(sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(s)))


def _shape_str_dims(s: str) -> "list[int]":
    m = _SHAPE_RE.search(s)
    if not m or not m.group(2):
        return []
    return [int(x) for x in m.group(2).split(",")]


class _Module:
    def __init__(self, hlo: str):
        self.comps = _split_computations(hlo)
        self.headers = {}  # comp -> header param name->shape
        # re-scan headers for param shapes
        cur = None
        for line in hlo.splitlines():
            stripped = line.strip()
            if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
                m = re.match(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)", stripped)
                cur = "__entry__" if "ENTRY" in stripped else (m.group(1) if m else None)
                if cur is not None:
                    self.headers[cur] = dict(_PARAM_RE.findall(stripped))
        self.symbols = {}
        for name, lines in self.comps.items():
            table = dict(self.headers.get(name, {}))
            for line in lines:
                dm = _DEF_RE.match(line)
                if dm:
                    table[dm.group(1)] = dm.group(2)
            self.symbols[name] = table
        self.mult = self._multiplicities()
        self.fused = self._fusion_bodies()
        self._param_charge_cache: Dict[str, dict] = {}

    def _multiplicities(self) -> Dict[str, float]:
        comps = self.comps
        mult: Dict[str, float] = defaultdict(float)
        entry = "__entry__" if "__entry__" in comps else next(iter(comps), None)
        if entry is None:
            return mult
        mult[entry] = 1.0
        for _ in range(64):
            changed = False
            for name, lines in comps.items():
                if mult[name] == 0.0:
                    continue
                for line in lines:
                    body = re.search(r"body=%?([\w\.\-]+)", line)
                    cond = re.search(r"condition=%?([\w\.\-]+)", line)
                    if body:
                        tm = _TRIP_RE.search(line)
                        if tm:
                            trips = int(tm.group(1))
                        elif cond and cond.group(1) in comps:
                            trips = _trip_count(comps[cond.group(1)])
                        else:
                            trips = 1
                        new = mult[name] * max(1, trips)
                        if new > mult[body.group(1)]:
                            mult[body.group(1)] = new
                            changed = True
                        if cond and mult[name] > mult[cond.group(1)]:
                            mult[cond.group(1)] = mult[name]
                            changed = True
                    for mm in re.finditer(r"(?:to_apply|calls)=%?([\w\.\-]+)", line):
                        if mm.group(1) in comps and mult[name] > mult[mm.group(1)]:
                            mult[mm.group(1)] = mult[name]
                            changed = True
                    bm = re.search(r"branch_computations=\{([^}]*)\}", line)
                    if bm:
                        for callee in bm.group(1).split(","):
                            callee = callee.strip().lstrip("%")
                            if callee in comps and mult[name] > mult[callee]:
                                mult[callee] = mult[name]
                                changed = True
            if not changed:
                break
        return mult

    def _fusion_bodies(self) -> set:
        fused = set()
        for lines in self.comps.values():
            for line in lines:
                if re.search(r"\bfusion\(", line):
                    m = re.search(r"calls=%?([\w\.\-]+)", line)
                    if m:
                        fused.add(m.group(1))
        for _ in range(8):
            added = False
            for name in list(fused):
                for line in self.comps.get(name, []):
                    for m in re.finditer(r"(?:to_apply|calls)=%?([\w\.\-]+)", line):
                        if m.group(1) not in fused:
                            fused.add(m.group(1))
                            added = True
            if not added:
                break
        return fused

    def _dot_flops(self, comp: str, line: str) -> float:
        if not re.search(r"\bdot\(", line):
            return 0.0
        dm = _DEF_RE.match(line)
        if not dm:
            return 0.0
        out_dims = _shape_str_dims(dm.group(2))
        n_out = 1
        for d in out_dims:
            n_out *= d
        # lhs operand name -> shape from the symbol table.
        call = line.split("dot(", 1)[-1]
        lhs_name_m = _OPERAND_RE.search(call)
        k = 1
        lc = _LHS_CONTRACT_RE.search(line)
        if lhs_name_m and lc is not None:
            lhs_shape = self.symbols[comp].get(lhs_name_m.group(1))
            if lhs_shape:
                dims = _shape_str_dims(lhs_shape)
                for ci in (lc.group(1).split(",") if lc.group(1) else []):
                    ci = int(ci)
                    if ci < len(dims):
                        k *= dims[ci]
        return 2.0 * n_out * k

    def _fusion_param_charges(self, body: str) -> dict:
        """For a fusion body: param index -> bytes actually read, when a
        parameter only feeds a dynamic-slice (through bitcast/reshape/
        copy/transpose). A scan body's fused `xs`-slice reads the whole
        stacked array as operand but touches one slice per iteration —
        charging the full array inflated scan-heavy cells ~30x."""
        if body in self._param_charge_cache:
            return self._param_charge_cache[body]
        charges: dict = {}
        lines = self.comps.get(body, [])
        defs = {}      # name -> (opcode, first_operand)
        param_idx = {}  # param name -> index
        for pname in (self.headers.get(body) or {}):
            m = re.match(r"param_(\d+)", pname)
            if m:
                param_idx[pname] = int(m.group(1))
        for line in lines:
            pm = re.match(
                r"\s*%?([\w\.\-]+)\s*=\s*[^=]*?\bparameter\((\d+)\)", line
            )
            if pm:
                param_idx[pm.group(1)] = int(pm.group(2))
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rest = line[dm.end():]
            om = re.search(r"\b([a-z][\w\-]*)\(", rest)
            if not om:
                continue
            cm = re.search(r"\(\s*%?([\w\.\-]+)", rest)
            defs[dm.group(1)] = (om.group(1), cm.group(1) if cm else None,
                                 dm.group(2))
        passthrough = {"bitcast", "reshape", "copy", "transpose"}
        for name, (opcode, operand, shape) in defs.items():
            if opcode != "dynamic-slice" or operand is None:
                continue
            src = operand
            for _ in range(6):
                if src in param_idx:
                    charges[param_idx[src]] = _shape_str_bytes(shape)
                    break
                nxt = defs.get(src)
                if nxt is None or nxt[0] not in passthrough or nxt[1] is None:
                    break
                src = nxt[1]
        self._param_charge_cache[body] = charges
        return charges

    _SKIP_OPS = {
        "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
        "after-all", "partition-id", "replica-id", "while", "conditional",
        "call", "iota", "rng-bit-generator-state",
    }
    # Ops that touch only a slice-sized region of their big operand.
    _SLICE_READ_OPS = {"dynamic-slice", "gather", "slice"}
    _SLICE_WRITE_OPS = {"dynamic-update-slice", "scatter", "scatter-add"}

    def _line_bytes(self, comp: str, line: str) -> float:
        dm = _DEF_RE.match(line)
        if not dm:
            return 0.0
        opcode_part = line[dm.end():]
        op_m = re.search(r"\b([a-z][\w\-]*)\(", opcode_part)
        if not op_m:
            return 0.0
        opcode = op_m.group(1)
        if opcode in self._SKIP_OPS:
            return 0.0
        result_bytes = _shape_str_bytes(dm.group(2))
        if opcode in self._SLICE_READ_OPS:
            return 2.0 * result_bytes  # read slice region + write result
        charges = {}
        if opcode == "fusion":
            bm = re.search(r"calls=%?([\w\.\-]+)", line)
            if bm:
                charges = self._fusion_param_charges(bm.group(1))
        call_m = re.search(r"\b[a-z][\w\-]*\(([^)]*)\)", opcode_part)
        operands = []
        if call_m:
            for i, op in enumerate(call_m.group(1).split(",")):
                nm = _OPERAND_RE.match(op.strip())
                if nm:
                    if i in charges:
                        operands.append(charges[i])
                        continue
                    shape = self.symbols[comp].get(nm.group(1))
                    if shape:
                        operands.append(_shape_str_bytes(shape))
        if opcode in self._SLICE_WRITE_OPS:
            # read + write only the update region (second/last operand).
            upd = operands[1] if len(operands) > 1 else min(operands or [0.0])
            return 2.0 * upd
        return result_bytes + sum(operands)


def compute_stats(hlo: str) -> Dict[str, float]:
    """{'flops', 'bytes'}: per-device dot FLOPs with loop multiplicity,
    fusion-boundary HBM bytes."""
    mod = _Module(hlo)
    flops = 0.0
    nbytes = 0.0
    for name, lines in mod.comps.items():
        m = mod.mult.get(name, 0.0)
        if m == 0.0:
            continue
        in_fusion = name in mod.fused
        for line in lines:
            flops += m * mod._dot_flops(name, line)
            if not in_fusion:
                nbytes += m * mod._line_bytes(name, line)
    return {"flops": flops, "bytes": nbytes}
