"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

Weak-type-correct, shardable, zero allocation — the dry-run lowers
against these. Modality frontends are stubs per the assignment:
`input_specs` hands the model precomputed patch/frame embeddings.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.common import dtype_of


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    cdt = dtype_of(cfg.compute_dtype)
    text = s - (cfg.modality_prefix if cfg.family == "vlm" else 0)
    specs = {
        "tokens": sds((b, text), jnp.int32),
        "labels": sds((b, text), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["vision_embeds"] = sds((b, cfg.modality_prefix, cfg.d_model), cdt)
    if cfg.is_encoder_decoder:
        specs["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), cdt)
    return specs


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    specs = train_batch_specs(cfg, shape)
    specs.pop("labels")
    return specs


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig):
    return sds((shape.global_batch, 1), jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """The model inputs lowered for this cell (excludes params/opt/cache,
    which come from jax.eval_shape over init functions)."""
    if shape.kind == "train":
        return train_batch_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_batch_specs(cfg, shape)
    if shape.kind == "decode":
        return {"tokens": decode_token_specs(cfg, shape)}
    raise ValueError(shape.kind)


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> "tuple[bool, str]":
    """Assignment skip rules (documented in DESIGN.md §6)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, (
            "pure full-attention arch: 524288-token dense-KV decode needs "
            "sub-quadratic attention (run for ssm/hybrid archs only)"
        )
    return True, ""
