import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# backend init, and the production meshes below need 512 host devices.

import argparse          # noqa: E402
import functools         # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCHS, SHAPES, get_config          # noqa: E402
from repro.configs.base import ModelConfig, ShapeConfig      # noqa: E402
from repro.launch.hlo_analysis import collective_stats, compute_stats  # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.launch.specs import cell_supported, input_specs   # noqa: E402
from repro.models import build_model                         # noqa: E402
from repro.optim import AdamWConfig, adamw_init              # noqa: E402
from repro.optim.schedule import constant_schedule           # noqa: E402
from repro.sharding.rules import DEFAULT_RULES, spec_tree    # noqa: E402
from repro.train.loop import (                               # noqa: E402
    TrainConfig,
    batch_specs,
    make_train_step,
    opt_shardings,
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell
from ShapeDtypeStructs only, and record memory / cost / collective
analysis for §Dry-run and §Roofline of EXPERIMENTS.md.

Run:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json
"""

# v5e hardware constants for the roofline report.
PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (≈ per chip usable)


def _adamw_for(cfg: ModelConfig) -> AdamWConfig:
    # bf16-param (huge MoE) archs use 8-bit moments; dense use fp32.
    bits = 8 if cfg.param_dtype == "bfloat16" else 32
    return AdamWConfig(state_bits=bits)


def lower_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    rules=DEFAULT_RULES,
):
    """Lower + compile one cell; returns (compiled, meta dict)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        return None, {"skipped": True, "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    if cfg.fsdp_over_pod and multi_pod:
        rules = rules.extend({"embed": (("pod", "data"), ("data",), None)})
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params_s = jax.eval_shape(model.init, key)
    p_shard = spec_tree(model.param_axes(), params_s, mesh, rules)
    batch = input_specs(cfg, shape)

    t0 = time.time()
    if shape.kind == "train":
        tcfg = TrainConfig(microbatches=1, adamw=_adamw_for(cfg))
        step = make_train_step(model, tcfg, constant_schedule(3e-4))
        opt_s = jax.eval_shape(
            functools.partial(adamw_init, cfg=tcfg.adamw), params_s
        )
        o_shard = opt_shardings(opt_s, p_shard, mesh, rules)
        b_shard = batch_specs(batch, mesh, rules)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard, None),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = jitted.lower(
                params_s, opt_s, batch, jax.ShapeDtypeStruct((), jnp.int32)
            )
    elif shape.kind == "prefill":
        fn = functools.partial(model.prefill, cache_len=shape.seq_len)
        b_shard = batch_specs(batch, mesh, rules)
        jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
        with mesh:
            lowered = jitted.lower(params_s, batch)
    else:  # decode
        cache_s = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len)
        )
        c_shard = spec_tree(model.cache_axes(), cache_s, mesh, rules)
        b_shard = batch_specs(batch, mesh, rules)
        jitted = jax.jit(
            model.decode_step,
            in_shardings=(p_shard, c_shard, b_shard["tokens"]),
            out_shardings=(None, c_shard),
            donate_argnums=(1,),
        )
        with mesh:
            lowered = jitted.lower(params_s, cache_s, batch["tokens"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    meta = {
        "skipped": False,
        "arch": arch,
        "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "n_devices": 512 if multi_pod else 256,
        "kind": shape.kind,
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
    }
    return compiled, meta


def analyse(compiled, meta: dict, cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Extract memory/cost/collective numbers + roofline terms."""
    out = dict(meta)
    try:
        mem = compiled.memory_analysis()
        out["memory"] = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
        if hasattr(mem, "serialized_size_in_bytes"):
            out["memory"]["serialized_size_in_bytes"] = int(
                mem.serialized_size_in_bytes
            )
    except Exception as e:  # CPU backend may not implement it
        out["memory"] = {"error": str(e)}

    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        keep = ("flops", "bytes accessed", "transcendentals", "optimal_seconds")
        out["cost"] = (
            {k: float(cost[k]) for k in keep if k in cost} if cost else {}
        )
    except Exception as e:
        out["cost"] = {"error": str(e)}

    hlo = compiled.as_text()
    stats = collective_stats(hlo)
    out["collectives"] = stats.to_dict()
    out["hlo_bytes"] = len(hlo)
    # Loop-multiplicity-aware HLO flops/bytes (XLA's cost analysis visits
    # while bodies once, undercounting scanned models — see hlo_analysis).
    parsed = compute_stats(hlo)
    out["parsed"] = parsed

    # Roofline terms (seconds; per device).
    n_dev = meta["n_devices"]
    flops = parsed["flops"] or out.get("cost", {}).get("flops", 0.0)
    bytes_accessed = parsed["bytes"] or out.get("cost", {}).get(
        "bytes accessed", 0.0
    )
    coll_bytes = stats.total_bytes
    # train: 6ND over all B*S tokens (fwd+bwd); prefill: 2ND over B*S
    # (fwd only); decode: 2ND over the B new tokens.
    if shape.kind == "train":
        model_flops = 6.0 * cfg.n_active_params() * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        model_flops = 2.0 * cfg.n_active_params() * shape.global_batch * shape.seq_len
    else:
        model_flops = 2.0 * cfg.n_active_params() * shape.global_batch
    out["roofline"] = {
        "compute_s": flops / PEAK_FLOPS if flops else None,
        "memory_s": bytes_accessed / HBM_BW if bytes_accessed else None,
        "collective_s": coll_bytes / ICI_BW if coll_bytes else 0.0,
        "model_flops_total": model_flops,
        "model_flops_per_device": model_flops / n_dev,
        "hlo_flops_per_device": flops,
        "useful_flops_ratio": (model_flops / n_dev) / flops if flops else None,
    }
    terms = {
        "compute": out["roofline"]["compute_s"] or 0.0,
        "memory": out["roofline"]["memory_s"] or 0.0,
        "collective": out["roofline"]["collective_s"] or 0.0,
    }
    out["roofline"]["dominant"] = max(terms, key=terms.get)
    return out


def run_cell(arch, shape_name, multi_pod, out_dir="artifacts/dryrun", force=False):
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    try:
        compiled, meta = lower_cell(arch, shape_name, multi_pod=multi_pod)
        if compiled is None:
            result = {
                "arch": arch, "shape": shape_name, "mesh": mesh_tag, **meta
            }
        else:
            result = analyse(compiled, meta, cfg, shape)
            print(f"[dryrun] {arch} x {shape_name} x {mesh_tag}: "
                  f"compile={result['t_compile_s']}s "
                  f"dominant={result['roofline']['dominant']}")
            print("  memory_analysis:", result.get("memory"))
            print("  cost_analysis:",
                  {k: v for k, v in result.get("cost", {}).items()
                   if k in ("flops", "bytes accessed")})
            del compiled
    except Exception as e:
        result = {
            "arch": arch, "shape": shape_name, "mesh": mesh_tag,
            "skipped": False, "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-2000:],
        }
        print(f"[dryrun] {arch} x {shape_name} x {mesh_tag}: FAILED {e}")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument(
        "--mesh", default="both", choices=["single", "multi", "both"]
    )
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                r = run_cell(arch, shape, mp, out_dir=args.out, force=args.force)
                if r.get("skipped"):
                    n_skip += 1
                elif "error" in r:
                    n_fail += 1
                else:
                    n_ok += 1
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
