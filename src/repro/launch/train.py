"""Training launcher: --arch <id> --shape <name> on the current device
pool (production pods use the same entry point; this container runs the
reduced config on 1 CPU device).

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 50 \
      --reduced --ckpt-dir artifacts/ckpt

On a real multi-host pod, initialise jax.distributed first (the launcher
does it when JAX_COORDINATOR is set) and drop --reduced; the mesh comes
from launch/mesh.py and params/opt shard per sharding/rules.py. On
restart after preemption or failure the latest committed checkpoint is
picked up automatically; persistent stragglers raise after an emergency
checkpoint so the orchestrator can re-mesh (train/fault.plan_mesh).
"""
from __future__ import annotations

import argparse
import dataclasses
import os

import jax

from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.data.synthetic_lm import make_train_stream
from repro.launch.mesh import make_dev_mesh, make_production_mesh
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.train import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config + shape (CPU)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--schedule", default="wsd",
                    choices=["wsd", "cosine", "constant"])
    ap.add_argument("--peak-lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 production mesh (needs 256 devices)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()  # multi-host pod entry

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    if args.reduced:
        cfg = cfg.reduced()
        shape = ShapeConfig(shape.name, 128, 4, shape.kind)
    model = build_model(cfg)
    print(f"[train] {cfg.name} x {shape.name}: ~{cfg.n_params()/1e6:.1f}M params")

    mesh = None
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    elif len(jax.devices()) > 1:
        mesh = make_dev_mesh()

    bits = 8 if cfg.param_dtype == "bfloat16" else 32
    tcfg = TrainConfig(
        peak_lr=args.peak_lr,
        total_steps=args.steps,
        schedule=args.schedule,
        microbatches=args.microbatches,
        adamw=AdamWConfig(state_bits=bits),
        checkpoint_dir=args.ckpt_dir,
        checkpoint_every=max(10, args.steps // 5),
        log_every=10,
    )
    trainer = Trainer(model, tcfg, mesh=mesh)
    trainer.install_preemption_hook()
    stream = make_train_stream(cfg, shape, seed=0)

    def log(step, m):
        print(f"[train] step {step:5d} loss {m['loss']:.4f} lr {m['lr']:.2e}")

    params, history = trainer.fit(jax.random.PRNGKey(0), stream, on_metrics=log)
    stream.close()
    print(f"[train] done; final loss {history[-1][1]['loss']:.4f}")


if __name__ == "__main__":
    main()
