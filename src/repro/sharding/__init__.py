from repro.sharding.rules import (  # noqa: F401
    AxisRules,
    DEFAULT_RULES,
    logical_to_spec,
    make_sharding,
    shard_activation,
    spec_tree,
)
