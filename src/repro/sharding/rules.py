"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Parameters and activations are annotated with *logical* axis names
("embed", "heads", "experts", "act_batch", ...). Rules map each logical
axis to an ordered list of mesh-axis candidates; `logical_to_spec` picks
the first candidate (or candidate tuple) whose product divides the dim
size and is present in the mesh, else leaves the dim unsharded. That
gives every architecture a coherent sharding on the fixed production
mesh even when, e.g., 56 heads don't divide the 16-wide model axis
(llava) — the fallback is handled by rule order, not by per-arch code.

Parallelism coverage on the (pod, data, model) mesh:
  DP    — act_batch -> (pod, data)
  FSDP  — embed -> data (params all-gathered per scanned layer)
  TP    — ff / heads / vocab / moe_ff -> model
  EP    — experts -> model
  SP    — act_seq -> model (sequence parallelism; used when heads do not
          divide the model axis, and for long-context cache sharding)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


Candidate = "str | tuple[str, ...] | None"


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Ordered logical-axis -> mesh-axis-candidate rules."""

    rules: "dict[str, tuple[Candidate, ...]]"

    def candidates(self, logical: str) -> "tuple[Candidate, ...]":
        return self.rules.get(logical, (None,))

    def extend(self, extra: "dict[str, tuple[Candidate, ...]]") -> "AxisRules":
        merged = dict(self.rules)
        merged.update(extra)
        return AxisRules(merged)


DEFAULT_RULES = AxisRules(
    {
        # --- parameter axes ---
        "embed": (("data",), None),          # FSDP
        "embed_pod": (("pod", "data"), ("data",), None),  # FSDP over pod too
        "ff": (("model",), None),            # TP
        "heads": (("model",), None),
        "kv_heads": (("model",), None),      # falls back to None if indivisible
        "vocab": (("model",), None),
        "experts": (("model",), None),       # EP
        "moe_ff": (None,),                   # expert inner dim stays local
        "head_dim": (None,),
        "layers": (None,),                   # scan-stacked leading dim
        "kv_lora": (None,),
        "q_lora": (None,),
        "conv": (None,),
        "state": (None,),
        "norm": (None,),
        # --- sweep-engine axes ---
        # Leading stacked-config axis of a design-space sweep group
        # (repro.distributed.sweep): shards over `data` when divisible,
        # else stays replicated (the staging fallback for non-padded
        # remainders — padding makes it divisible inside the engine).
        "config": (("data",), None),
        # --- activation axes ---
        "act_batch": (("pod", "data"), ("data",), None),
        "act_seq": (None,),                  # overridden to ("model",) for SP
        "act_seq_sp": (("model",), None),
        "act_embed": (None,),
        "act_heads": (("model",), None),
        "act_kv_heads": (("model",), None),
        "act_ff": (("model",), None),
        "act_vocab": (("model",), None),
        "act_experts": (("model",), None),
        "cache_batch": (("pod", "data"), ("data",), None),
        "cache_seq": (("model",), None),     # context-parallel KV cache
        "cache_kv_heads": (None,),
        # flat (1-D) optimizer payloads: fully shard over every axis
        "opt_flat": (
            ("pod", "data", "model"), ("data", "model"), ("data",), None,
        ),
    }
)


def _axis_size(mesh: Mesh, cand: Candidate) -> int:
    if cand is None:
        return 1
    names = (cand,) if isinstance(cand, str) else cand
    size = 1
    for n in names:
        if n not in mesh.shape:
            return -1  # not available on this mesh
        size *= mesh.shape[n]
    return size


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    dim_sizes: Sequence[int],
    mesh: Mesh,
    rules: AxisRules = DEFAULT_RULES,
) -> P:
    """Resolve logical axis names to a PartitionSpec for `mesh`.

    Every logical axis tries its candidates in order; a candidate is
    accepted if all its mesh axes exist, are unused so far in this spec,
    and their product divides the dimension size.
    """
    assert len(logical_axes) == len(dim_sizes), (logical_axes, dim_sizes)
    used: set = set()
    out = []
    for name, dim in zip(logical_axes, dim_sizes):
        if name is None:
            out.append(None)
            continue
        chosen: Candidate = None
        for cand in rules.candidates(name):
            if cand is None:
                chosen = None
                break
            names = (cand,) if isinstance(cand, str) else tuple(cand)
            size = _axis_size(mesh, names)
            if size <= 0 or any(n in used for n in names):
                continue
            if dim % size == 0:
                chosen = names if len(names) > 1 else names[0]
                used.update(names)
                break
        out.append(chosen)
    return P(*out)


def make_sharding(
    logical_axes: Sequence[Optional[str]],
    dim_sizes: Sequence[int],
    mesh: Mesh,
    rules: AxisRules = DEFAULT_RULES,
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical_axes, dim_sizes, mesh, rules))


def spec_tree(param_axes, params_shape, mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    """Map a pytree of logical-axis tuples + matching shapes to shardings.

    `param_axes` leaves are tuples of logical names; `params_shape` leaves
    are ShapeDtypeStruct (or arrays). Returns a matching NamedSharding tree.
    """

    def one(axes, shaped):
        shape = shaped.shape
        if len(axes) != len(shape):
            raise ValueError(f"axes {axes} do not match shape {shape}")
        return make_sharding(axes, shape, mesh, rules)

    return jax.tree_util.tree_map(
        one, param_axes, params_shape,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


def shard_activation(
    x: jax.Array,
    logical_axes: Sequence[Optional[str]],
    mesh: Optional[Mesh] = None,
    rules: AxisRules = DEFAULT_RULES,
) -> jax.Array:
    """with_sharding_constraint using logical names; no-op outside jit/mesh."""
    if mesh is None:
        mesh = _current_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = logical_to_spec(logical_axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh() -> Optional[Mesh]:
    try:
        env = jax._src.mesh.thread_resources.env  # type: ignore[attr-defined]
        mesh = env.physical_mesh
        return mesh
    except Exception:
        return None


def model_axis_size(mesh: Optional[Mesh] = None) -> int:
    if mesh is None:
        mesh = _current_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.shape:
        return 1
    return mesh.shape["model"]


def attn_q_axes(n_heads: int) -> "tuple[Optional[str], ...]":
    """Activation axes for (B, S, H, D) attention tensors.

    Heads shard over the model axis when divisible (TP attention);
    otherwise the query sequence carries the model axis instead
    (sequence-parallel attention with XLA-gathered KV) — this is what
    keeps phi3 (40H), minicpm (36H) and llava (56H) score tensors
    sharded on the 16-wide axis.
    """
    if n_heads % max(model_axis_size(), 1) == 0:
        return ("act_batch", "act_seq", "act_heads", None)
    return ("act_batch", "act_seq_sp", None, None)
