from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from repro.optim.schedule import (  # noqa: F401
    cosine_schedule,
    make_schedule,
    wsd_schedule,
)
