"""AdamW with optional 8-bit (block-quantised) first/second moments.

At kimi-k2 scale, fp32 Adam moments are 8 TB; blockwise int8 moments with
fp32 per-block absmax scales (bitsandbytes-style) cut that 4x with
negligible quality impact — block size is static so everything jits and
shards like the fp32 path.

All functions are pure pytree -> pytree; no optimizer library involved.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256  # elements per quantisation block


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_bits: int = 32  # 32 or 8


@jax.tree_util.register_pytree_node_class
class Quantised:
    """int8 payload (in the parameter's own shape) + per-block fp32
    absmax scales over the LAST axis. Keeping q in param shape means the
    state shards exactly like its parameter — a flat layout would force
    XLA to fully rematerialise (replicate!) the dequantised moments when
    resharding flat->param layout (observed: 436 GB/device buffers on
    deepseek expert weights)."""

    def __init__(self, q: jax.Array, scale: jax.Array, shape):
        self.q = q          # int8, shape == param shape (last dim padded)
        self.scale = scale  # f32, shape[:-1] + (n_blocks,)
        self.shape = tuple(shape)

    def tree_flatten(self):
        return (self.q, self.scale), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux)

    def __repr__(self):
        return f"Quantised(shape={self.shape})"


def _block_for(last_dim: int) -> int:
    """Block size: BLOCK when it divides, else the dim itself (small)."""
    return BLOCK if last_dim % BLOCK == 0 else last_dim


def quantise(x: jax.Array) -> Quantised:
    shape = x.shape
    if x.ndim == 0:
        x = x[None]
    last = x.shape[-1]
    blk = _block_for(last)
    blocks = x.reshape(*x.shape[:-1], last // blk, blk)
    scale = jnp.max(jnp.abs(blocks), axis=-1) + 1e-12
    q = jnp.round(blocks / scale[..., None] * 127.0).astype(jnp.int8)
    return Quantised(
        q=q.reshape(x.shape), scale=scale.astype(jnp.float32), shape=shape
    )


def dequantise(qv: Quantised) -> jax.Array:
    x = qv.q
    if not qv.shape:
        x = x.reshape(1)
    last = x.shape[-1]
    blk = _block_for(last)
    blocks = x.reshape(*x.shape[:-1], last // blk, blk).astype(jnp.float32)
    out = blocks * qv.scale[..., None] / 127.0
    return out.reshape(qv.shape)


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any   # pytree: fp32 arrays (32-bit) or Quantised leaves (8-bit)
    v: Any


def _is_state_leaf(x) -> bool:
    return isinstance(x, Quantised)


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return quantise(z) if cfg.state_bits == 8 else z

    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zero_like, params),
        v=jax.tree_util.tree_map(zero_like, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr: jax.Array,
    cfg: AdamWConfig,
):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    bits = cfg.state_bits

    p_leaves, treedef = jax.tree_util.tree_flatten(params)
    g_leaves = treedef.flatten_up_to(grads)
    m_leaves, _ = jax.tree_util.tree_flatten(state.m, is_leaf=_is_state_leaf)
    v_leaves, _ = jax.tree_util.tree_flatten(state.v, is_leaf=_is_state_leaf)

    new_p, new_m, new_v = [], [], []
    for p, g, m_q, v_q in zip(p_leaves, g_leaves, m_leaves, v_leaves):
        g = g.astype(jnp.float32) * clip
        m = dequantise(m_q) if bits == 8 else m_q
        v = dequantise(v_q) if bits == 8 else v_q
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if p.ndim >= 2:  # decoupled decay on matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * u).astype(p.dtype))
        new_m.append(quantise(m) if bits == 8 else m)
        new_v.append(quantise(v) if bits == 8 else v)

    unflatten = jax.tree_util.tree_unflatten
    metrics = {"grad_norm": gnorm, "lr": lr}
    return (
        unflatten(treedef, new_p),
        AdamWState(step=step, m=unflatten(treedef, new_m), v=unflatten(treedef, new_v)),
        metrics,
    )
