"""LR schedules: WSD (MiniCPM's warmup-stable-decay), cosine, linear."""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def wsd_schedule(
    peak_lr: float,
    total_steps: int,
    *,
    warmup_frac: float = 0.01,
    decay_frac: float = 0.1,
    min_ratio: float = 0.1,
) -> Schedule:
    """Warmup-Stable-Decay (arXiv:2404.06395): linear warmup, long flat
    stable phase, sharp final decay to min_ratio * peak."""
    warm = max(1, int(total_steps * warmup_frac))
    decay = max(1, int(total_steps * decay_frac))
    stable_end = total_steps - decay

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm_lr = peak_lr * step / warm
        decay_t = jnp.clip((step - stable_end) / decay, 0.0, 1.0)
        decay_lr = peak_lr * (1.0 - (1.0 - min_ratio) * decay_t)
        return jnp.where(step < warm, warm_lr, jnp.where(step < stable_end, peak_lr, decay_lr))

    return fn


def cosine_schedule(
    peak_lr: float,
    total_steps: int,
    *,
    warmup_frac: float = 0.01,
    min_ratio: float = 0.1,
) -> Schedule:
    warm = max(1, int(total_steps * warmup_frac))

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm_lr = peak_lr * step / warm
        t = jnp.clip((step - warm) / max(1, total_steps - warm), 0.0, 1.0)
        cos_lr = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warm, warm_lr, cos_lr)

    return fn


def constant_schedule(lr: float) -> Schedule:
    def fn(step):
        return jnp.full_like(jnp.asarray(step, jnp.float32), lr)

    return fn


def make_schedule(kind: str, peak_lr: float, total_steps: int, **kw) -> Schedule:
    if kind == "wsd":
        return wsd_schedule(peak_lr, total_steps, **kw)
    if kind == "cosine":
        return cosine_schedule(peak_lr, total_steps, **kw)
    if kind == "constant":
        return constant_schedule(peak_lr)
    raise ValueError(f"unknown schedule {kind!r}")
