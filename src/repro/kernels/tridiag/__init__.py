from repro.kernels.tridiag.ops import tridiag  # noqa: F401
