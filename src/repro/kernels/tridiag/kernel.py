"""Pallas TPU kernel: batched Thomas solve.

Layout: systems run along the *sublane* axis (N rows), independent
systems along the *lane* axis (blocks of 128). The Thomas recurrence is
inherently sequential in N, so each grid step owns a (N, LANES) tile in
VMEM and runs the forward/backward sweeps with fori_loop over rows; the
128-wide vector unit solves 128 systems per step in parallel. The
crossbar solver batches (tiles x samples x rows) into the lane axis, so
a 32x32-partitioned MNIST layer keeps thousands of lanes busy.

VMEM budget per block (f32): 6 buffers x N x 128 x 4B; N<=1024 -> ~3MB,
comfortably inside the ~16MB v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _tridiag_kernel(dl_ref, d_ref, du_ref, b_ref, x_ref, cp_ref, dp_ref, *, n):
    """One (N, LANES) tile: forward elimination then back-substitution."""
    # Row 0.
    d0 = d_ref[0, :]
    cp0 = du_ref[0, :] / d0
    dp0 = b_ref[0, :] / d0
    cp_ref[0, :] = cp0
    dp_ref[0, :] = dp0

    def fwd(i, _):
        cp_prev = cp_ref[i - 1, :]
        dp_prev = dp_ref[i - 1, :]
        dl_i = dl_ref[i, :]
        denom = d_ref[i, :] - dl_i * cp_prev
        cp_ref[i, :] = du_ref[i, :] / denom
        dp_ref[i, :] = (b_ref[i, :] - dl_i * dp_prev) / denom
        return 0

    jax.lax.fori_loop(1, n, fwd, 0)

    x_ref[n - 1, :] = dp_ref[n - 1, :]

    def bwd(k, _):
        i = n - 2 - k
        x_ref[i, :] = dp_ref[i, :] - cp_ref[i, :] * x_ref[i + 1, :]
        return 0

    jax.lax.fori_loop(0, n - 1, bwd, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tridiag_nb(
    dl: jax.Array,
    d: jax.Array,
    du: jax.Array,
    b: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """Solve tridiagonal systems laid out (N, B); B padded to 128.

    Args:
      dl, d, du, b: (N, B) coefficient arrays (systems along axis 1).

    Returns:
      x: (N, B) solutions.
    """
    n, batch = d.shape
    assert batch % LANES == 0, f"batch {batch} must be padded to {LANES}"
    grid = (batch // LANES,)
    spec = pl.BlockSpec((n, LANES), lambda i: (0, i))
    return pl.pallas_call(
        functools.partial(_tridiag_kernel, n=n),
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n, batch), d.dtype),
        scratch_shapes=[
            pltpu.VMEM((n, LANES), d.dtype),
            pltpu.VMEM((n, LANES), d.dtype),
        ],
        interpret=interpret,
    )(dl, d, du, b)
