"""Public jit'd wrapper for the tridiag Pallas kernel.

Accepts the solver's native (..., N) layout, flattens the batch onto the
lane axis, pads to 128 and dispatches to the kernel. On non-TPU backends
it runs in interpret mode (or falls back to the scan oracle for speed —
interpret mode executes the kernel body in Python per grid step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.tridiag.kernel import LANES, tridiag_nb
from repro.kernels.tridiag.ref import tridiag_ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def tridiag(
    dl: jax.Array,
    d: jax.Array,
    du: jax.Array,
    b: jax.Array,
    *,
    interpret: "bool | None" = None,
) -> jax.Array:
    """Batched tridiagonal solve along the last axis, Pallas-accelerated.

    Drop-in replacement for solver.tridiag_scan (same semantics:
    dl[..., 0] / du[..., -1] ignored).
    """
    if interpret is None:
        interpret = not on_tpu()
    shape = d.shape
    n = shape[-1]
    batch = 1
    for s in shape[:-1]:
        batch *= s

    def flat(a):
        return jnp.broadcast_to(a, shape).reshape(batch, n).T  # (N, B)

    pad = (-batch) % LANES
    args = []
    for a in (dl, d, du, b):
        a = flat(a)
        if pad:
            # Padding systems solve d*x = 0 with d=1 — harmless.
            fill = jnp.ones((n, pad), a.dtype) if a is not None else None
            a = jnp.concatenate([a, fill], axis=1)
        args.append(a)
    # Ensure padded diagonal is nonsingular: replace d-pad with ones, the
    # off-diagonals/rhs with zeros.
    if pad:
        dl_p, d_p, du_p, b_p = args
        zeros = jnp.zeros((n, pad), d_p.dtype)
        args = [
            jnp.concatenate([dl_p[:, :batch], zeros], axis=1),
            d_p,
            jnp.concatenate([du_p[:, :batch], zeros], axis=1),
            jnp.concatenate([b_p[:, :batch], zeros], axis=1),
        ]
    x = tridiag_nb(*args, interpret=interpret)
    return x[:, :batch].T.reshape(shape)


def tridiag_or_ref(*args, use_kernel: bool = True, **kw):
    """Select kernel vs oracle (tests use both)."""
    return tridiag(*args, **kw) if use_kernel else tridiag_ref(*args)
