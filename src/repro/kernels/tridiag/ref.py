"""Pure-jnp oracle for the batched tridiagonal (Thomas) solve."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tridiag_ref(dl: jax.Array, d: jax.Array, du: jax.Array, b: jax.Array) -> jax.Array:
    """Solve tridiagonal systems along the last axis.

    dl[..., 0] and du[..., -1] are ignored. Shapes all (..., N).
    """
    n = d.shape[-1]
    if n == 1:
        return b / d
    dl_t = jnp.moveaxis(dl, -1, 0)
    d_t = jnp.moveaxis(d, -1, 0)
    du_t = jnp.moveaxis(du, -1, 0)
    b_t = jnp.moveaxis(b, -1, 0)

    def fwd(carry, row):
        cp_prev, dp_prev = carry
        dl_j, d_j, du_j, b_j = row
        denom = d_j - dl_j * cp_prev
        cp = du_j / denom
        dp = (b_j - dl_j * dp_prev) / denom
        return (cp, dp), (cp, dp)

    zeros = jnp.zeros_like(d_t[0])
    dl_eff = dl_t.at[0].set(0.0)
    _, (cp, dp) = jax.lax.scan(fwd, (zeros, zeros), (dl_eff, d_t, du_t, b_t))

    def bwd(x_next, row):
        cp_j, dp_j = row
        x_j = dp_j - cp_j * x_next
        return x_j, x_j

    _, x_rev = jax.lax.scan(bwd, zeros, (cp, dp), reverse=True)
    return jnp.moveaxis(x_rev, 0, -1)
