"""Pallas TPU kernel: fused quantised differential analog MVM.

The AnalogLinear fast path: simulate programming a weight matrix onto
differential crossbar pairs (conductance quantisation) and driving it
with DAC-quantised activations — fused into a tiled MXU matmul so the
"analog simulation" costs the same as a plain matmul at scale.

Tiling: grid (M/bm, N/bn, K/bk), K innermost so the f32 VMEM accumulator
is revisited per (m, n) tile; quantisation is applied elementwise on the
(bm, bk) / (bk, bn) tiles before the dot. MXU-aligned blocks (128x128
default). VMEM per step: bm*bk + bk*bn + bm*bn floats ~ 192KB at 128³.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dac(x, dac_bits: int):
    if dac_bits <= 0:
        return jnp.clip(x, 0.0, 1.0)
    n = (1 << dac_bits) - 1
    return jnp.round(jnp.clip(x, 0.0, 1.0) * n) * (1.0 / n)


def _wq(w, levels: int):
    w = jnp.clip(w, -1.0, 1.0)
    if levels <= 1:
        return w
    step = 1.0 / (levels - 1)
    return jnp.sign(w) * jnp.round(jnp.abs(w) * (1.0 / step)) * step


def _mvm_kernel(x_ref, w_ref, o_ref, acc_ref, *, dac_bits, levels, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xq = _dac(x_ref[...].astype(jnp.float32), dac_bits)
    wq = _wq(w_ref[...].astype(jnp.float32), levels)
    acc_ref[...] += jnp.dot(xq, wq, preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("dac_bits", "levels", "bm", "bn", "bk", "interpret")
)
def imac_mvm_padded(
    x: jax.Array,
    w: jax.Array,
    *,
    dac_bits: int = 8,
    levels: int = 16,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """x: (M, K), w: (K, N), dims already padded to block multiples."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(
            _mvm_kernel, dac_bits=dac_bits, levels=levels, n_k=grid[2]
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
