"""Public wrapper for the imac_mvm kernel: pads to MXU tiles, picks
interpret mode off-TPU, and exposes device-physics semantics (technology
-> levels, read noise, energy estimate) for AnalogLinear.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.devices import DeviceTech, get_tech
from repro.kernels.imac_mvm.kernel import imac_mvm_padded
from repro.kernels.imac_mvm.ref import imac_mvm_ref


def _pad_to(a: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-a.shape[axis]) % mult
    if not pad:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(
    jax.jit, static_argnames=("dac_bits", "levels", "interpret", "bm", "bn", "bk")
)
def imac_mvm(
    x: jax.Array,
    w: jax.Array,
    *,
    dac_bits: int = 8,
    levels: int = 16,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: "bool | None" = None,
) -> jax.Array:
    """Quantised differential analog MVM. x: (..., K) in [0,1] digital
    units; w: (K, N) in [-1,1] normalised weights. Returns (..., N)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    lead = x.shape[:-1]
    k = x.shape[-1]
    xf = x.reshape(-1, k)
    m = xf.shape[0]
    xp = _pad_to(_pad_to(xf, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    y = imac_mvm_padded(
        xp, wp, dac_bits=dac_bits, levels=levels,
        bm=bm, bn=bn, bk=bk, interpret=interpret,
    )
    return y[:m, : w.shape[1]].reshape(*lead, w.shape[1])


def analog_linear(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array],
    tech: "DeviceTech | str" = "PCM",
    *,
    dac_bits: int = 8,
    levels: Optional[int] = None,
    noise_key: Optional[jax.Array] = None,
    w_scale: Optional[jax.Array] = None,
    interpret: "bool | None" = None,
) -> jax.Array:
    """Drop-in y = x @ w + b simulated on ideal analog crossbars.

    Activations are normalised per-call into [0,1] (dynamic-range DAC),
    weights into [-1,1]; device physics sets the conductance level count
    and read noise. Used by the substrate's AnalogLinear serving mode.
    """
    tech = get_tech(tech)
    levels = levels or tech.levels or 16
    if w_scale is None:
        w_scale = jnp.max(jnp.abs(w)) + 1e-12
    x_lo = jnp.min(x)
    x_hi = jnp.max(x)
    x_rng = jnp.maximum(x_hi - x_lo, 1e-12)
    xn = (x - x_lo) / x_rng
    wn = w / w_scale
    y = imac_mvm(xn, wn, dac_bits=dac_bits, levels=levels, interpret=interpret)
    # Undo normalisation: x = xn*rng + lo -> x@w = (xn@wn)*rng*scale + lo*colsum.
    colsum = jnp.sum(w, axis=0)
    y = y * (x_rng * w_scale) + x_lo * colsum
    if noise_key is not None and tech.read_noise_rel > 0:
        y = y + tech.read_noise_rel * jnp.abs(y) * jax.random.normal(
            noise_key, y.shape, y.dtype
        )
    if b is not None:
        y = y + b
    return y.astype(x.dtype)
