"""Pure-jnp oracle for the fused quantised differential analog MVM.

Semantics (normalised units; ops.py maps device physics onto these):
  * inputs x in [0, 1] are DAC-quantised to 2^dac_bits uniform levels,
  * weights w in [-1, 1] are programmed as a differential conductance
    pair, each side on `levels` uniform levels in [0, 1]; the effective
    quantised weight is sign(w) * Q_levels(|w|),
  * y = DAC(x) @ Q(w).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dac_quant(x: jax.Array, dac_bits: int) -> jax.Array:
    if dac_bits <= 0:
        return jnp.clip(x, 0.0, 1.0)
    n = (1 << dac_bits) - 1
    return jnp.round(jnp.clip(x, 0.0, 1.0) * n) / n


def weight_quant(w: jax.Array, levels: int) -> jax.Array:
    w = jnp.clip(w, -1.0, 1.0)
    if levels <= 1:
        return w
    step = 1.0 / (levels - 1)
    return jnp.sign(w) * jnp.round(jnp.abs(w) / step) * step


def imac_mvm_ref(
    x: jax.Array,
    w: jax.Array,
    *,
    dac_bits: int = 8,
    levels: int = 16,
) -> jax.Array:
    """x: (B, K) in [0,1]; w: (K, N) in [-1,1] -> (B, N) float32."""
    xq = dac_quant(x.astype(jnp.float32), dac_bits)
    wq = weight_quant(w.astype(jnp.float32), levels)
    return xq @ wq
