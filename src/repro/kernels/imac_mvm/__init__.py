from repro.kernels.imac_mvm.ops import imac_mvm  # noqa: F401
