"""Pallas TPU kernels for IMAC-Sim-JAX's compute hot-spots.

  tridiag          — batched Thomas solve, the inner loop of the crossbar
                     circuit solver (lanes = independent systems).
  imac_mvm         — fused quantised differential analog MVM (the
                     ideal-analog fast path / AnalogLinear backend).
  decode_attention — flash-decoding (online softmax over KV blocks) for
                     long-context serving shapes.
  gs_fused         — the *entire* Gauss–Seidel sweep loop fused in one
                     kernel (lane block of systems resident in VMEM);
                     the "fused" entry of the solver backend registry.

Each kernel directory has kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd public wrapper choosing interpret mode off-TPU) and
ref.py (pure-jnp oracle used by the tests).
"""
from repro.kernels.tridiag.ops import tridiag  # noqa: F401
from repro.kernels.imac_mvm.ops import imac_mvm  # noqa: F401
from repro.kernels.decode_attention.ops import decode_attention  # noqa: F401
from repro.kernels.gs_fused.ops import fused_lane_block, fused_solve  # noqa: F401
