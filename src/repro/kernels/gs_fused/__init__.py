from repro.kernels.gs_fused.ops import fused_lane_block, fused_solve  # noqa: F401
