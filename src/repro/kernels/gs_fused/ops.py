"""Host-side wrapper of the fused Gauss–Seidel sweep kernel.

`fused_solve` is the ``"fused"`` entry of the solver-backend registry
(repro.core.backends): it assembles the sweep-invariant tridiagonal
coefficients of the segment-RC MNA structure — including companion-model
stamps from a `Stamps` pytree — precomputes the Thomas forward
multipliers and inverse denominators once per solve, flattens every
leading batch axis (configs × trials × samples × tiles) into the
kernel's lane-block axis, and returns the same `CrossbarSolution` the
generic sweep loop produces.

Off-TPU the kernel runs in interpret mode (the caller resolves the flag
via repro.core.backends.resolve_interpret); tiles too large for VMEM
residency fall back to the per-half-sweep ``"pallas"`` backend with a
single logged notice.
"""
from __future__ import annotations

import logging

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels.gs_fused.kernel import gs_fused_nb

logger = logging.getLogger(__name__)

#: Conservative per-lane-block buffer counts used for VMEM sizing:
#: 12 buffers in (M, N) layout (inputs, outputs, scratch, carry) plus
#: 5 in the transposed (N, M) layout (see kernel.py's docstring).
_BUFS_MN = 12
_BUFS_NM = 5
VMEM_BUDGET_BYTES = 8 * 1024 * 1024

_fallback_notice_emitted = False


def _pad8(x: int) -> int:
    return -(-x // 8) * 8


def _pad128(x: int) -> int:
    return -(-x // 128) * 128


def fused_lane_block(
    m: int,
    n: int,
    dtype=jnp.float32,
    budget_bytes: int = VMEM_BUDGET_BYTES,
) -> int:
    """Lane-block size LB the fused kernel can keep resident in VMEM.

    The VMEM cost per batched system is ``itemsize × (12 × pad8(M) ×
    pad128(N) + 5 × pad8(N) × pad128(M))`` (Mosaic pads the minor axis
    to 128 lanes and the second-minor to 8 sublanes for f32). Returns 0
    when even one system does not fit — callers must then fall back to
    the per-half-sweep ``"pallas"`` backend.
    """
    itemsize = jnp.dtype(dtype).itemsize
    per_system = itemsize * (
        _BUFS_MN * _pad8(m) * _pad128(n) + _BUFS_NM * _pad8(n) * _pad128(m)
    )
    return min(budget_bytes // per_system, 256)


def _thomas_coeffs(d: jax.Array, off) -> "tuple[jax.Array, jax.Array]":
    """Sweep-invariant Thomas forward coefficients along the last axis.

    For systems with constant off-diagonals ``off`` (dl = du = off;
    dl[..., 0] / du[..., -1] unused) and diagonal ``d``, returns
    ``(cp, inv_den)`` such that the forward elimination reduces to
    ``dp_j = (b_j - off * dp_{j-1}) * inv_den_j`` and back-substitution
    to ``x_j = dp_j - cp_j * x_{j+1}`` — no divisions left in the sweep
    loop.
    """
    off_b = jnp.broadcast_to(off, d.shape)
    d_t = jnp.moveaxis(d, -1, 0)
    du_t = jnp.moveaxis(off_b, -1, 0)
    dl_t = du_t.at[0].set(0.0)

    def step(cp_prev, row):
        d_j, dl_j, du_j = row
        inv = 1.0 / (d_j - dl_j * cp_prev)
        cp_j = du_j * inv
        return cp_j, (cp_j, inv)

    _, (cpv, inv) = jax.lax.scan(
        step, jnp.zeros_like(d_t[0]), (d_t, dl_t, du_t)
    )
    return jnp.moveaxis(cpv, 0, -1), jnp.moveaxis(inv, 0, -1)


def fused_solve(g, v_in, cp, stamps=None, *, interpret: bool = False):
    """Solve crossbar tiles with the fused multi-sweep Pallas kernel.

    Drop-in replacement for the generic sweep loop in
    `repro.core.solver._sweep_solve` — same batching semantics (leading
    axes broadcast, per-config electrical scalars allowed), same
    companion-stamp support, same final un-relaxed half-sweep. Runs the
    full ``cp.gs_iters`` sweep budget; ``cp.tol`` early exit does not
    apply (on-chip sweeps are cheap, and a data-dependent trip count
    would stall the whole lane block anyway).

    Args:
      g: (..., M, N) device conductances.
      v_in: (..., M) driver voltages.
      cp: `CircuitParams` (floats or leading-axis arrays).
      stamps: optional `Stamps` companion stamps / warm start.
      interpret: run the kernel in Pallas interpret mode (CPU).

    Returns:
      `CrossbarSolution` matching the scan backend to float tolerance.
    """
    from repro.core.solver import (
        CrossbarSolution,
        SolveOptions,
        Stamps,
        _align,
        solve_crossbar,
    )

    st = stamps or Stamps()
    g = jnp.asarray(g)
    v_in = jnp.asarray(v_in)
    m, n = g.shape[-2], g.shape[-1]
    dtype = g.dtype

    lb = fused_lane_block(m, n, dtype)
    if lb < 1:
        # Structured telemetry: one event per fallback occurrence, with
        # the cause and the backend actually taking over (counted as
        # backend_fallback_total{cause="vmem_budget",...}). The legacy
        # one-shot logger notice stays for uninstrumented runs.
        obs.event(
            "backend_fallback",
            cause="vmem_budget",
            from_backend="fused",
            to_backend="pallas",
            tile=f"{m}x{n}",
        )
        global _fallback_notice_emitted
        if not _fallback_notice_emitted:
            _fallback_notice_emitted = True
            logger.warning(
                "fused solver backend: %dx%d tile exceeds the VMEM "
                "residency budget (see kernels.gs_fused.fused_lane_block); "
                "falling back to the per-half-sweep 'pallas' backend.",
                m, n,
            )
        return solve_crossbar(
            g, v_in, cp, stamps=stamps,
            options=SolveOptions(backend="pallas", interpret=interpret),
        )

    batch = jnp.broadcast_shapes(
        g.shape[:-2],
        v_in.shape[:-1],
        *(x.shape[:-2] for x in st.fields() if x is not None),
    )
    nd = len(batch) + 2
    g_b = jnp.broadcast_to(g, batch + (m, n)).astype(dtype)
    v_b = jnp.broadcast_to(v_in, batch + (m,)).astype(dtype)

    def scal(value):
        """Electrical scalar -> per-system (..., 1, 1) array."""
        return jnp.broadcast_to(_align(value, nd, dtype), batch + (1, 1))

    g_row, g_col = scal(cp.g_row), scal(cp.g_col)
    g_source, g_tia = scal(cp.g_source), scal(cp.g_tia)
    omega = scal(cp.omega)

    def maybe(x):
        return (
            None if x is None
            else jnp.broadcast_to(x.astype(dtype), batch + (m, n))
        )

    gsh_row, gsh_col = maybe(st.g_shunt_row), maybe(st.g_shunt_col)
    inj_row, inj_col = maybe(st.i_inj_row), maybe(st.i_inj_col)
    vc0 = maybe(st.v_init)

    # Diagonals: wire-chain conductance + devices (+ companion shunts),
    # mirroring solver._row_system / _col_system exactly.
    idx_n = jnp.arange(n)
    if n == 1:
        chain_r = g_source
    else:
        chain_r = jnp.where(
            idx_n == 0,
            g_row + g_source,
            jnp.where(idx_n == n - 1, g_row, 2.0 * g_row),
        )
    d_row = chain_r + g_b
    if gsh_row is not None:
        d_row = d_row + gsh_row

    idx_m = jnp.arange(m)[:, None]
    if m == 1:
        chain_c = g_tia
    else:
        chain_c = jnp.where(
            idx_m == 0,
            g_col,
            jnp.where(idx_m == m - 1, g_col + g_tia, 2.0 * g_col),
        )
    d_col = chain_c + g_b
    if gsh_col is not None:
        d_col = d_col + gsh_col

    # Sweep-invariant Thomas coefficients (row systems run along N, col
    # systems along M — computed along the last axis of the transposed
    # view and swapped back).
    cp_row, id_row = _thomas_coeffs(d_row, -g_row)
    cp_colT, id_colT = _thomas_coeffs(jnp.swapaxes(d_col, -1, -2), -g_col)

    # Right-hand-side constants: the driver source enters row column 0.
    src_row = jnp.where(idx_n == 0, g_source * v_b[..., :, None], 0.0)
    src_row = jnp.broadcast_to(src_row, batch + (m, n))
    if inj_row is not None:
        src_row = src_row + inj_row
    injc = inj_col if inj_col is not None else jnp.zeros(batch + (m, n), dtype)
    if vc0 is None:
        vc0 = jnp.zeros(batch + (m, n), dtype)

    # Flatten the batch onto the lane-block axis and pad; all-zero
    # padded systems are inert (no divisions happen in the kernel).
    b_total = 1
    for s in batch:
        b_total *= s
    lb = min(lb, max(b_total, 1))
    pad = (-b_total) % lb

    def flat(a, rows, cols):
        a = a.reshape((b_total, rows, cols))
        if pad:
            a = jnp.concatenate(
                [a, jnp.zeros((pad, rows, cols), a.dtype)], axis=0
            )
        return a

    vr, vc, res = gs_fused_nb(
        flat(g_b, m, n),
        flat(src_row, m, n),
        flat(injc, m, n),
        flat(jnp.swapaxes(cp_row, -1, -2), n, m),
        flat(jnp.swapaxes(id_row, -1, -2), n, m),
        flat(jnp.swapaxes(cp_colT, -1, -2), m, n),
        flat(jnp.swapaxes(id_colT, -1, -2), m, n),
        flat(-g_row, 1, 1),
        flat(-g_col, 1, 1),
        flat(omega, 1, 1),
        flat(vc0, m, n),
        m=m,
        n=n,
        sweeps=int(cp.gs_iters),
        lane_block=lb,
        interpret=interpret,
    )
    vr = vr[:b_total].reshape(batch + (m, n))
    vc = vc[:b_total].reshape(batch + (m, n))
    residual = res[:b_total, 0, 0].reshape(batch)
    i_out = _align(cp.g_tia, vc.ndim - 1, dtype) * vc[..., m - 1, :]
    return CrossbarSolution(
        i_out=i_out,
        vr=vr,
        vc=vc,
        residual=residual,
        sweeps=jnp.asarray(int(cp.gs_iters), jnp.int32),
    )
