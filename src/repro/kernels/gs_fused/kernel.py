"""Pallas TPU kernel: the *entire* block Gauss–Seidel sweep loop, fused.

This extends the Thomas tile in `repro.kernels.tridiag.kernel` from one
half-sweep to the whole iteration: instead of materializing the row/col
voltages to HBM between every half-sweep (two kernel launches + four
HBM round-trips per sweep in the ``"pallas"`` backend), one grid step
owns a lane block of LB independent (tile × sample) systems in VMEM and
iterates

    row-tridiag → transpose → col-tridiag → SOR update → residual

``sweeps`` times on-chip, then runs one final un-relaxed sweep so the
returned row voltages are consistent with the converged column voltages
(matching `repro.core.solver._sweep_solve` exactly).

Layouts. Column-phase arrays live in the natural ``(LB, M, N)`` layout:
the Thomas recurrence walks the sublane axis (M) while the vector unit
solves all N columns × LB systems per step. Row-phase arrays live in the
transposed ``(LB, N, M)`` layout for the same reason. The two in-VMEM
transposes per sweep (rhs in, solution out) replace what used to be HBM
round-trips.

Arithmetic split. The tridiagonal *coefficients* (diagonal chain +
device conductances + companion-stamp shunts) are constant across
sweeps — only the right-hand side changes. The forward-elimination
multipliers ``cp`` and inverse denominators ``inv_den`` are therefore
precomputed once outside the kernel (repro.kernels.gs_fused.ops), which
halves the sequential work per sweep and removes every division from
the inner loop.

VMEM budget: ~17 lane-block buffers of the padded tile, i.e. roughly
``17 × LB × pad8(M) × pad128(N) × 4B`` for f32 — `ops.fused_lane_block`
picks LB against an 8 MB budget (≈ LB=16 at 32×32) and reports 0 when
even LB=1 does not fit (≥ ~352×352 tiles), in which case the solver
falls back to the per-half-sweep ``"pallas"`` backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gs_fused_kernel(
    # inputs
    g_ref,       # (LB, M, N) device conductances
    src_ref,     # (LB, M, N) row rhs sources (driver + i_inj_row)
    injc_ref,    # (LB, M, N) column rhs injections (i_inj_col)
    cpr_ref,     # (LB, N, M) row-system forward multipliers, transposed
    idr_ref,     # (LB, N, M) row-system inverse denominators, transposed
    cpc_ref,     # (LB, M, N) col-system forward multipliers
    idc_ref,     # (LB, M, N) col-system inverse denominators
    odr_ref,     # (LB, 1, 1) row off-diagonal (-g_row)
    odc_ref,     # (LB, 1, 1) col off-diagonal (-g_col)
    om_ref,      # (LB, 1, 1) SOR over-relaxation factor
    vc0_ref,     # (LB, M, N) initial column voltages (warm start)
    # outputs
    vr_ref,      # (LB, M, N) row-node voltages
    vc_ref,      # (LB, M, N) column-node voltages
    res_ref,     # (LB, 1, 1) last sweep's max |Δvc|
    # scratch
    btr_ref,     # (LB, N, M) transposed row rhs
    dpr_ref,     # (LB, N, M) row forward-sweep partials
    xr_ref,      # (LB, N, M) row solution (transposed)
    bc_ref,      # (LB, M, N) column rhs
    dpc_ref,     # (LB, M, N) col forward-sweep partials
    vcg_ref,     # (LB, M, N) col solution (the GS update)
    vcs_ref,     # (LB, M, N) SOR-relaxed column-voltage carry
    *,
    m: int,
    n: int,
    sweeps: int,
):
    g = g_ref[...]
    odr = odr_ref[...][:, :, 0]   # (LB, 1): broadcasts over (LB, M)
    odc = odc_ref[...][:, :, 0]
    omega = om_ref[...]
    vcs_ref[...] = vc0_ref[...]
    res_ref[...] = jnp.full(res_ref.shape, jnp.inf, res_ref.dtype)

    def row_solve():
        """All-rows Thomas solve given the current column voltages."""
        btr_ref[...] = jnp.swapaxes(g * vcs_ref[...] + src_ref[...], 1, 2)
        dpr_ref[:, 0, :] = btr_ref[:, 0, :] * idr_ref[:, 0, :]

        def fwd(j, _):
            dpr_ref[:, j, :] = (
                btr_ref[:, j, :] - odr * dpr_ref[:, j - 1, :]
            ) * idr_ref[:, j, :]
            return 0

        jax.lax.fori_loop(1, n, fwd, 0)
        xr_ref[:, n - 1, :] = dpr_ref[:, n - 1, :]

        def bwd(k, _):
            j = n - 2 - k
            xr_ref[:, j, :] = (
                dpr_ref[:, j, :] - cpr_ref[:, j, :] * xr_ref[:, j + 1, :]
            )
            return 0

        jax.lax.fori_loop(0, n - 1, bwd, 0)
        return jnp.swapaxes(xr_ref[...], 1, 2)  # (LB, M, N)

    def col_solve(vr):
        """All-columns Thomas solve given fresh row voltages."""
        bc_ref[...] = g * vr + injc_ref[...]
        dpc_ref[:, 0, :] = bc_ref[:, 0, :] * idc_ref[:, 0, :]

        def fwd(i, _):
            dpc_ref[:, i, :] = (
                bc_ref[:, i, :] - odc * dpc_ref[:, i - 1, :]
            ) * idc_ref[:, i, :]
            return 0

        jax.lax.fori_loop(1, m, fwd, 0)
        vcg_ref[:, m - 1, :] = dpc_ref[:, m - 1, :]

        def bwd(k, _):
            i = m - 2 - k
            vcg_ref[:, i, :] = (
                dpc_ref[:, i, :] - cpc_ref[:, i, :] * vcg_ref[:, i + 1, :]
            )
            return 0

        jax.lax.fori_loop(0, m - 1, bwd, 0)
        return vcg_ref[...]

    def sweep(_, __):
        vr = row_solve()
        vc_old = vcs_ref[...]
        vc_gs = col_solve(vr)
        vc_new = vc_old + omega * (vc_gs - vc_old)
        vcs_ref[...] = vc_new
        res_ref[...] = jnp.max(
            jnp.abs(vc_new - vc_old), axis=(1, 2), keepdims=True
        )
        return 0

    jax.lax.fori_loop(0, sweeps, sweep, 0)

    # Final un-relaxed half-sweeps: row voltages consistent with vc.
    vr = row_solve()
    vr_ref[...] = vr
    vc_ref[...] = col_solve(vr)


@functools.partial(
    jax.jit, static_argnames=("m", "n", "sweeps", "lane_block", "interpret")
)
def gs_fused_nb(
    g: jax.Array,        # (B, M, N)
    src_row: jax.Array,  # (B, M, N)
    inj_col: jax.Array,  # (B, M, N)
    cp_rowT: jax.Array,  # (B, N, M)
    id_rowT: jax.Array,  # (B, N, M)
    cp_col: jax.Array,   # (B, M, N)
    id_col: jax.Array,   # (B, M, N)
    od_row: jax.Array,   # (B, 1, 1)
    od_col: jax.Array,   # (B, 1, 1)
    omega: jax.Array,    # (B, 1, 1)
    vc0: jax.Array,      # (B, M, N)
    *,
    m: int,
    n: int,
    sweeps: int,
    lane_block: int,
    interpret: bool = False,
) -> "tuple[jax.Array, jax.Array, jax.Array]":
    """Dispatch the fused sweep kernel over a padded batch.

    B must be a multiple of ``lane_block``; zero-padded trailing systems
    are harmless (all-zero coefficients solve to all-zero voltages).

    Returns:
      (vr, vc, res): (B, M, N), (B, M, N), (B, 1, 1).
    """
    batch = g.shape[0]
    assert batch % lane_block == 0, (batch, lane_block)
    grid = (batch // lane_block,)
    dtype = g.dtype

    def spec(rows, cols):
        return pl.BlockSpec((lane_block, rows, cols), lambda i: (i, 0, 0))

    mn, nm, one = spec(m, n), spec(n, m), spec(1, 1)
    return pl.pallas_call(
        functools.partial(_gs_fused_kernel, m=m, n=n, sweeps=sweeps),
        grid=grid,
        in_specs=[mn, mn, mn, nm, nm, mn, mn, one, one, one, mn],
        out_specs=[mn, mn, one],
        out_shape=[
            jax.ShapeDtypeStruct((batch, m, n), dtype),
            jax.ShapeDtypeStruct((batch, m, n), dtype),
            jax.ShapeDtypeStruct((batch, 1, 1), dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((lane_block, n, m), dtype),
            pltpu.VMEM((lane_block, n, m), dtype),
            pltpu.VMEM((lane_block, n, m), dtype),
            pltpu.VMEM((lane_block, m, n), dtype),
            pltpu.VMEM((lane_block, m, n), dtype),
            pltpu.VMEM((lane_block, m, n), dtype),
            pltpu.VMEM((lane_block, m, n), dtype),
        ],
        interpret=interpret,
    )(
        g, src_row, inj_col, cp_rowT, id_rowT, cp_col, id_col,
        od_row, od_col, omega, vc0,
    )
