"""Pallas TPU kernel: flash-decoding (one query token vs a long KV cache).

Decode attention is memory-bound: the whole KV cache streams HBM->VMEM
once per step. The kernel tiles the cache into (bs, D) blocks along the
sequence, keeps online-softmax state (m, l, acc) in VMEM scratch that
persists across the sequential S-grid dimension, and writes the output on
the last block — one pass, no (S,) intermediates in HBM.

Layout: one grid row per (batch, kv_head); the G = H/Hkv grouped query
heads form the sublane dim of a (G, Dk) q tile, so GQA groups share the
streamed KV block (this is what makes GQA decode G× more
bandwidth-efficient, and it falls out of the tiling). Variable cache
lengths come in via scalar prefetch and mask the tail block.

Dk (score) and Dv (value) may differ — MLA absorbed decode uses
Dk=kv_lora+rope, Dv=kv_lora on a single shared KV head (MQA-like).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, bs, n_s, scale
):
    i = pl.program_id(0)
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)          # (G, Dk)
    k = k_ref[0].astype(jnp.float32)          # (bs, Dk)
    v = v_ref[0].astype(jnp.float32)          # (bs, Dv)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                  # (G, bs)

    kv_len = len_ref[i]
    pos = s * bs + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(pos < kv_len, scores, NEG_INF)

    m_prev = m_ref[...]                        # (G, 1)
    m_cur = jnp.max(scores, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                # (G, bs)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "scale", "interpret"))
def decode_attention_flat(
    q: jax.Array,       # (BN, G, Dk)
    k: jax.Array,       # (BN, S, Dk)
    v: jax.Array,       # (BN, S, Dv)
    lengths: jax.Array,  # (BN,) int32 valid cache lengths
    *,
    bs: int = 512,
    scale: float = 1.0,
    interpret: bool = False,
) -> jax.Array:
    bn, g, dk = q.shape
    _, s, dv = v.shape
    assert s % bs == 0, f"S={s} must be a multiple of block {bs}"
    n_s = s // bs
    grid = (bn, n_s)
    kernel = functools.partial(_decode_kernel, bs=bs, n_s=n_s, scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, g, dk), lambda i, s_, lens: (i, 0, 0)),
                pl.BlockSpec((1, bs, dk), lambda i, s_, lens: (i, s_, 0)),
                pl.BlockSpec((1, bs, dv), lambda i, s_, lens: (i, s_, 0)),
            ],
            out_specs=pl.BlockSpec((1, g, dv), lambda i, s_, lens: (i, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, dv), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bn, g, dv), q.dtype),
        interpret=interpret,
    )(lengths, q, k, v)
