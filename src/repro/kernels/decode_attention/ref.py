"""Pure-jnp oracle for flash-decoding attention (single query step).

GQA layout: q (B, H, Dk), k (B, S, Hkv, Dk), v (B, S, Hkv, Dv) with
H = G * Hkv. `kv_len` masks the valid cache prefix per batch element.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    kv_len: Optional[jax.Array] = None,
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    b, h, dk = q.shape
    _, s, hkv, _ = k.shape
    dv = v.shape[-1]
    g = h // hkv
    if scale is None:
        scale = dk ** -0.5
    qf = q.reshape(b, hkv, g, dk).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bngd,bsnd->bngs", qf, kf) * scale
    if kv_len is not None:
        mask = jnp.arange(s)[None, :] < kv_len[:, None]  # (B, S)
        scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngs,bsnd->bngd", p, vf)
    return out.reshape(b, h, dv).astype(q.dtype)
