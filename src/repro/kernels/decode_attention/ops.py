"""Public wrapper: GQA-layout flash-decoding attention.

Reshapes (B, H, Dk) x (B, S, Hkv, D*) into per-(batch, kv-head) rows for
the kernel, broadcasts cache lengths, and picks interpret mode off-TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_flat
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("bs", "scale", "interpret"))
def decode_attention(
    q: jax.Array,                      # (B, H, Dk)
    k: jax.Array,                      # (B, S, Hkv, Dk)
    v: jax.Array,                      # (B, S, Hkv, Dv)
    kv_len: Optional[jax.Array] = None,  # (B,) int32
    *,
    bs: int = 512,
    scale: Optional[float] = None,
    interpret: "bool | None" = None,
) -> jax.Array:
    """Single-step decode attention with online softmax over KV blocks."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, h, dk = q.shape
    _, s, hkv, _ = k.shape
    dv = v.shape[-1]
    g = h // hkv
    if scale is None:
        scale = dk ** -0.5
    if kv_len is None:
        kv_len = jnp.full((b,), s, jnp.int32)

    bs_eff = min(bs, s)
    while s % bs_eff:
        bs_eff //= 2

    qf = q.reshape(b, hkv, g, dk).reshape(b * hkv, g, dk)
    kf = jnp.swapaxes(k, 1, 2).reshape(b * hkv, s, dk)
    vf = jnp.swapaxes(v, 1, 2).reshape(b * hkv, s, dv)
    lens = jnp.repeat(kv_len.astype(jnp.int32), hkv)
    out = decode_attention_flat(
        qf, kf, vf, lens, bs=bs_eff, scale=float(scale), interpret=interpret
    )
    return out.reshape(b, hkv, g, dv).reshape(b, h, dv)


__all__ = ["decode_attention", "decode_attention_ref"]
