"""Fault-tolerant checkpointing: async, atomic, sharding-aware.

Layout: <dir>/step_<n>.tmp-<nonce>/ is written (one .npy per flattened
leaf + a JSON manifest with the treedef, dtypes and logical step), then
atomically renamed to step_<n>/ and a COMMIT marker file written last.
Restart safety: readers only consider directories with COMMIT markers;
interrupted writes leave only .tmp dirs, which are garbage-collected.

Async: `save(...)` snapshots device arrays to host (blocking only on
transfer) and hands the file I/O to a worker thread, so the train loop
overlaps checkpoint writes with compute. `wait()` joins pending writes
(called before exit and before the next save).

Restore: leaves are loaded host-side and re-placed with jax.device_put
against target shardings if given — this is the elastic-restart path
(a checkpoint written on one mesh restores onto another).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import uuid
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy cannot serialise these natively; store bit-views + logical dtype.
_CUSTOM_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _to_storable(arr: np.ndarray) -> "tuple[np.ndarray, str]":
    name = str(arr.dtype)
    if name in _CUSTOM_DTYPES:
        return arr.view(_CUSTOM_DTYPES[name][1]), name
    return arr, name


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _CUSTOM_DTYPES:
        return arr.view(_CUSTOM_DTYPES[dtype_name][0])
    return arr


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_pytree(tree, directory: str) -> None:
    """Synchronous atomic save of a pytree of arrays."""
    paths, leaves, _ = _flatten_with_paths(tree)
    tmp = f"{directory}.tmp-{uuid.uuid4().hex[:8]}"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"leaves": []}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)
        store, dtype_name = _to_storable(arr)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), store)
        manifest["leaves"].append(
            {"path": p, "file": fname, "dtype": dtype_name, "shape": list(arr.shape)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)
    with open(os.path.join(directory, "COMMIT"), "w") as f:
        f.write(str(time.time()))


def load_pytree(directory: str, like, shardings=None):
    """Load into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). `shardings`: optional matching tree of
    jax.sharding.Sharding for elastic re-placement."""
    if not os.path.exists(os.path.join(directory, "COMMIT")):
        raise FileNotFoundError(f"no committed checkpoint at {directory}")
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _flatten_with_paths(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    if set(paths) != set(by_path):
        missing = set(paths) ^ set(by_path)
        raise ValueError(f"checkpoint/param tree mismatch: {sorted(missing)[:5]}")
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    out = []
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        entry = by_path[p]
        arr = _from_storable(
            np.load(os.path.join(directory, entry["file"])), entry["dtype"]
        )
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{p}: shape {arr.shape} != expected {leaf.shape}")
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.device_put(arr.astype(leaf.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Step-indexed async checkpointing with retention + auto-resume."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._gc_tmp()

    def _gc_tmp(self):
        for name in os.listdir(self.directory):
            if ".tmp-" in name:
                shutil.rmtree(os.path.join(self.directory, name), ignore_errors=True)

    def step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def all_steps(self) -> "list[int]":
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and ".tmp-" not in name:
                if os.path.exists(os.path.join(self.directory, name, "COMMIT")):
                    steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree, *, blocking: bool = False):
        """Snapshot to host, then write asynchronously."""
        self.wait()
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save_pytree(host_tree, self.step_dir(step))
                self._retain()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            work()
            if self._error is not None:
                err, self._error = self._error, None
                raise err
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def _retain(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.step_dir(s), ignore_errors=True)

    def restore(self, like, *, step: Optional[int] = None, shardings=None):
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return load_pytree(self.step_dir(step), like, shardings), step
