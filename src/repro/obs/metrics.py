"""Metrics registry: counters, gauges, histograms, structured events.

One process-wide registry keyed by (metric name, label set). Handles are
obtained with `counter` / `gauge` / `histogram` and are cheap to fetch
repeatedly (a dict lookup under a lock); when observability is disabled
every accessor returns a shared no-op handle so instrumented code pays a
single flag check and allocates nothing.

Histograms use *fixed* bucket edges chosen at first registration —
`exponential_buckets(start, factor, count)` builds the geometric ladders
solver telemetry wants (sweep counts, residual magnitudes). Exporters:

  * `export_prometheus()` — Prometheus text exposition format
    (``name_bucket{le="..."}`` / ``_sum`` / ``_count`` for histograms);
  * `snapshot()` / `export_json()` — a JSON-able dict, embedded by
    ``benchmarks/run.py`` into each ``BENCH_<name>.json``.

`event(name, **fields)` records a structured occurrence: it increments
the ``<name>_total`` counter labeled by the event's scalar fields, keeps
the full record on an event log (`events()` — what regression tests
assert against), and drops an instant mark on the span timeline so
Chrome traces show *when* a backend fallback or cache eviction happened.
"""
from __future__ import annotations

import bisect
import json
import math
import threading
import time
from typing import Optional, Sequence

from repro.obs import state, trace as _trace

_lock = threading.RLock()
_metrics: "dict[tuple, object]" = {}
_types: "dict[str, str]" = {}
_events: "list[dict]" = []


def exponential_buckets(
    start: float, factor: float, count: int
) -> "tuple[float, ...]":
    """`count` geometric bucket edges: start, start*factor, ... ."""
    if start <= 0.0 or factor <= 1.0 or count < 1:
        raise ValueError("need start > 0, factor > 1, count >= 1")
    return tuple(start * factor**i for i in range(count))


#: Default edges: 1..2^15 — covers iteration-count style histograms.
DEFAULT_BUCKETS = exponential_buckets(1.0, 2.0, 16)

#: Gauss–Seidel sweeps-to-converge (suggest_iters tops out in the
#: hundreds for paper-scale tiles).
SWEEPS_BUCKETS = exponential_buckets(1.0, 2.0, 12)

#: Final-residual magnitudes (volts): 1e-10 .. 10, decade per bucket.
RESIDUAL_BUCKETS = exponential_buckets(1e-10, 10.0, 12)

#: Wall-clock seconds: 100 µs .. ~1.7 min, quadrupling.
SECONDS_BUCKETS = exponential_buckets(1e-4, 4.0, 11)

#: Quantiles surfaced on every histogram snapshot (p50/p95/p99).
SNAPSHOT_QUANTILES = (0.5, 0.95, 0.99)


def quantile_from_cumulative(
    cum: "Sequence[tuple[float, int]]", q: float
) -> "Optional[float]":
    """Estimate the q-quantile from cumulative (le, count) pairs.

    `cum` is `Histogram.cumulative()` output: ascending bucket edges
    ending with ``(inf, total)``. Within the containing bucket the
    estimate interpolates linearly between the bucket's lower and upper
    edge (the lower edge of the first bucket is taken as 0, matching
    Prometheus ``histogram_quantile`` semantics), so the error is
    bounded by that bucket's width. Observations in the +Inf overflow
    bucket clamp to the highest finite edge. Returns None on an empty
    histogram.
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1], got {q}")
    if not cum:
        return None
    total = cum[-1][1]
    if total == 0:
        return None
    target = q * total
    prev_edge, prev_cum = 0.0, 0
    for edge, c in cum:
        if c >= target and c > prev_cum:
            if math.isinf(edge):
                return prev_edge  # overflow: clamp to the top edge
            frac = (target - prev_cum) / (c - prev_cum)
            return prev_edge + frac * (edge - prev_edge)
        prev_edge, prev_cum = (prev_edge if math.isinf(edge) else edge), c
    return prev_edge


def _labels_key(labels: "Optional[dict]") -> tuple:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a gauge")
        with _lock:
            self.value += amount


class Gauge:
    """Point-in-time value."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, value: float) -> None:
        with _lock:
            self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with _lock:
            self.value += amount


class Histogram:
    """Histogram over fixed (exponential) bucket edges.

    `counts[i]` counts observations with ``value <= edges[i]`` exclusive
    of earlier buckets; the final slot is the +Inf overflow. Prometheus
    export emits the conventional cumulative ``_bucket`` series.
    """

    __slots__ = ("name", "labels", "edges", "counts", "sum", "count")

    def __init__(self, name: str, labels: tuple, edges: "Sequence[float]"):
        edges = tuple(float(e) for e in edges)
        if list(edges) != sorted(edges) or len(set(edges)) != len(edges):
            raise ValueError("bucket edges must be strictly increasing")
        self.name = name
        self.labels = labels
        self.edges = edges
        self.counts = [0] * (len(edges) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        value = float(value)
        if math.isnan(value):
            return
        idx = bisect.bisect_left(self.edges, value)
        with _lock:
            self.counts[idx] += 1
            self.sum += value
            self.count += 1

    def cumulative(self) -> "list[tuple[float, int]]":
        """(le_edge, cumulative_count) pairs, ending with (+inf, count)."""
        out, acc = [], 0
        with _lock:
            for edge, c in zip(self.edges, self.counts):
                acc += c
                out.append((edge, acc))
            out.append((math.inf, self.count))
        return out

    def quantile(self, q: float) -> "Optional[float]":
        """Interpolated q-quantile (see `quantile_from_cumulative`)."""
        return quantile_from_cumulative(self.cumulative(), q)

    def quantiles(
        self, qs: "Sequence[float]" = SNAPSHOT_QUANTILES
    ) -> "dict[str, Optional[float]]":
        """{"p50": ..., "p95": ..., "p99": ...} (None when empty)."""
        cum = self.cumulative()
        return {
            f"p{round(q * 100)}": quantile_from_cumulative(cum, q)
            for q in qs
        }


class _Noop:
    """Disabled-mode handle for every metric kind."""

    __slots__ = ()

    def inc(self, amount: float = 1.0):
        pass

    def set(self, value: float):
        pass

    def observe(self, value: float):
        pass


_NOOP = _Noop()


def _get(kind: str, cls, name: str, labels: "Optional[dict]", *args):
    if not state._enabled:
        return _NOOP
    key = (name, _labels_key(labels))
    with _lock:
        prev = _types.get(name)
        if prev is not None and prev != kind:
            raise ValueError(
                f"metric {name!r} already registered as {prev}, "
                f"cannot re-register as {kind}"
            )
        m = _metrics.get(key)
        if m is None:
            _types[name] = kind
            m = _metrics[key] = cls(name, key[1], *args)
        return m


def counter(name: str, labels: "Optional[dict]" = None) -> Counter:
    """Get-or-create the counter (name, labels). Registers at 0."""
    return _get("counter", Counter, name, labels)


def gauge(name: str, labels: "Optional[dict]" = None) -> Gauge:
    """Get-or-create the gauge (name, labels)."""
    return _get("gauge", Gauge, name, labels)


def histogram(
    name: str,
    labels: "Optional[dict]" = None,
    buckets: "Optional[Sequence[float]]" = None,
) -> Histogram:
    """Get-or-create the histogram; `buckets` binds at first creation."""
    return _get(
        "histogram", Histogram, name, labels, buckets or DEFAULT_BUCKETS
    )


def event(name: str, **fields) -> None:
    """Record a structured event (no-op when observability is off).

    Increments ``<name>_total`` labeled by the event's scalar fields,
    appends the full record to the event log, and marks the span
    timeline. Scalar fields (str/int/bool) become counter labels; other
    values ride only on the event record.
    """
    if not state._enabled:
        return
    labels = {
        k: v for k, v in fields.items() if isinstance(v, (str, int, bool))
    }
    counter(f"{name}_total", labels).inc()
    with _lock:
        _events.append(
            {"name": name, "ts": time.perf_counter(), "fields": dict(fields)}
        )
    _trace.add_instant(name, labels)


def events(name: "Optional[str]" = None) -> "list[dict]":
    """Snapshot of recorded events, optionally filtered by name."""
    with _lock:
        recs = list(_events)
    if name is None:
        return recs
    return [r for r in recs if r["name"] == name]


def reset() -> None:
    """Drop every registered metric and recorded event."""
    with _lock:
        _metrics.clear()
        _types.clear()
        _events.clear()


# ---------------------------------------------------------------------------
# Exporters.
# ---------------------------------------------------------------------------


def _fmt_labels(labels: tuple, extra: "Optional[tuple]" = None) -> str:
    items = list(labels) + (list(extra) if extra else [])
    if not items:
        return ""
    body = ",".join(
        '{}="{}"'.format(
            k, str(v).replace("\\", r"\\").replace('"', r"\"")
        )
        for k, v in items
    )
    return "{" + body + "}"


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    # %.12g keeps full useful precision while collapsing float-noise
    # edges like 1e-7*10**k -> "1e-07" instead of repr's 17 digits.
    return f"{f:.12g}"


def export_prometheus() -> str:
    """All registered metrics in Prometheus text exposition format."""
    with _lock:
        items = sorted(_metrics.items())
        types = dict(_types)
    lines = []
    seen_type = set()
    for (name, _), m in items:
        if name not in seen_type:
            seen_type.add(name)
            lines.append(f"# TYPE {name} {types[name]}")
        if isinstance(m, Histogram):
            for le, cum in m.cumulative():
                lines.append(
                    f"{name}_bucket"
                    f"{_fmt_labels(m.labels, (('le', _fmt_value(le)),))}"
                    f" {cum}"
                )
            lines.append(f"{name}_sum{_fmt_labels(m.labels)} {m.sum!r}")
            lines.append(f"{name}_count{_fmt_labels(m.labels)} {m.count}")
        else:
            lines.append(
                f"{name}{_fmt_labels(m.labels)} {_fmt_value(m.value)}"
            )
    return "\n".join(lines) + "\n" if lines else ""


def export_prometheus_file(path: str) -> str:
    """Write `export_prometheus()` to `path`; returns the path."""
    with open(path, "w") as fh:
        fh.write(export_prometheus())
    return path


def snapshot() -> dict:
    """All registered metrics as one JSON-able dict.

    Shape: ``{name: {type, series: [{labels, ...values}]}}`` — counters
    and gauges carry ``value``; histograms carry ``buckets`` (le →
    cumulative count), ``sum`` and ``count``.
    """
    with _lock:
        items = sorted(_metrics.items())
        types = dict(_types)
    out: dict = {}
    for (name, _), m in items:
        entry = out.setdefault(
            name, {"type": types[name], "series": []}
        )
        labels = {k: v for k, v in m.labels}
        if isinstance(m, Histogram):
            entry["series"].append(
                {
                    "labels": labels,
                    "buckets": [
                        {"le": _fmt_value(le), "count": cum}
                        for le, cum in m.cumulative()
                    ],
                    "sum": m.sum,
                    "count": m.count,
                    "quantiles": m.quantiles(),
                }
            )
        else:
            entry["series"].append({"labels": labels, "value": m.value})
    return out


def export_json() -> str:
    return json.dumps(snapshot(), indent=1, sort_keys=True)
