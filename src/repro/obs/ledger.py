"""Append-only performance-trajectory ledger (JSON Lines).

One line per benchmark (or opt-in engine) run: run id, wall-clock
timestamp, git SHA + dirty flag, jax/device metadata, the emitted
``us_per_call`` rows and — when observability is on — the embedded
metrics snapshot. The ledger is the durable perf trajectory that the
one-shot ``BENCH_<name>.json`` files never were: ``benchmarks/run.py``
appends to it on every invocation, ``benchmarks/regress.py`` compares
the latest run against the history it accumulates, and
``python -m repro.obs.report`` renders it as a dashboard.

The format is deliberately boring: UTF-8 JSONL, one self-contained
object per line, append-only (concurrent appenders interleave whole
lines on POSIX). `load` skips corrupt lines instead of failing so a
truncated write never poisons the trajectory.

Path resolution: an explicit ``path`` argument, else the
``REPRO_OBS_LEDGER`` environment variable, else
``artifacts/perf_ledger.jsonl`` under the current directory.
"""
from __future__ import annotations

import contextlib
import json
import os
import subprocess
import time
import uuid
from typing import Iterable, Optional

#: Bumped when the per-line payload layout changes.
ENTRY_SCHEMA = 1

DEFAULT_LEDGER = os.path.join("artifacts", "perf_ledger.jsonl")

#: Metadata keys a well-formed entry carries (regress matches runs on
#: the environment subset so CPU history never gates a TPU run, and —
#: via mesh_shape — a sharded sweep never gates a single-device one).
ENV_KEYS = ("jax_backend", "device_platform", "device_count", "mesh_shape")

# Ambient mesh tag for entries written while a sharded engine run is in
# flight ("data8" for an 8-wide sweep mesh; None = single device).
# Entries predating this key — and single-device runs — read back as
# None through dict.get, so old history keeps matching unsharded runs.
# Seedable via $REPRO_MESH_SHAPE so whole bench processes (the CI
# mesh-smoke job) can tag every entry they write.
_MESH_CONTEXT: "Optional[str]" = os.environ.get("REPRO_MESH_SHAPE") or None


def current_mesh_context() -> "Optional[str]":
    """The mesh tag new entries will carry ("data8"), or None."""
    return _MESH_CONTEXT


def set_mesh_context(shape: "Optional[str]") -> None:
    global _MESH_CONTEXT
    _MESH_CONTEXT = shape


@contextlib.contextmanager
def mesh_context(shape: "Optional[str]"):
    """Scope a mesh tag over ledger writes; None leaves the tag as-is."""
    if shape is None:
        yield
        return
    prev = _MESH_CONTEXT
    set_mesh_context(shape)
    try:
        yield
    finally:
        set_mesh_context(prev)


def default_path() -> str:
    """The ledger path: $REPRO_OBS_LEDGER, else artifacts/perf_ledger.jsonl."""
    return os.environ.get("REPRO_OBS_LEDGER") or DEFAULT_LEDGER


def _git(args: "list[str]") -> "Optional[str]":
    try:
        proc = subprocess.run(
            ["git", *args], capture_output=True, text=True, timeout=10
        )
        if proc.returncode != 0:
            return None
        return proc.stdout
    except Exception:
        return None


def git_state() -> "tuple[str, Optional[bool]]":
    """(HEAD sha or "unknown", dirty flag or None when git is absent)."""
    sha = (_git(["rev-parse", "HEAD"]) or "").strip() or "unknown"
    status = _git(["status", "--porcelain"])
    dirty = None if status is None else bool(status.strip())
    return sha, dirty


def run_metadata() -> dict:
    """git + jax/device/python metadata for a ledger entry.

    Mirrors the ``BENCH_<name>.json`` v2 metadata block; every field
    degrades to "unknown"/None rather than raising so the ledger can be
    written from environments without git or a usable jax backend.
    """
    import platform

    sha, dirty = git_state()
    meta = {
        "git_sha": sha,
        "git_dirty": dirty,
        "python_version": platform.python_version(),
        "jax_version": "unknown",
        "jax_backend": "unknown",
        "device_platform": "unknown",
        "device_count": 0,
        "mesh_shape": current_mesh_context(),
    }
    try:
        import jax

        devices = jax.devices()
        meta.update(
            jax_version=jax.__version__,
            jax_backend=jax.default_backend(),
            device_platform=devices[0].platform if devices else "none",
            device_count=len(devices),
        )
    except Exception:
        pass
    return meta


def _normalize_rows(rows) -> "list[dict]":
    out = []
    for r in rows:
        if isinstance(r, dict):
            out.append(
                {
                    "name": str(r["name"]),
                    "us_per_call": float(r["us_per_call"]),
                    "derived": str(r.get("derived", "")),
                }
            )
        else:  # benchmarks.common.CSV_ROWS tuples
            name, us, derived = r
            out.append(
                {
                    "name": str(name),
                    "us_per_call": float(us),
                    "derived": str(derived),
                }
            )
    return out


def make_entry(
    bench: str,
    rows,
    *,
    ok: bool = True,
    meta: "Optional[dict]" = None,
    metrics: "Optional[dict]" = None,
) -> dict:
    """Build one ledger entry (not yet written).

    Args:
      bench: benchmark / engine name ("solver", "engine.run_sweep", ...).
      rows: row dicts (name / us_per_call / derived) or the equivalent
        (name, us, derived) tuples from benchmarks.common.CSV_ROWS.
      ok: whether the run completed without raising.
      meta: metadata dict (see `run_metadata`); gathered fresh if None.
        A missing ``git_dirty`` is filled in from `git_state` so v2
        BENCH metadata can be passed through unchanged.
      metrics: a `repro.obs.snapshot()` dict to embed, or None.
    """
    meta = dict(meta) if meta is not None else run_metadata()
    if "git_dirty" not in meta:
        meta["git_dirty"] = git_state()[1]
    if "mesh_shape" not in meta:
        meta["mesh_shape"] = current_mesh_context()
    meta.pop("schema_version", None)  # BENCH json versioning, not ours
    now = time.time()
    return {
        "schema": ENTRY_SCHEMA,
        "run_id": uuid.uuid4().hex[:12],
        "ts_unix": now,
        "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime(now)),
        "bench": str(bench),
        "ok": bool(ok),
        **meta,
        "rows": _normalize_rows(rows),
        "metrics": metrics,
    }


def append(entry: dict, path: "Optional[str]" = None) -> str:
    """Append one entry as a single JSONL line; returns the path."""
    path = path or default_path()
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    line = json.dumps(entry, sort_keys=True)
    if "\n" in line:
        raise ValueError("ledger entries must serialize to one line")
    with open(path, "a", encoding="utf-8") as fh:
        fh.write(line + "\n")
    return path


def record_run(
    bench: str,
    rows,
    *,
    ok: bool = True,
    meta: "Optional[dict]" = None,
    metrics: "Optional[dict]" = None,
    path: "Optional[str]" = None,
) -> dict:
    """`make_entry` + `append`; returns the written entry."""
    entry = make_entry(bench, rows, ok=ok, meta=meta, metrics=metrics)
    append(entry, path)
    return entry


def load(path: "Optional[str]" = None) -> "list[dict]":
    """All parseable entries, oldest first; missing file → empty list.

    Corrupt lines (truncated writes, merge debris) are silently
    dropped; use `load_report` when the caller wants the skip count.
    """
    return load_report(path)[0]


def load_report(path: "Optional[str]" = None) -> "tuple[list[dict], int]":
    """(entries, n_skipped_corrupt_lines)."""
    path = path or default_path()
    entries: "list[dict]" = []
    skipped = 0
    if not os.path.exists(path):
        return entries, skipped
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except ValueError:
                skipped += 1
                continue
            if isinstance(obj, dict) and "bench" in obj and "rows" in obj:
                entries.append(obj)
            else:
                skipped += 1
    return entries, skipped


def matching(
    entries: "Iterable[dict]",
    *,
    bench: "Optional[str]" = None,
    env_of: "Optional[dict]" = None,
    ok_only: bool = True,
) -> "list[dict]":
    """Filter entries by bench name and execution environment.

    `env_of` is a reference entry (or metadata dict): candidates must
    agree on every `ENV_KEYS` field so timings are only ever compared
    within one backend/device population.
    """
    out = []
    for e in entries:
        if bench is not None and e.get("bench") != bench:
            continue
        if ok_only and not e.get("ok", False):
            continue
        if env_of is not None and any(
            e.get(k) != env_of.get(k) for k in ENV_KEYS
        ):
            continue
        out.append(e)
    return out


def row_values(entries: "Iterable[dict]", row: str) -> "list[float]":
    """The us_per_call trajectory of one row across entries (in order)."""
    vals = []
    for e in entries:
        for r in e.get("rows", ()):
            if r.get("name") == row:
                vals.append(float(r["us_per_call"]))
                break
    return vals


def engine_opt_in() -> "Optional[str]":
    """Ledger path for opt-in engine-run recording, or None.

    Engines record a ledger entry per run only when *both* the
    observability flag is on and ``REPRO_OBS_LEDGER`` names a path —
    a plain `run_sweep` in a notebook never touches the filesystem.
    """
    from repro.obs import state

    if not state._enabled:
        return None
    return os.environ.get("REPRO_OBS_LEDGER") or None


def record_engine_run(
    name: str, seconds: float, *, count: int = 1, derived: str = ""
) -> "Optional[dict]":
    """Record one engine invocation when `engine_opt_in` allows it.

    Embeds the current metrics snapshot so solver telemetry accumulated
    during the run (sweep histograms etc.) rides along with the timing.
    """
    path = engine_opt_in()
    if path is None:
        return None
    from repro.obs import metrics as _metrics

    row = {
        "name": f"engine/{name}",
        "us_per_call": seconds * 1e6 / max(count, 1),
        "derived": derived,
    }
    return record_run(
        f"engine.{name}",
        [row],
        metrics=_metrics.snapshot(),
        path=path,
    )
