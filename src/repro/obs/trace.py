"""Span tracer: nestable, thread-safe, exportable.

`trace(name)` is a context manager recording one wall-clock span;
`traced(name)` is the decorator form (the enabled check happens at call
time, so functions decorated while observability is off start tracing
as soon as it is enabled). Spans nest through a thread-local stack and
carry their parent id and depth, so the same records export both as
Chrome ``trace_event`` JSON (chrome://tracing, Perfetto) and as an
indented plain-text tree (`span_tree`).

`instrument_jit` wraps a ``jax.jit``-ed callable so every call records a
span split into ``name[compile]`` (the call populated a new executable —
lowering + compilation + first run) vs ``name[run]`` (steady-state
execution against a cached executable), using the jit cache size as the
miss detector. While tracing it blocks until the outputs are ready so
span durations measure device execution, not async dispatch — the
tracer never injects host callbacks *inside* a traced computation.

When observability is disabled (`repro.obs.state`), `trace` returns a
shared no-op handle: no span objects, no lock traffic, no allocations.
"""
from __future__ import annotations

import functools
import itertools
import json
import os
import threading
import time
from typing import Callable, Optional

from repro.obs import state

_lock = threading.Lock()
_spans: "list[Span]" = []          # finished spans, in completion order
_live: "dict[int, Span]" = {}      # open spans by sid (flushed on export)
_instants: "list[dict]" = []       # point-in-time marks (obs.event)
_ids = itertools.count(1)          # thread-safe under CPython
_local = threading.local()


def _stack() -> list:
    st = getattr(_local, "stack", None)
    if st is None:
        st = _local.stack = []
    return st


class Span:
    """One live-or-finished span. Use via ``with trace(name):``."""

    __slots__ = (
        "name", "attrs", "sid", "parent", "depth", "tid", "t_start", "t_end"
    )

    def __init__(self, name: str, attrs: "Optional[dict]" = None):
        self.name = name
        self.attrs = attrs
        self.sid = 0
        self.parent: "Optional[int]" = None
        self.depth = 0
        self.tid = 0
        self.t_start = 0.0
        self.t_end = 0.0

    def set(self, key: str, value) -> None:
        """Attach an attribute (exported in the Chrome trace ``args``)."""
        if self.attrs is None:
            self.attrs = {}
        self.attrs[key] = value

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start

    def __enter__(self) -> "Span":
        stack = _stack()
        self.sid = next(_ids)
        self.tid = threading.get_ident()
        if stack:
            top = stack[-1]
            self.parent = top.sid
            self.depth = top.depth + 1
        stack.append(self)
        with _lock:
            _live[self.sid] = self
        self.t_start = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.t_end = time.perf_counter()
        if exc_type is not None:
            # The exception keeps unwinding (we return False); the span
            # records what killed its body so failed stages are visible
            # in the exported trace instead of silently short.
            self.set("error", f"{exc_type.__name__}: {exc}")
        stack = _stack()
        if stack and stack[-1] is self:
            stack.pop()
        with _lock:
            _live.pop(self.sid, None)
            _spans.append(self)
        return False


class _NoopSpan:
    """Shared disabled-mode handle: enter/exit/set are free."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, key, value):
        pass

    @property
    def duration(self) -> float:
        return 0.0


_NOOP = _NoopSpan()


def trace(name: str, attrs: "Optional[dict]" = None):
    """Context manager recording one span (no-op when obs is disabled).

    Args:
      name: span label (dots/brackets render fine in both exporters).
      attrs: optional dict of attributes (Chrome trace ``args``).
    """
    if not state._enabled:
        return _NOOP
    return Span(name, attrs)


def traced(name: "Optional[str]" = None, attrs: "Optional[dict]" = None):
    """Decorator form of `trace`; checks the enable flag per call."""

    def deco(fn: Callable) -> Callable:
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapped(*args, **kw):
            if not state._enabled:
                return fn(*args, **kw)
            with trace(label, dict(attrs) if attrs else None):
                return fn(*args, **kw)

        return wrapped

    return deco


def instrument_jit(fn: Callable, name: str) -> Callable:
    """Wrap a jitted callable with compile-vs-run split spans.

    Each call records ``name[compile]`` when it populated a new jit
    executable (first call for a new input signature: lowering +
    compilation + run) or ``name[run]`` for steady-state execution.
    While tracing, the wrapper blocks until the outputs are ready so the
    span covers device time; with observability disabled it forwards
    with zero added work beyond one flag check.
    """
    cache_size = getattr(fn, "_cache_size", None)
    n_calls = itertools.count()

    @functools.wraps(fn)
    def wrapped(*args, **kw):
        if not state._enabled:
            return fn(*args, **kw)
        import jax

        before = cache_size() if cache_size is not None else next(n_calls)
        sp = Span(name)
        with sp:
            out = jax.block_until_ready(fn(*args, **kw))
            after = cache_size() if cache_size is not None else before + 1
            compiled = after > before
            sp.name = f"{name}[compile]" if compiled else f"{name}[run]"
            sp.set("compiled", compiled)
        return out

    return wrapped


def add_instant(name: str, attrs: "Optional[dict]" = None) -> None:
    """Record a point-in-time mark on the trace timeline (obs.event)."""
    if not state._enabled:
        return
    rec = {
        "name": name,
        "ts": time.perf_counter(),
        "tid": threading.get_ident(),
        "attrs": dict(attrs) if attrs else {},
    }
    with _lock:
        _instants.append(rec)


def spans() -> "list[Span]":
    """Snapshot of the finished spans recorded so far."""
    with _lock:
        return list(_spans)


def live_spans() -> "list[Span]":
    """Snapshot of the spans currently open (entered, not yet exited)."""
    with _lock:
        return list(_live.values())


def reset() -> None:
    """Drop all recorded spans and instant marks (open spans too)."""
    with _lock:
        _spans.clear()
        _live.clear()
        _instants.clear()


# ---------------------------------------------------------------------------
# Exporters.
# ---------------------------------------------------------------------------


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


def chrome_trace() -> dict:
    """The recorded spans as a Chrome ``trace_event`` JSON object.

    Loadable by chrome://tracing and https://ui.perfetto.dev. Spans are
    complete ('X') events with microsecond timestamps; instant marks
    ('i') carry their fields as args. Still-open spans are flushed as
    complete events truncated at export time and tagged
    ``unfinished=true`` — a crash mid-pipeline must not drop the very
    spans that show where it died. Timestamps are rebased to the
    earliest recorded event so the trace starts near t=0.
    """
    now = time.perf_counter()
    with _lock:
        done = list(_spans)
        open_ = list(_live.values())
        marks = list(_instants)
    t0 = min(
        [s.t_start for s in done + open_] + [m["ts"] for m in marks],
        default=0.0,
    )
    pid = os.getpid()
    events = [
        {
            "name": s.name,
            "cat": "repro",
            "ph": "X",
            "ts": (s.t_start - t0) * 1e6,
            "dur": (s.t_end - s.t_start) * 1e6,
            "pid": pid,
            "tid": s.tid,
            "args": {k: _jsonable(v) for k, v in (s.attrs or {}).items()},
        }
        for s in done
    ]
    events += [
        {
            "name": s.name,
            "cat": "repro",
            "ph": "X",
            "ts": (s.t_start - t0) * 1e6,
            "dur": (now - s.t_start) * 1e6,
            "pid": pid,
            "tid": s.tid,
            "args": {
                **{k: _jsonable(v) for k, v in (s.attrs or {}).items()},
                "unfinished": True,
            },
        }
        for s in open_
    ]
    events += [
        {
            "name": m["name"],
            "cat": "repro",
            "ph": "i",
            "s": "t",
            "ts": (m["ts"] - t0) * 1e6,
            "pid": pid,
            "tid": m["tid"],
            "args": {k: _jsonable(v) for k, v in m["attrs"].items()},
        }
        for m in marks
    ]
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def export_chrome_trace(path: str) -> str:
    """Write `chrome_trace()` to `path`; returns the path."""
    with open(path, "w") as fh:
        json.dump(chrome_trace(), fh, indent=1)
        fh.write("\n")
    return path


def span_tree() -> str:
    """The recorded spans as an indented plain-text tree (per thread)."""
    with _lock:
        done = sorted(_spans, key=lambda s: (s.tid, s.t_start, s.sid))
    if not done:
        return "(no spans recorded)"
    lines = []
    threads = sorted({s.tid for s in done})
    for tid in threads:
        if len(threads) > 1:
            lines.append(f"thread {tid}")
        for s in done:
            if s.tid != tid:
                continue
            extra = ""
            if s.attrs:
                extra = " " + " ".join(
                    f"{k}={_jsonable(v)}" for k, v in s.attrs.items()
                )
            lines.append(
                f"{'  ' * s.depth}{s.name:<40s} "
                f"{(s.t_end - s.t_start) * 1e3:10.2f} ms{extra}"
            )
    return "\n".join(lines)
