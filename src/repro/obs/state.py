"""Process-wide observability switch.

All of `repro.obs` is gated on one module-level flag so instrumented hot
paths pay a single function call (returning a cached bool) when
observability is off — no span objects, no metric lookups, no
allocations. The flag starts from the ``REPRO_OBS`` environment variable
(any value other than empty/``0``/``false`` enables) and can be toggled
at runtime with `enable` / `disable`.

Instrumentation that must *construct* data before recording (label
dicts, host transfers of device scalars) should guard with
``if obs.enabled():`` at the call site so the construction itself is
skipped on the disabled path.
"""
from __future__ import annotations

import os

def _env_enabled() -> bool:
    """Read ``REPRO_OBS``: anything but empty/0/false/no enables."""
    return os.environ.get("REPRO_OBS", "").lower() not in (
        "", "0", "false", "no",
    )


_enabled: bool = _env_enabled()


def enabled() -> bool:
    """Whether observability (spans, metrics, events) is recording."""
    return _enabled


def enable() -> None:
    """Turn observability on for the rest of the process (idempotent)."""
    global _enabled
    _enabled = True


def disable() -> None:
    """Turn observability off; already-recorded data is kept."""
    global _enabled
    _enabled = False
