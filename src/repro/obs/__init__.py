"""repro.obs — dependency-free tracing, metrics and telemetry events.

The observability layer shared by all four engines (explore /
variability / transient / spice-lowered evaluation) and the solver
backend registry:

  * **span tracer** — ``with obs.trace("solve_dc"): ...`` /
    ``@obs.traced()``; thread-safe, nestable; exports Chrome
    ``trace_event`` JSON (`export_chrome_trace`) and a plain-text tree
    (`span_tree`). `instrument_jit` splits jitted calls into
    ``[compile]`` vs ``[run]`` spans.
  * **metrics registry** — `counter` / `gauge` / `histogram` (fixed
    exponential buckets) with Prometheus text (`export_prometheus`) and
    JSON (`snapshot` / `export_json`) exporters.
  * **structured events** — `event("backend_fallback", cause=...)`
    counts occurrences, keeps an assertable event log (`events`), and
    marks the span timeline.

Everything is gated on one process-wide flag (`enable` / `disable` /
``REPRO_OBS=1``); when disabled, every entry point is a single flag
check returning shared no-op handles — zero allocations on the hot
path. See README § Observability.

The continuous-performance tier lives in submodules: `repro.obs.ledger`
(append-only JSONL run ledger), `repro.obs.regress` (noise-aware
regression verdicts against the ledger history), `repro.obs.prof`
(jax.profiler capture, device-memory watermarks, HLO cost analysis)
and `repro.obs.report` (``python -m repro.obs.report`` dashboard). See
README § Performance tracking.
"""
from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    RESIDUAL_BUCKETS,
    SECONDS_BUCKETS,
    SNAPSHOT_QUANTILES,
    SWEEPS_BUCKETS,
    counter,
    event,
    events,
    export_json,
    export_prometheus,
    export_prometheus_file,
    exponential_buckets,
    gauge,
    histogram,
    quantile_from_cumulative,
    snapshot,
)
from repro.obs.metrics import reset as _reset_metrics
from repro.obs.state import disable, enable, enabled
from repro.obs.trace import (
    Span,
    add_instant,
    chrome_trace,
    export_chrome_trace,
    instrument_jit,
    span_tree,
    spans,
    trace,
    traced,
)
from repro.obs.trace import reset as _reset_traces
from repro.obs import ledger, prof, regress  # noqa: E402  (submodules)


def reset() -> None:
    """Drop all recorded spans, events and metrics (keeps the flag)."""
    _reset_traces()
    _reset_metrics()
    prof.reset_cost()


__all__ = [
    "DEFAULT_BUCKETS",
    "RESIDUAL_BUCKETS",
    "SECONDS_BUCKETS",
    "SNAPSHOT_QUANTILES",
    "SWEEPS_BUCKETS",
    "Span",
    "add_instant",
    "chrome_trace",
    "counter",
    "disable",
    "enable",
    "enabled",
    "event",
    "events",
    "export_chrome_trace",
    "export_json",
    "export_prometheus",
    "export_prometheus_file",
    "exponential_buckets",
    "gauge",
    "histogram",
    "instrument_jit",
    "ledger",
    "prof",
    "quantile_from_cumulative",
    "regress",
    "reset",
    "snapshot",
    "span_tree",
    "spans",
    "trace",
    "traced",
]
