"""Render the performance ledger as a text / markdown dashboard.

``python -m repro.obs.report`` reads the JSONL run ledger
(`repro.obs.ledger`) and prints, per benchmark:

  * the latest run's provenance — run id, timestamp, git SHA (+dirty
    marker), jax version/backend, device platform and count;
  * one line per timing row: latest ``us_per_call``, the noise-aware
    baseline verdict from `repro.obs.regress`, and the recent
    trajectory (oldest → newest);
  * the latest run's histogram quantiles (p50/p95/p99) from the
    embedded metrics snapshot — solver sweeps, residuals, jit runtimes.

Usage:
  python -m repro.obs.report [--ledger PATH] [--bench NAME]
                             [--last N] [--markdown]
"""
from __future__ import annotations

import argparse
import math
import sys
from typing import Optional

from repro.obs import ledger, metrics, regress


def _fmt_us(v: "Optional[float]") -> str:
    if v is None:
        return "—"
    if v >= 1e6:
        return f"{v / 1e6:.2f}s"
    if v >= 1e3:
        return f"{v / 1e3:.1f}ms"
    return f"{v:.1f}us"


def _fmt_q(v: "Optional[float]") -> str:
    if v is None:
        return "—"
    if v != 0 and (abs(v) >= 1e4 or abs(v) < 1e-2):
        return f"{v:.3g}"
    return f"{v:.2f}"


def _parse_le(le) -> float:
    if isinstance(le, str) and le.strip() in ("+Inf", "Inf", "inf"):
        return math.inf
    return float(le)


def series_quantiles(series: dict) -> "dict[str, Optional[float]]":
    """p50/p95/p99 of one snapshot histogram series.

    Prefers the precomputed ``quantiles`` block (snapshots written
    since quantile support landed); falls back to recomputing from the
    cumulative buckets so older ledger entries still render.
    """
    q = series.get("quantiles")
    if isinstance(q, dict) and q:
        return q
    cum = [
        (_parse_le(b["le"]), int(b["count"]))
        for b in series.get("buckets", ())
    ]
    return {
        f"p{round(qq * 100)}": metrics.quantile_from_cumulative(cum, qq)
        for qq in metrics.SNAPSHOT_QUANTILES
    }


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) + "}"


def _header(entry: dict, md: bool) -> "list[str]":
    dirty = entry.get("git_dirty")
    sha = str(entry.get("git_sha", "unknown"))[:12] + (
        "+dirty" if dirty else ""
    )
    line = (
        f"run {entry.get('run_id', '?')} at {entry.get('ts', '?')} — "
        f"git {sha} — jax {entry.get('jax_version', '?')} "
        f"[{entry.get('jax_backend', '?')}] — "
        f"{entry.get('device_count', '?')}x "
        f"{entry.get('device_platform', '?')}"
    )
    return [f"*{line}*" if md else line]


def render_bench(
    entries: "list[dict]", bench: str, *, last: int = 8, markdown: bool = False
) -> "list[str]":
    """Dashboard lines for one benchmark's trajectory."""
    hist = ledger.matching(entries, bench=bench, ok_only=False)
    if not hist:
        return []
    latest = hist[-1]
    lines = []
    lines.append(f"## {bench}" if markdown else f"=== {bench} ===")
    lines += _header(latest, markdown)
    verdicts = {v.row: v for v in regress.compare(latest, hist)}
    ok_hist = ledger.matching(hist, bench=bench, env_of=latest)
    if markdown:
        lines.append("")
        lines.append("| row | latest | baseline | verdict | trajectory |")
        lines.append("|---|---|---|---|---|")
    else:
        lines.append(
            f"  {'row':<44s} {'latest':>10s} {'baseline':>10s} "
            f"{'verdict':<12s} trajectory"
        )
    for row in latest.get("rows", ()):
        name = row["name"]
        us = float(row["us_per_call"])
        if us <= 0:
            continue
        v = verdicts.get(name)
        base = _fmt_us(v.baseline_us) if v else "—"
        status = v.status if v else "new"
        traj = ledger.row_values(ok_hist, name)[-last:]
        spark = " ".join(_fmt_us(t) for t in traj)
        if markdown:
            lines.append(
                f"| `{name}` | {_fmt_us(us)} | {base} | {status} "
                f"| {spark} |"
            )
        else:
            lines.append(
                f"  {name:<44s} {_fmt_us(us):>10s} {base:>10s} "
                f"{status:<12s} {spark}"
            )
    qlines = quantile_lines(latest, markdown)
    if qlines:
        lines.append("")
        lines.append(
            "### histogram quantiles (latest run)"
            if markdown
            else "  histogram quantiles (latest run):"
        )
        lines += qlines
    lines.append("")
    return lines


def quantile_lines(entry: dict, markdown: bool = False) -> "list[str]":
    """p50/p95/p99 lines for every histogram in the embedded snapshot."""
    snap = entry.get("metrics")
    if not isinstance(snap, dict):
        return []
    out = []
    for name in sorted(snap):
        fam = snap[name]
        if fam.get("type") != "histogram":
            continue
        for series in fam.get("series", ()):
            if not series.get("count"):
                continue
            qs = series_quantiles(series)
            body = " ".join(
                f"{k}={_fmt_q(qs.get(k))}" for k in ("p50", "p95", "p99")
            )
            label = f"{name}{_fmt_labels(series.get('labels', {}))}"
            if markdown:
                out.append(f"- `{label}`: {body} (n={series['count']})")
            else:
                out.append(f"    {label:<40s} {body} (n={series['count']})")
    return out


def render(
    entries: "list[dict]",
    *,
    bench: "Optional[str]" = None,
    last: int = 8,
    markdown: bool = False,
) -> str:
    """The full dashboard for a loaded ledger."""
    if not entries:
        return "(ledger is empty)"
    benches = sorted({e.get("bench", "?") for e in entries})
    if bench is not None:
        benches = [b for b in benches if b == bench]
        if not benches:
            return f"(no ledger entries for bench {bench!r})"
    title = "# Performance trajectory" if markdown else "PERFORMANCE TRAJECTORY"
    lines = [title, ""]
    for b in benches:
        lines += render_bench(entries, b, last=last, markdown=markdown)
    return "\n".join(lines).rstrip() + "\n"


def main(argv: "Optional[list[str]]" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report", description=__doc__
    )
    ap.add_argument(
        "--ledger",
        default=None,
        help="ledger path (default: $REPRO_OBS_LEDGER or "
        "artifacts/perf_ledger.jsonl)",
    )
    ap.add_argument("--bench", default=None, help="restrict to one bench")
    ap.add_argument(
        "--last", type=int, default=8, help="trajectory points per row"
    )
    ap.add_argument(
        "--markdown", action="store_true", help="emit markdown instead of text"
    )
    args = ap.parse_args(argv)
    entries, skipped = ledger.load_report(args.ledger)
    if skipped:
        print(f"# skipped {skipped} corrupt ledger line(s)", file=sys.stderr)
    print(
        render(
            entries,
            bench=args.bench,
            last=args.last,
            markdown=args.markdown,
        ),
        end="",
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
