"""Deep profiling hooks: jax.profiler capture, device-memory watermarks,
and per-jitted-fn HLO cost analysis joined with measured runtimes.

Three opt-in layers on top of the base tracer/metrics:

  * `jax_profile(logdir)` — context manager wrapping
    ``jax.profiler.trace``; also armed by the
    ``REPRO_OBS_JAX_PROFILE`` environment variable so any entry point
    (benchmarks, engines, services) can capture a TensorBoard-loadable
    device profile without code changes.
  * `sample_memory(stage)` — device-memory gauges
    (``device_bytes_in_use`` / ``device_peak_bytes_in_use`` labeled by
    pipeline stage), sampled around the map/stamp/solve/measure stage
    spans. CPU backends without ``memory_stats()`` are a silent no-op.
  * `instrument_jit(fn, name)` — the tracer's compile-vs-run span split
    *plus*, when cost profiling is enabled (``REPRO_OBS_COST=1`` or
    `enable_cost`), a one-time HLO ``cost_analysis()`` per input
    signature recording ``hlo_flops`` / ``hlo_bytes_accessed`` gauges,
    a per-call ``jit_seconds`` histogram, and — for steady-state calls
    — ``achieved_flops_per_s`` and ``roofline_utilization`` against
    `peak_flops`. Cost analysis relowers the function once per new
    signature, which is why it is opt-in.
"""
from __future__ import annotations

import contextlib
import functools
import os
import time
from typing import Callable, Optional

from repro.obs import metrics, state

# The package re-exports the trace() *function* under the submodule's
# name, which shadows the attribute `import repro.obs.trace as _trace`
# would resolve — go through sys.modules to bind the module itself.
import importlib

_trace = importlib.import_module("repro.obs.trace")

#: Nominal peak FLOP/s per device platform for roofline utilization.
#: Override with REPRO_OBS_PEAK_FLOPS (floats accepted, e.g. "1.97e14").
#: CPU peaks vary too much across hosts to guess — utilization is only
#: reported when a peak is known.
PLATFORM_PEAK_FLOPS = {
    "tpu": 1.97e14,  # TPU v4 bf16 MXU peak per chip
    "gpu": None,
    "cpu": None,
}

_cost_flag: "Optional[bool]" = None
_costs: "dict[str, dict]" = {}  # fn name -> last recorded cost dict
_cost_seen: "set[tuple]" = set()


def cost_enabled() -> bool:
    """Whether HLO cost analysis runs inside `instrument_jit`."""
    if _cost_flag is not None:
        return _cost_flag
    return os.environ.get("REPRO_OBS_COST", "").lower() not in (
        "", "0", "false", "no",
    )


def enable_cost() -> None:
    global _cost_flag
    _cost_flag = True


def disable_cost() -> None:
    global _cost_flag
    _cost_flag = False


def reset_cost() -> None:
    """Forget the flag override and every cached cost record."""
    global _cost_flag
    _cost_flag = None
    _costs.clear()
    _cost_seen.clear()


def peak_flops(platform: "Optional[str]" = None) -> "Optional[float]":
    """Peak device FLOP/s for utilization, or None when unknown."""
    env = os.environ.get("REPRO_OBS_PEAK_FLOPS")
    if env:
        try:
            return float(env)
        except ValueError:
            pass
    if platform is None:
        try:
            import jax

            platform = jax.devices()[0].platform
        except Exception:
            return None
    return PLATFORM_PEAK_FLOPS.get(platform)


@contextlib.contextmanager
def jax_profile(logdir: "Optional[str]" = None):
    """Capture a jax.profiler trace into `logdir` (TensorBoard format).

    `logdir` defaults to ``$REPRO_OBS_JAX_PROFILE``; when neither is
    set the context is a no-op, so call sites can wrap unconditionally.
    """
    logdir = logdir or os.environ.get("REPRO_OBS_JAX_PROFILE")
    if not logdir:
        yield None
        return
    import jax

    os.makedirs(logdir, exist_ok=True)
    with _trace.trace("jax_profile", {"logdir": logdir}):
        with jax.profiler.trace(logdir):
            yield logdir


def device_memory_stats() -> "Optional[dict]":
    """`memory_stats()` of the first local device, or None (CPU)."""
    try:
        import jax

        dev = jax.local_devices()[0]
        stats = dev.memory_stats()
    except Exception:
        return None
    if not stats:
        return None
    return dict(stats)


def sample_memory(stage: str) -> "Optional[dict]":
    """Record device-memory watermark gauges for one pipeline stage.

    Gauges: ``device_bytes_in_use{stage=...}`` (live allocation at the
    sample point) and ``device_peak_bytes_in_use{stage=...}`` (the
    allocator's high-water mark). Returns the raw stats dict, or None
    when disabled or the backend exposes no memory stats.
    """
    if not state._enabled:
        return None
    stats = device_memory_stats()
    if stats is None:
        return None
    labels = {"stage": stage}
    if "bytes_in_use" in stats:
        metrics.gauge("device_bytes_in_use", labels).set(
            stats["bytes_in_use"]
        )
    peak = stats.get("peak_bytes_in_use")
    if peak is not None:
        g = metrics.gauge("device_peak_bytes_in_use", labels)
        if peak > g.value:
            g.set(peak)
    return stats


def hlo_cost(jitfn: Callable, *args, **kw) -> "Optional[dict]":
    """FLOPs / bytes-accessed of `jitfn` at this signature via XLA.

    Lowers and compiles the jitted callable (AOT path — cached by jax
    per signature) and returns the normalized ``cost_analysis()`` dict:
    ``{"flops": float, "bytes_accessed": float, ...}``. Returns None
    when the backend implements no cost analysis or lowering fails
    (e.g. exotic custom calls).
    """
    try:
        cost = jitfn.lower(*args, **kw).compile().cost_analysis()
    except Exception:
        return None
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    if not cost:
        return None
    out = {}
    for key, norm in (
        ("flops", "flops"),
        ("bytes accessed", "bytes_accessed"),
        ("transcendentals", "transcendentals"),
        ("optimal_seconds", "optimal_seconds"),
    ):
        if key in cost:
            try:
                out[norm] = float(cost[key])
            except (TypeError, ValueError):
                pass
    return out or None


def last_cost(name: str) -> "Optional[dict]":
    """The most recent cost record for an instrumented fn, or None."""
    return _costs.get(name)


def _sig_key(name: str, args, kw) -> tuple:
    import jax

    leaves = jax.tree_util.tree_leaves((args, kw))
    return (
        name,
        tuple(
            (getattr(v, "shape", None), str(getattr(v, "dtype", type(v))))
            for v in leaves
        ),
    )


def _record_cost(jitfn: Callable, name: str, args, kw) -> "Optional[dict]":
    cost = hlo_cost(jitfn, *args, **kw)
    if cost is None:
        return None
    _costs[name] = cost
    labels = {"fn": name}
    if "flops" in cost:
        metrics.gauge("hlo_flops", labels).set(cost["flops"])
    if "bytes_accessed" in cost:
        metrics.gauge("hlo_bytes_accessed", labels).set(
            cost["bytes_accessed"]
        )
    return cost


def instrument_jit(fn: Callable, name: str) -> Callable:
    """Span split + runtime histogram + opt-in HLO cost join.

    Wraps `trace.instrument_jit` (``name[compile]`` / ``name[run]``
    spans, block-until-ready timing) and additionally:

      * observes every traced call into a ``jit_seconds{fn=name}``
        histogram (`metrics.SECONDS_BUCKETS`);
      * with cost profiling on, runs `hlo_cost` once per new input
        signature (gauges ``hlo_flops`` / ``hlo_bytes_accessed``) and,
        on steady-state calls, derives ``achieved_flops_per_s{fn=name}``
        = flops / measured seconds plus ``roofline_utilization``
        against `peak_flops` when a platform peak is known.
    """
    traced_fn = _trace.instrument_jit(fn, name)

    @functools.wraps(fn)
    def wrapped(*args, **kw):
        if not state._enabled:
            return fn(*args, **kw)
        t0 = time.perf_counter()
        out = traced_fn(*args, **kw)
        dt = time.perf_counter() - t0
        metrics.histogram(
            "jit_seconds", {"fn": name}, buckets=metrics.SECONDS_BUCKETS
        ).observe(dt)
        if cost_enabled():
            try:
                key = _sig_key(name, args, kw)
            except Exception:
                key = None
            if key is not None and key not in _cost_seen:
                # First call at this signature: the measured time is
                # dominated by compilation — record the cost, skip the
                # throughput join.
                _cost_seen.add(key)
                _record_cost(fn, name, args, kw)
            else:
                cost = _costs.get(name)
                if cost and cost.get("flops") and dt > 0:
                    achieved = cost["flops"] / dt
                    metrics.gauge(
                        "achieved_flops_per_s", {"fn": name}
                    ).set(achieved)
                    peak = peak_flops()
                    if peak:
                        metrics.gauge(
                            "roofline_utilization", {"fn": name}
                        ).set(achieved / peak)
        return out

    return wrapped
