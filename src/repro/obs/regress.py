"""Noise-aware performance-regression verdicts over the run ledger.

Given the latest ledger entry for a benchmark and its history on the
same backend/device, produce one `RowVerdict` per timing row:

  * **baseline** — the median ``us_per_call`` of the last
    ``n_baseline`` matching historical runs (median, not mean: one
    noisy CI run must not drag the reference).
  * **threshold** — derived from the historical spread: the relative
    median-absolute-deviation widened by `k`, floored at `min_ratio`
    so a perfectly-quiet history still tolerates scheduler jitter.
    A row regresses when ``current > baseline * threshold`` and is an
    improvement below ``baseline / threshold``.
  * rows without history are ``new``; rows with fewer than
    ``min_history`` observations are ``insufficient`` (never gate);
    zero/negative timings (derived-only rows) are ``skipped``.

`benchmarks/regress.py` is the CLI wrapper that turns verdicts into an
exit code for CI.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Iterable, Optional

from repro.obs import ledger

#: Spread multiplier: threshold_ratio = 1 + K_SPREAD * (MAD / median).
K_SPREAD = 6.0

#: Minimum tolerated ratio even for a zero-spread history (±25%
#: covers same-machine scheduler noise on µs-scale rows).
MIN_RATIO = 1.25

#: Rows need this many historical observations before they can gate.
MIN_HISTORY = 2

GATING = ("regression",)


@dataclasses.dataclass(frozen=True)
class RowVerdict:
    """Comparison of one timing row against its ledger history."""

    row: str
    status: str  # ok | regression | improved | new | insufficient | skipped
    current_us: float
    baseline_us: "Optional[float]"
    ratio: "Optional[float]"  # current / baseline
    threshold: float  # tolerated ratio
    n_history: int

    @property
    def gating(self) -> bool:
        return self.status in GATING


def noise_threshold(
    values: "list[float]",
    *,
    min_ratio: float = MIN_RATIO,
    k: float = K_SPREAD,
) -> float:
    """Tolerated current/baseline ratio given the historical spread."""
    if len(values) < 2:
        return min_ratio
    med = statistics.median(values)
    if med <= 0:
        return min_ratio
    mad = statistics.median(abs(v - med) for v in values)
    return max(min_ratio, 1.0 + k * (mad / med))


def judge_row(
    row: str,
    current_us: float,
    history_us: "list[float]",
    *,
    n_baseline: int = 5,
    min_ratio: float = MIN_RATIO,
    k: float = K_SPREAD,
    min_history: int = MIN_HISTORY,
) -> RowVerdict:
    """Verdict for one row given its raw historical trajectory."""
    if current_us <= 0:
        return RowVerdict(
            row, "skipped", current_us, None, None, min_ratio,
            len(history_us),
        )
    window = [v for v in history_us[-n_baseline:] if v > 0]
    if not window:
        return RowVerdict(
            row, "new", current_us, None, None, min_ratio, 0
        )
    base = statistics.median(window)
    thr = noise_threshold(window, min_ratio=min_ratio, k=k)
    ratio = current_us / base
    if len(window) < min_history:
        status = "insufficient"
    elif ratio > thr:
        status = "regression"
    elif ratio < 1.0 / thr:
        status = "improved"
    else:
        status = "ok"
    return RowVerdict(row, status, current_us, base, ratio, thr, len(window))


def compare(
    current: dict,
    history: "Iterable[dict]",
    *,
    n_baseline: int = 5,
    min_ratio: float = MIN_RATIO,
    k: float = K_SPREAD,
    min_history: int = MIN_HISTORY,
) -> "list[RowVerdict]":
    """Verdicts for every timing row of `current` against `history`.

    History is pre-filtered to the current entry's bench and execution
    environment (`ledger.ENV_KEYS`) and to runs that completed ok; the
    current entry itself (by run_id) never counts as its own baseline.
    """
    past = [
        e
        for e in ledger.matching(
            history, bench=current.get("bench"), env_of=current
        )
        if e.get("run_id") != current.get("run_id")
    ]
    past.sort(key=lambda e: e.get("ts_unix", 0.0))
    verdicts = []
    for r in current.get("rows", ()):
        name = r["name"]
        verdicts.append(
            judge_row(
                name,
                float(r["us_per_call"]),
                ledger.row_values(past, name),
                n_baseline=n_baseline,
                min_ratio=min_ratio,
                k=k,
                min_history=min_history,
            )
        )
    return verdicts


def has_regressions(verdicts: "Iterable[RowVerdict]") -> bool:
    return any(v.gating for v in verdicts)


def baseline_depth(verdicts: "Iterable[RowVerdict]") -> int:
    """Deepest history any row was judged against (for auto-enforce)."""
    return max((v.n_history for v in verdicts), default=0)


def format_table(verdicts: "Iterable[RowVerdict]") -> str:
    """Plain-text verdict table (one row per timing row)."""
    lines = [
        f"{'row':<44s} {'current':>12s} {'baseline':>12s} "
        f"{'ratio':>7s} {'thresh':>7s} {'n':>3s}  verdict"
    ]
    for v in verdicts:
        base = f"{v.baseline_us:.1f}" if v.baseline_us is not None else "—"
        ratio = f"{v.ratio:.2f}" if v.ratio is not None else "—"
        lines.append(
            f"{v.row:<44s} {v.current_us:>12.1f} {base:>12s} "
            f"{ratio:>7s} {v.threshold:>7.2f} {v.n_history:>3d}  {v.status}"
        )
    return "\n".join(lines)
