"""Deterministic synthetic 20x20 digit dataset (MNIST stand-in).

The container is offline (no torchvision/MNIST), so the paper's
400-input workload uses a procedurally generated dataset: 10 smooth
class prototypes + per-sample elastic jitter, shifts and pixel noise.
A float MLP reaches >97% on it, leaving headroom for the analog
non-idealities under study — the same experimental role MNIST plays in
the paper (documented in DESIGN.md §9).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

IMG = 20
N_CLASSES = 10


def _prototypes(key: jax.Array) -> jax.Array:
    """Ten smooth, well-separated 20x20 prototypes in [0, 1]."""
    raw = jax.random.normal(key, (N_CLASSES, IMG, IMG))
    # Smooth with a separable box blur a few times for spatial structure.
    k = jnp.ones((5,)) / 5.0

    def blur(img):
        img = jnp.apply_along_axis(lambda r: jnp.convolve(r, k, mode="same"), 1, img)
        img = jnp.apply_along_axis(lambda c: jnp.convolve(c, k, mode="same"), 2, img)
        return img

    s = raw
    for _ in range(3):
        s = blur(s)
    s = (s - s.min(axis=(1, 2), keepdims=True)) / (
        s.max(axis=(1, 2), keepdims=True) - s.min(axis=(1, 2), keepdims=True)
    )
    return s


def make_digits(
    n_samples: int,
    *,
    seed: int = 0,
    noise: float = 0.25,
    max_shift: int = 2,
) -> Tuple[jax.Array, jax.Array]:
    """Generate (x, y): x (n, 400) in [0,1], y (n,) int labels."""
    key = jax.random.PRNGKey(seed)
    kp, ky, kn, ks = jax.random.split(key, 4)
    protos = _prototypes(kp)
    y = jax.random.randint(ky, (n_samples,), 0, N_CLASSES)
    imgs = protos[y]  # (n, IMG, IMG)
    shifts = jax.random.randint(ks, (n_samples, 2), -max_shift, max_shift + 1)

    def shift_one(img, sh):
        return jnp.roll(img, (sh[0], sh[1]), axis=(0, 1))

    imgs = jax.vmap(shift_one)(imgs, shifts)
    imgs = imgs + noise * jax.random.normal(kn, imgs.shape)
    imgs = jnp.clip(imgs, 0.0, 1.0)
    return imgs.reshape(n_samples, IMG * IMG), y


def train_test_split(
    n_train: int, n_test: int, *, seed: int = 0, **kw
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    x, y = make_digits(n_train + n_test, seed=seed, **kw)
    return x[:n_train], y[:n_train], x[n_train:], y[n_train:]
