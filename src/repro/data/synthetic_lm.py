"""Deterministic synthetic LM token pipeline.

Generates a Zipf-distributed Markov-ish token stream so models have real
structure to learn (synthetic perplexity decreases measurably within a
few hundred steps of the example driver). Sharded per host: each data
shard draws a disjoint counter-based PRNG stream (restart-safe: the
stream is a pure function of (seed, shard, step)), with background
prefetch overlapping host generation with device compute.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch_size: int          # per-process batch
    seed: int = 0
    shard: int = 0
    n_shards: int = 1
    zipf_a: float = 1.3      # skewed unigram distribution
    run_len: int = 4         # deterministic successor-chain run length


class SyntheticLM:
    """Restart-safe synthetic token stream (pure function of step).

    Tokens are Zipf-sampled run anchors followed by run_len-1 steps of a
    fixed random permutation ("successor") — so within a run the next
    token is a deterministic function of the current one. A model that
    learns the permutation reaches (run_len-1)/run_len next-token
    accuracy; perplexity drops measurably within a few hundred steps.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.successor = rng.permutation(cfg.vocab).astype(np.int32)
        # Powers of the permutation up to run_len for vectorised chains.
        powers = [np.arange(cfg.vocab, dtype=np.int32)]
        for _ in range(cfg.run_len - 1):
            powers.append(self.successor[powers[-1]])
        self.succ_pow = np.stack(powers)  # (run_len, vocab)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self.unigram = (probs / probs.sum()).astype(np.float64)

    def batch_at(self, step: int) -> "dict[str, np.ndarray]":
        cfg = self.cfg
        ss = np.random.SeedSequence(
            entropy=cfg.seed, spawn_key=(cfg.shard, step)
        )
        rng = np.random.default_rng(ss)
        b, s = cfg.batch_size, cfg.seq_len
        r = cfg.run_len
        n_runs = (s + 1 + r - 1) // r
        anchors = rng.choice(
            cfg.vocab, size=(b, n_runs), p=self.unigram
        ).astype(np.int32)
        t = np.arange(n_runs * r)
        toks = self.succ_pow[t % r, anchors[:, t // r]][:, : s + 1]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator["dict[str, np.ndarray]"]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def multimodal_extras(
    cfg: ModelConfig, batch_size: int, step: int, seed: int = 0
) -> "dict[str, np.ndarray]":
    """Stub frontend tensors for vlm/audio archs (assignment: precomputed
    patch/frame embeddings)."""
    rng = np.random.default_rng(np.random.SeedSequence(seed, spawn_key=(99, step)))
    extras = {}
    if cfg.family == "vlm":
        extras["vision_embeds"] = rng.standard_normal(
            (batch_size, cfg.modality_prefix, cfg.d_model), dtype=np.float32
        )
    if cfg.is_encoder_decoder:
        extras["frames"] = rng.standard_normal(
            (batch_size, cfg.encoder_seq, cfg.d_model), dtype=np.float32
        )
    return extras


class Prefetcher:
    """Background-thread prefetch queue over a batch-producing callable."""

    def __init__(self, produce, depth: int = 2, start_step: int = 0):
        self._produce = produce
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        while not self._stop.is_set():
            batch = self._produce(self._step)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def get(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)


def make_train_stream(
    model_cfg: ModelConfig,
    shape: ShapeConfig,
    *,
    per_process_batch: Optional[int] = None,
    seed: int = 0,
    start_step: int = 0,
    prefetch: int = 2,
):
    """Prefetching stream of train batches for (arch, shape)."""
    b = per_process_batch or shape.global_batch
    lm = SyntheticLM(
        DataConfig(vocab=model_cfg.vocab, seq_len=shape.seq_len, batch_size=b, seed=seed)
    )

    def produce(step):
        batch = lm.batch_at(step)
        batch.update(multimodal_extras(model_cfg, b, step, seed))
        return batch

    return Prefetcher(produce, depth=prefetch, start_step=start_step)
