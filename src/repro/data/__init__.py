from repro.data.digits import make_digits  # noqa: F401
