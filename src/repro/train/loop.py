"""Fault-tolerant distributed training loop.

Features (see DESIGN.md §7):
  * pjit'd train_step with sharded params/opt-state (logical rules),
    donated state buffers, microbatched gradient accumulation with a
    single deferred gradient reduction,
  * async atomic checkpointing + auto-resume (bit-exact: data stream is
    a pure function of step),
  * preemption hook (SIGTERM -> checkpoint -> clean exit),
  * straggler watchdog + elastic re-mesh recommendation,
  * optional int8 error-feedback gradient compression around the DP
    all-reduce (distributed/compression.py).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import Model
from repro.optim.adamw import AdamWConfig, AdamWState, adamw_init, adamw_update
from repro.optim.schedule import Schedule, make_schedule
from repro.sharding.rules import DEFAULT_RULES, AxisRules, spec_tree
from repro.train.fault import StragglerWatchdog


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    total_steps: int = 1000
    schedule: str = "cosine"       # cosine | wsd | constant
    microbatches: int = 1          # gradient accumulation
    adamw: AdamWConfig = AdamWConfig()
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 200
    keep_checkpoints: int = 3
    log_every: int = 10


def batch_specs(batch_shapes, mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    """NamedShardings for a train batch: batch dim over (pod, data)."""
    from repro.sharding.rules import logical_to_spec

    def spec(x):
        axes = ["act_batch"] + [None] * (len(x.shape) - 1)
        return NamedSharding(mesh, logical_to_spec(axes, x.shape, mesh, rules))

    return jax.tree_util.tree_map(spec, batch_shapes)


def opt_shardings(
    opt: AdamWState,
    param_shardings,
    mesh: Mesh,
    rules: AxisRules = DEFAULT_RULES,
):
    """Shardings matching an AdamWState.

    fp32 moments mirror the parameter tree -> reuse param shardings.
    int8 (Quantised) moments keep the parameter's own shape, so q reuses
    the param sharding directly and the per-block scales reuse it minus
    the blocked last dim.
    """
    from repro.optim.adamw import Quantised

    is_q = lambda x: isinstance(x, Quantised)

    def mv_shard(tree):
        flat_s, _ = jax.tree_util.tree_flatten(tree, is_leaf=is_q)
        flat_p, treedef = jax.tree_util.tree_flatten(param_shardings)
        out = []
        for ps, leaf in zip(flat_p, flat_s):
            if is_q(leaf):
                spec = ps.spec
                scale_spec = P(*(tuple(spec[:-1]) + (None,))) if len(spec) else P()
                out.append(
                    Quantised(
                        q=ps,
                        scale=NamedSharding(mesh, scale_spec),
                        shape=leaf.shape,
                    )
                )
            else:
                out.append(ps)
        return jax.tree_util.tree_unflatten(treedef, out)

    return AdamWState(
        step=NamedSharding(mesh, P()),
        m=mv_shard(opt.m),
        v=mv_shard(opt.v),
    )


def make_train_step(
    model: Model,
    tcfg: TrainConfig,
    schedule: Schedule,
):
    """Build the (params, opt, batch, step) -> (params, opt, metrics) fn.

    Microbatching: the global batch is split along axis 0 into
    `tcfg.microbatches` slices; local gradients accumulate in fp32 and
    the (implicit, XLA-inserted) data-parallel all-reduce happens once
    on the accumulated gradient — not once per microbatch.
    """

    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: AdamWState, batch, step):
        nm = tcfg.microbatches
        if nm == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(i, carry):
                gacc, lacc = carry
                mb = jax.tree_util.tree_map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // nm), x.shape[0] // nm, axis=0
                    ),
                    batch,
                )
                (l, _), g = grad_fn(params, mb)
                gacc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g
                )
                return gacc, lacc + l

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, loss = jax.lax.fori_loop(0, nm, micro, (g0, 0.0))
            grads = jax.tree_util.tree_map(lambda g: g / nm, grads)
            loss = loss / nm
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}

        lr = schedule(step)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_state, params, lr, tcfg.adamw
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


class Trainer:
    """End-to-end fault-tolerant trainer for one (arch, shape)."""

    def __init__(
        self,
        model: Model,
        tcfg: TrainConfig,
        mesh: Optional[Mesh] = None,
        rules: AxisRules = DEFAULT_RULES,
    ):
        self.model = model
        self.tcfg = tcfg
        self.mesh = mesh
        self.rules = rules
        self.schedule = make_schedule(
            tcfg.schedule, tcfg.peak_lr, tcfg.total_steps
        )
        self.watchdog = StragglerWatchdog()
        self.ckpt = (
            CheckpointManager(tcfg.checkpoint_dir, keep=tcfg.keep_checkpoints)
            if tcfg.checkpoint_dir
            else None
        )
        self._preempted = False
        self._step_fn = None

    def install_preemption_hook(self):
        def handler(signum, frame):
            self._preempted = True

        signal.signal(signal.SIGTERM, handler)

    # ------------------------------------------------------------------

    def init_state(self, key: jax.Array):
        params = self.model.init(key)
        opt = adamw_init(params, self.tcfg.adamw)
        return params, opt

    def shardings_for(self, params, opt):
        if self.mesh is None:
            return None, None
        axes = self.model.param_axes()
        p_shard = spec_tree(axes, params, self.mesh, self.rules)
        o_shard = opt_shardings(opt, p_shard, self.mesh, self.rules)
        return p_shard, o_shard

    def compile_step(self, params, opt, batch_shapes):
        step_fn = make_train_step(self.model, self.tcfg, self.schedule)
        if self.mesh is not None:
            p_shard, o_shard = self.shardings_for(params, opt)
            b_shard = batch_specs(batch_shapes, self.mesh, self.rules)
            self._step_fn = jax.jit(
                step_fn,
                in_shardings=(p_shard, o_shard, b_shard, None),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1),
            )
        else:
            self._step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        return self._step_fn

    # ------------------------------------------------------------------

    def fit(
        self,
        key: jax.Array,
        stream,
        *,
        steps: Optional[int] = None,
        on_metrics: Optional[Callable[[int, dict], None]] = None,
    ):
        """Run the loop with auto-resume; returns (params, history)."""
        params, opt = self.init_state(key)
        start_step = 0
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            (params, opt), start_step = self.ckpt.restore((params, opt))
        total = steps if steps is not None else self.tcfg.total_steps
        step_fn = self._step_fn or self.compile_step(
            params, opt, jax.tree_util.tree_map(np.asarray, stream.get())
        )

        history = []
        for step in range(start_step, total):
            t0 = time.time()
            batch = stream.get()
            params, opt, metrics = step_fn(
                params, opt, batch, jnp.asarray(step, jnp.int32)
            )
            if (step % self.tcfg.log_every == 0) or step == total - 1:
                metrics = {
                    k: float(v) for k, v in metrics.items()
                }  # blocks: flushes the step
                history.append((step, metrics))
                if on_metrics:
                    on_metrics(step, metrics)
            dt = time.time() - t0
            if self.watchdog.record(dt) and self.watchdog.should_remesh:
                # Persistent straggler: checkpoint and let the launcher
                # re-mesh (train/fault.plan_mesh) — surfaced, not hidden.
                if self.ckpt is not None:
                    self.ckpt.save(step + 1, (params, opt), blocking=True)
                raise RuntimeError(
                    "persistent straggler detected; checkpointed at "
                    f"step {step + 1} — re-mesh with plan_mesh()"
                )
            if self.ckpt is not None and (
                (step + 1) % self.tcfg.checkpoint_every == 0
                or self._preempted
                or step == total - 1
            ):
                self.ckpt.save(
                    step + 1, (params, opt), blocking=self._preempted
                )
            if self._preempted:
                break
        if self.ckpt is not None:
            self.ckpt.wait()
        return params, history
