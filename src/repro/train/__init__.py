from repro.train.loop import TrainConfig, Trainer, make_train_step  # noqa: F401
from repro.train.fault import ElasticPlan, StragglerWatchdog, plan_mesh  # noqa: F401
