"""Fault-tolerance utilities: straggler detection and elastic re-meshing.

StragglerWatchdog — EMA step-time monitor. In multi-host SPMD a slow host
stalls every step (lockstep collectives), so persistent step-time
inflation *is* the straggler signal observable from any host; the
watchdog flags it and (per policy) recommends checkpoint-and-remesh.
Data-level hedging (prefetch depth) covers input-pipeline stragglers.

plan_mesh — elastic re-meshing: given a degraded device count, pick the
largest usable (data, model) factorisation (keeping the model axis if
possible, since parameter layouts depend on it), report the devices to
drop, and feed CheckpointManager.restore(shardings=new) to resume.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional


@dataclasses.dataclass
class StragglerWatchdog:
    """Flags steps slower than `threshold` x the EMA step time."""

    threshold: float = 2.0
    ema_decay: float = 0.9
    warmup_steps: int = 5
    _ema: Optional[float] = None
    _count: int = 0
    slow_steps: int = 0
    consecutive_slow: int = 0

    def record(self, step_time: float) -> bool:
        """Record one step; returns True if this step was a straggler."""
        self._count += 1
        if self._ema is None:
            self._ema = step_time
            return False
        slow = (
            self._count > self.warmup_steps
            and step_time > self.threshold * self._ema
        )
        if slow:
            self.slow_steps += 1
            self.consecutive_slow += 1
        else:
            self.consecutive_slow = 0
            # Only fold healthy steps into the EMA so a degrading host
            # cannot normalise itself away.
            self._ema = self.ema_decay * self._ema + (1 - self.ema_decay) * step_time
        return slow

    @property
    def should_remesh(self) -> bool:
        """Persistent degradation: recommend checkpoint + elastic restart."""
        return self.consecutive_slow >= 10

    @property
    def ema(self) -> Optional[float]:
        return self._ema


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int
    pod: int
    used_devices: int
    dropped_devices: int

    @property
    def axes(self):
        if self.pod > 1:
            return {"pod": self.pod, "data": self.data, "model": self.model}
        return {"data": self.data, "model": self.model}


def plan_mesh(
    n_devices: int,
    *,
    prefer_model: int = 16,
    pods: int = 1,
) -> ElasticPlan:
    """Largest usable (pod, data, model) mesh for a degraded device pool.

    Keeps the model axis at `prefer_model` if possible (parameter TP
    layouts survive restarts); shrinks it to the largest power-of-two
    divisor otherwise. Remaining devices go to data parallelism; any
    non-factorable remainder is dropped (hot spares).
    """
    if n_devices < 1:
        raise ValueError("need at least one device")
    per_pod = n_devices // pods
    model = prefer_model
    while model > 1 and per_pod // model < 1:
        model //= 2
    data = max(1, per_pod // model)
    used = pods * data * model
    return ElasticPlan(
        data=data,
        model=model,
        pod=pods,
        used_devices=used,
        dropped_devices=n_devices - used,
    )


class Heartbeat:
    """Simple liveness marker for external orchestrators (file mtime)."""

    def __init__(self, path: str):
        self.path = path

    def beat(self, step: int):
        with open(self.path, "w") as f:
            f.write(f"{step} {time.time()}\n")
