"""Canonical SPICE printer for the Circuit IR.

One printer serves both directions of the interchange: the netlist
generator (`core.netlist.map_layer` / `map_imac`) builds IR cards and
prints them here, and anything parsed by `repro.spice.parser` re-prints
through the same code — which is what makes ``emit -> parse -> emit``
byte-stable (and a single round trip canonicalizing for third-party
netlists).

Numbers print with ``%.6g`` (`fmt`), the project-wide SPICE significant
precision; parsing a ``%.6g`` string and re-printing it reproduces the
string exactly, so resistor values survive any number of round trips.
"""
from __future__ import annotations

from repro.spice.ir import (
    BehavioralSource,
    Capacitor,
    Card,
    Circuit,
    Comment,
    Directive,
    Instance,
    ISource,
    Resistor,
    Subckt,
    Title,
    VSource,
)


def fmt(x: float) -> str:
    """Canonical SPICE number formatting (6 significant digits)."""
    return f"{x:.6g}"


def emit_card(card: Card) -> "list[str]":
    """Print one card as netlist lines (a Subckt spans several)."""
    if isinstance(card, Comment):
        return [f"*{card.text}"]
    if isinstance(card, Title):
        return [card.text]
    if isinstance(card, (Resistor, Capacitor)):
        return [f"{card.name} {card.n1} {card.n2} {fmt(card.value)}"]
    if isinstance(card, VSource):
        if card.pwl is not None:
            pts = " ".join(f"{fmt(t)} {fmt(v)}" for t, v in card.pwl)
            return [f"{card.name} {card.npos} {card.nneg} PWL({pts})"]
        return [f"{card.name} {card.npos} {card.nneg} DC {fmt(card.dc or 0.0)}"]
    if isinstance(card, ISource):
        return [f"{card.name} {card.npos} {card.nneg} DC {fmt(card.dc)}"]
    if isinstance(card, BehavioralSource):
        return [f"{card.name} {card.npos} {card.nneg} VALUE={{{card.expr}}}"]
    if isinstance(card, Instance):
        nodes = " ".join(card.nodes)
        return [f"{card.name} {nodes} {card.subckt}"]
    if isinstance(card, Directive):
        if card.args:
            return [f".{card.name} {' '.join(card.args)}"]
        return [f".{card.name}"]
    if isinstance(card, Subckt):
        lines = [f".SUBCKT {card.name} {' '.join(card.ports)}"]
        for inner in card.cards:
            lines.extend(emit_card(inner))
        lines.append(f".ENDS {card.name}")
        return lines
    raise TypeError(f"cannot emit {type(card).__name__}")


def emit(circuit: Circuit) -> str:
    """Print a whole circuit; every line newline-terminated."""
    lines: "list[str]" = []
    for card in circuit.cards:
        lines.extend(emit_card(card))
    return "\n".join(lines) + "\n" if lines else ""
