"""External differential oracle: run a real ngspice binary when present.

The container images this repo targets usually have no SPICE binary —
the JAX solver *is* the simulator — so everything here degrades
gracefully: `find_ngspice()` returns None when the binary is absent and
the test suite skips. When `ngspice` is installed (CI's optional oracle
job apt-installs it), `run_ngspice` executes a netlist in batch mode,
captures an ASCII rawfile of every analysis, and the parsed plots are
compared differentially against the in-repo backends.

The rawfile parser (`parse_raw`) is pure string processing and is unit
tested with canned data regardless of whether the binary exists.
"""
from __future__ import annotations

import dataclasses
import os
import re
import shutil
import subprocess
import tempfile
from typing import Dict, List, Optional

import numpy as np


class NgspiceError(RuntimeError):
    """ngspice failed to run or produced unparseable output."""


def find_ngspice() -> Optional[str]:
    """Path of the ngspice binary, or None. `REPRO_NGSPICE` overrides."""
    override = os.environ.get("REPRO_NGSPICE")
    if override:
        return override if os.path.exists(override) else None
    return shutil.which("ngspice")


# ---------------------------------------------------------------------------
# ASCII rawfile parsing.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RawPlot:
    """One plot from an ngspice rawfile (one analysis)."""

    name: str                    # Plotname, e.g. "Operating Point"
    variables: "tuple[str, ...]"  # as printed, e.g. ("time", "v(x1_0)")
    values: np.ndarray           # (n_points, n_vars), float64

    def _index(self, name: str) -> int:
        want = name.lower()
        for k, var in enumerate(self.variables):
            v = var.lower()
            if v == want or v == f"v({want})" or v == f"i({want})":
                return k
        raise KeyError(
            f"variable {name!r} not in plot {self.name!r}: "
            f"{list(self.variables)}"
        )

    def signal(self, name: str) -> np.ndarray:
        """Column by variable name; `x` matches both `x` and `v(x)`
        (ngspice lowercases everything)."""
        return self.values[:, self._index(name)]

    def voltage(self, node: str) -> float:
        """Scalar node voltage (operating-point plots)."""
        return float(self.signal(node)[0])

    def time(self) -> np.ndarray:
        return self.signal("time")


_HDR = re.compile(r"^(?P<key>[A-Za-z. ]+):\s*(?P<val>.*)$")


def parse_raw(text: str) -> "list[RawPlot]":
    """Parse an ASCII ngspice rawfile (``set filetype=ascii``).

    Handles multiple plots per file (``write out.raw all``); complex
    values keep their real part.
    """
    plots: "list[RawPlot]" = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        header: Dict[str, str] = {}
        variables: List[str] = []
        while i < len(lines):
            line = lines[i]
            m = _HDR.match(line)
            if not m:
                i += 1
                continue
            key = m["key"].strip().lower()
            if key == "variables" and not m["val"].strip():
                i += 1
                while i < len(lines) and lines[i][:1].isspace():
                    parts = lines[i].split()
                    if len(parts) >= 2:
                        variables.append(parts[1])
                    i += 1
                continue
            if key == "values":
                i += 1
                break
            header[key] = m["val"].strip()
            i += 1
        else:
            break
        try:
            n_vars = int(header["no. variables"])
            n_points = int(header["no. points"])
        except KeyError as e:
            raise NgspiceError(f"rawfile header missing {e}") from e
        if len(variables) != n_vars:
            raise NgspiceError(
                f"rawfile lists {len(variables)} variables, header says "
                f"{n_vars}"
            )
        toks: List[str] = []
        need = n_points * (n_vars + 1)
        while i < len(lines) and len(toks) < need:
            if _HDR.match(lines[i]) and not lines[i][:1].isspace():
                break
            toks.extend(lines[i].split())
            i += 1
        if len(toks) < need:
            raise NgspiceError(
                f"rawfile plot {header.get('plotname', '?')!r}: expected "
                f"{need} value tokens, got {len(toks)}"
            )
        vals = np.empty((n_points, n_vars))
        for p in range(n_points):
            row = toks[p * (n_vars + 1) : (p + 1) * (n_vars + 1)]
            if int(row[0]) != p:
                raise NgspiceError(
                    f"rawfile point index {row[0]} != {p}"
                )
            vals[p] = [float(t.split(",")[0]) for t in row[1:]]
        plots.append(
            RawPlot(
                name=header.get("plotname", ""),
                variables=tuple(variables),
                values=vals,
            )
        )
    if not plots:
        raise NgspiceError("no plots found in rawfile")
    return plots


# ---------------------------------------------------------------------------
# Batch execution.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NgspiceResult:
    plots: "tuple[RawPlot, ...]"
    log: str

    def plot(self, kind: str) -> RawPlot:
        """First plot whose Plotname contains `kind` (case-insensitive)."""
        for p in self.plots:
            if kind.lower() in p.name.lower():
                return p
        raise KeyError(
            f"no {kind!r} plot among {[p.name for p in self.plots]}"
        )

    def op(self) -> RawPlot:
        return self.plot("operating point")

    def tran(self) -> RawPlot:
        return self.plot("transient")


_END_RE = re.compile(r"^\s*\.end\s*$", re.I | re.M)

_CONTROL = """.control
set filetype=ascii
run
write {raw} all
.endc
"""


def _instrument(main_text: str, raw_name: str) -> str:
    """Splice the rawfile-writing .control block in front of `.end`.

    The first line of the deck must be a comment or title line (ngspice
    consumes it as the title); everything `map_imac` emits satisfies
    this.
    """
    control = _CONTROL.format(raw=raw_name)
    m = _END_RE.search(main_text)
    if m:
        return main_text[: m.start()] + control + main_text[m.start() :]
    return main_text + control + ".end\n"


def run_ngspice(
    files: "Dict[str, str]",
    main: "str | None" = None,
    *,
    ngspice: "str | None" = None,
    timeout: float = 120.0,
) -> NgspiceResult:
    """Run a (multi-file) netlist through ``ngspice -b``.

    `files` maps filename -> contents, the shape `map_imac` returns;
    `main` defaults to ``imac_main.sp`` or the single entry. Every
    analysis stated in the deck runs; the resulting plots come back
    parsed. Raises `NgspiceError` when the binary is missing, exits
    non-zero, times out, or writes no rawfile.
    """
    binary = ngspice or find_ngspice()
    if binary is None:
        raise NgspiceError(
            "ngspice binary not found (install it or set REPRO_NGSPICE)"
        )
    if main is None:
        if "imac_main.sp" in files:
            main = "imac_main.sp"
        elif len(files) == 1:
            main = next(iter(files))
        else:
            raise NgspiceError(
                f"cannot infer the main file among {sorted(files)}; pass main="
            )
    with tempfile.TemporaryDirectory(prefix="repro-ngspice-") as tmp:
        for name, text in files.items():
            if os.path.basename(name) != name:
                raise NgspiceError(f"netlist filename {name!r} is not flat")
            body = _instrument(text, "out.raw") if name == main else text
            with open(os.path.join(tmp, name), "w") as fh:
                fh.write(body)
        cmd = [binary, "-b", "-o", "ngspice.log", main]
        try:
            proc = subprocess.run(
                cmd,
                cwd=tmp,
                stdout=subprocess.PIPE,
                stderr=subprocess.STDOUT,
                timeout=timeout,
            )
        except subprocess.TimeoutExpired as e:
            raise NgspiceError(
                f"ngspice timed out after {timeout:g}s on {main}"
            ) from e
        log_path = os.path.join(tmp, "ngspice.log")
        log = ""
        if os.path.exists(log_path):
            with open(log_path, errors="replace") as fh:
                log = fh.read()
        log += proc.stdout.decode(errors="replace")
        raw_path = os.path.join(tmp, "out.raw")
        if proc.returncode != 0 or not os.path.exists(raw_path):
            tail = "\n".join(log.splitlines()[-25:])
            raise NgspiceError(
                f"ngspice exited {proc.returncode} without a rawfile; log "
                f"tail:\n{tail}"
            )
        with open(raw_path, errors="replace") as fh:
            raw = fh.read()
    return NgspiceResult(plots=tuple(parse_raw(raw)), log=log)


__all__ = [
    "NgspiceError",
    "NgspiceResult",
    "RawPlot",
    "find_ngspice",
    "parse_raw",
    "run_ngspice",
]
