"""Tokenizer + parser: SPICE netlist text -> Circuit IR.

Covers the subset the framework emits plus the common third-party forms
needed to import external crossbar netlists:

  * R / C / V / I element cards (DC levels, PWL sources) and E-sources
    with ``VALUE={...}`` behavioural expressions;
  * ``.SUBCKT`` / ``.ENDS`` definitions and ``X`` instantiation;
  * dot directives (``.TRAN``, ``.OPTION``, ``.PRINT``, ``.INCLUDE``,
    ``.OP``, ``.END``, ...) with verbatim argument tokens;
  * unit suffixes (``10k``, ``1n``, ``3meg``, trailing units ``20ns``),
    continuation lines (``+``), ``*`` comment lines, ``;`` / `` $``
    end-of-line comments, and a bare title line (auto-detected when the
    first line is not parseable as a card).

Anything outside the subset raises `ParseError` with the offending line,
so unsupported circuits fail loudly at the boundary instead of lowering
to nonsense.
"""
from __future__ import annotations

import re

from repro.spice.ir import (
    BehavioralSource,
    Capacitor,
    Card,
    Circuit,
    Comment,
    Directive,
    Instance,
    ISource,
    Resistor,
    Subckt,
    Title,
    VSource,
    spice_number,
)


class ParseError(ValueError):
    """A netlist line outside the supported SPICE subset."""

    def __init__(self, msg: str, lineno: "int | None" = None):
        self.lineno = lineno
        where = f" (line {lineno})" if lineno is not None else ""
        super().__init__(f"{msg}{where}")


_EOL_COMMENT = re.compile(r"(;|\s\$).*$")


def _logical_lines(text: str) -> "list[tuple[int, str]]":
    """Assemble (lineno, text) logical lines: continuations joined,
    end-of-line comments stripped, blanks dropped (full-line comments
    are preserved verbatim)."""
    out: "list[tuple[int, str]]" = []
    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.rstrip("\r")
        stripped = line.strip()
        if not stripped:
            continue
        if stripped.startswith("*"):
            out.append((lineno, line.lstrip()))
            continue
        line = _EOL_COMMENT.sub("", line).rstrip()
        if not line.strip():
            continue
        if line.lstrip().startswith("+"):
            if not out or out[-1][1].startswith("*"):
                raise ParseError("continuation with nothing to continue", lineno)
            prev_no, prev = out[-1]
            out[-1] = (prev_no, prev + " " + line.lstrip()[1:].strip())
        else:
            out.append((lineno, line.strip()))
    return out


def _balance(tok: str) -> int:
    return tok.count("(") - tok.count(")") + tok.count("{") - tok.count("}")


def _tokens(line: str, lineno: int) -> "list[str]":
    """Whitespace split, re-joining tokens inside (...) / {...} groups
    (PWL point lists, VALUE expressions)."""
    parts = line.split()
    out: "list[str]" = []
    depth = 0
    for part in parts:
        if depth > 0:
            out[-1] += " " + part
        elif out and out[-1].upper() in ("PWL", "VALUE=") and part.startswith("("):
            out[-1] += part
        else:
            out.append(part)
        depth += _balance(part)
        if depth < 0:
            raise ParseError(f"unbalanced parentheses in {line!r}", lineno)
    if depth != 0:
        raise ParseError(f"unbalanced parentheses in {line!r}", lineno)
    return out


def _parse_pwl(tok: str, lineno: int) -> "tuple[tuple[float, float], ...]":
    inner = tok[tok.index("(") + 1 : tok.rindex(")")]
    vals = [spice_number(t) for t in inner.split()]
    if len(vals) < 2 or len(vals) % 2:
        raise ParseError(f"PWL needs (t v) pairs, got {tok!r}", lineno)
    return tuple((vals[i], vals[i + 1]) for i in range(0, len(vals), 2))


def _parse_source(toks: "list[str]", lineno: int) -> VSource:
    name, npos, nneg = toks[0], toks[1], toks[2]
    rest = toks[3:]
    dc = None
    pwl = None
    i = 0
    while i < len(rest):
        t = rest[i]
        u = t.upper()
        if u == "DC":
            if i + 1 >= len(rest):
                raise ParseError(f"{name}: DC without a value", lineno)
            dc = spice_number(rest[i + 1])
            i += 2
        elif u.startswith("PWL"):
            if "(" not in t:  # "PWL" then a merged "(...)" token
                if i + 1 >= len(rest):
                    raise ParseError(f"{name}: PWL without points", lineno)
                t = t + rest[i + 1]
                i += 1
            pwl = _parse_pwl(t, lineno)
            i += 1
        elif u.startswith(("SIN", "PULSE", "EXP", "SFFM", "AC")):
            raise ParseError(
                f"{name}: unsupported source function {t!r} "
                "(supported: DC, PWL)",
                lineno,
            )
        else:
            dc = spice_number(t)  # bare value == DC level
            i += 1
    if dc is None and pwl is None:
        raise ParseError(f"{name}: source without DC or PWL value", lineno)
    return VSource(name=name, npos=npos, nneg=nneg, dc=dc, pwl=pwl)


def _parse_two_terminal(toks: "list[str]", lineno: int):
    """R/C cards: name n1 n2 value [name=value params ignored]."""
    if len(toks) < 4:
        raise ParseError(f"element card too short: {' '.join(toks)!r}", lineno)
    for extra in toks[4:]:
        if "=" not in extra:
            raise ParseError(
                f"{toks[0]}: unsupported trailing token {extra!r}", lineno
            )
    return toks[0], toks[1], toks[2], spice_number(toks[3])


def _parse_card(toks: "list[str]", lineno: int) -> Card:
    kind = toks[0][0].upper()
    if kind == "R":
        name, n1, n2, val = _parse_two_terminal(toks, lineno)
        return Resistor(name=name, n1=n1, n2=n2, value=val)
    if kind == "C":
        name, n1, n2, val = _parse_two_terminal(toks, lineno)
        return Capacitor(name=name, n1=n1, n2=n2, value=val)
    if kind == "V":
        if len(toks) < 4:
            raise ParseError(f"source card too short: {' '.join(toks)!r}", lineno)
        return _parse_source(toks, lineno)
    if kind == "I":
        if len(toks) < 4:
            raise ParseError(f"source card too short: {' '.join(toks)!r}", lineno)
        src = _parse_source(toks, lineno)
        if src.pwl is not None:
            raise ParseError(f"{toks[0]}: PWL current sources unsupported", lineno)
        return ISource(name=src.name, npos=src.npos, nneg=src.nneg, dc=src.dc)
    if kind == "E":
        if len(toks) != 4 or not toks[3].upper().startswith("VALUE="):
            raise ParseError(
                f"{toks[0]}: only 'E n+ n- VALUE={{expr}}' sources are "
                "supported",
                lineno,
            )
        expr = toks[3][len("VALUE=") :]
        if not (expr.startswith("{") and expr.endswith("}")):
            raise ParseError(f"{toks[0]}: VALUE expression must be braced", lineno)
        return BehavioralSource(
            name=toks[0], npos=toks[1], nneg=toks[2], expr=expr[1:-1]
        )
    if kind == "X":
        if len(toks) < 3:
            raise ParseError(f"instance card too short: {' '.join(toks)!r}", lineno)
        return Instance(
            name=toks[0], nodes=tuple(toks[1:-1]), subckt=toks[-1]
        )
    raise ParseError(
        f"unsupported element card {toks[0]!r} (supported: R C V I E X)",
        lineno,
    )


def parse_netlist(text: str) -> Circuit:
    """Parse one netlist file into a `Circuit`.

    A first line that is not parseable as any card is kept as a `Title`
    (SPICE's implicit title line); files produced by this framework
    always begin with a ``*`` comment instead.
    """
    lines = _logical_lines(text)
    cards: "list[Card]" = []
    stack: "list[tuple[str, tuple[str, ...], list[Card]]]" = []
    first = True
    for lineno, line in lines:
        is_first = first
        first = False
        if line.startswith("*"):
            card: Card = Comment(line[1:])
            (stack[-1][2] if stack else cards).append(card)
            continue
        try:
            toks = _tokens(line, lineno)
            if line.startswith("."):
                name = toks[0][1:].upper()
                if name == "SUBCKT":
                    if len(toks) < 2:
                        raise ParseError(".SUBCKT without a name", lineno)
                    stack.append((toks[1], tuple(toks[2:]), []))
                    continue
                if name == "ENDS":
                    if not stack:
                        raise ParseError(".ENDS without .SUBCKT", lineno)
                    sname, ports, body = stack.pop()
                    if len(toks) > 1 and toks[1] != sname:
                        raise ParseError(
                            f".ENDS {toks[1]} does not close .SUBCKT {sname}",
                            lineno,
                        )
                    sub = Subckt(name=sname, ports=ports, cards=tuple(body))
                    (stack[-1][2] if stack else cards).append(sub)
                    continue
                card = Directive(name=name, args=tuple(toks[1:]))
            else:
                card = _parse_card(toks, lineno)
        except (ParseError, ValueError) as e:
            if is_first:
                cards.append(Title(line))
                continue
            if isinstance(e, ParseError):
                raise
            raise ParseError(str(e), lineno) from e
        (stack[-1][2] if stack else cards).append(card)
    if stack:
        raise ParseError(f".SUBCKT {stack[-1][0]} never closed by .ENDS")
    return Circuit(cards=tuple(cards))


def parse_files(
    files: "dict[str, str]", main: "str | None" = None
) -> Circuit:
    """Parse a multi-file netlist dict, resolving ``.INCLUDE`` in place.

    `files` maps filename -> contents (the shape `core.netlist.map_imac`
    returns). `main` names the top file; defaults to ``imac_main.sp``
    when present, else the single entry.
    """
    if main is None:
        if "imac_main.sp" in files:
            main = "imac_main.sp"
        elif len(files) == 1:
            main = next(iter(files))
        else:
            raise ParseError(
                f"cannot infer the main file among {sorted(files)}; pass main="
            )

    def resolve(name: str, seen: "tuple[str, ...]") -> "tuple[Card, ...]":
        if name in seen:
            raise ParseError(f"circular .INCLUDE of {name!r}")
        try:
            text = files[name]
        except KeyError:
            raise ParseError(
                f".INCLUDE {name!r} not found among {sorted(files)}"
            ) from None
        out: "list[Card]" = []
        for card in parse_netlist(text).cards:
            if isinstance(card, Directive) and card.name == "INCLUDE":
                out.extend(resolve(card.args[0].strip("'\""), seen + (name,)))
            else:
                out.append(card)
        return tuple(out)

    return Circuit(cards=resolve(main, ()))
