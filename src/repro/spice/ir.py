"""Circuit IR — the common representation of SPICE netlists.

Every netlist in the project flows through this IR: `core.netlist`
*builds* it (map_layer / map_imac emit by constructing cards and
printing them with `repro.spice.emitter`), `repro.spice.parser` *parses*
text back into it, and `repro.spice.lower` turns it into the crossbar
MNA structure the JAX solver backends consume. Because generation and
parsing share one printer, ``emit -> parse -> emit`` is byte-stable for
everything the framework produces, and third-party netlists converge to
the canonical form after one round trip.

The IR is deliberately card-shaped (one dataclass per SPICE card kind)
rather than graph-shaped: order and comments are preserved, so the IR
can reproduce a file exactly, while `Circuit` offers the indexed views
(elements by kind, subckt registry, directive lookup) the lowering and
the oracle need.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterator, Optional, Tuple, Union

#: PWL breakpoints: ((t0, v0), (t1, v1), ...) seconds/volts.
PwlPoints = Tuple[Tuple[float, float], ...]


@dataclasses.dataclass(frozen=True)
class Comment:
    """A full-line comment; `text` is everything after the leading '*'."""

    text: str


@dataclasses.dataclass(frozen=True)
class Title:
    """A bare first line that is not a card (SPICE's title line)."""

    text: str


@dataclasses.dataclass(frozen=True)
class Resistor:
    name: str
    n1: str
    n2: str
    value: float  # ohms


@dataclasses.dataclass(frozen=True)
class Capacitor:
    name: str
    n1: str
    n2: str
    value: float  # farads


@dataclasses.dataclass(frozen=True)
class VSource:
    """Independent voltage source: DC level and/or PWL waveform."""

    name: str
    npos: str
    nneg: str
    dc: Optional[float] = None
    pwl: Optional[PwlPoints] = None

    def final_value(self) -> float:
        """The settled drive: last PWL breakpoint, else the DC level."""
        if self.pwl:
            return self.pwl[-1][1]
        return self.dc if self.dc is not None else 0.0


@dataclasses.dataclass(frozen=True)
class ISource:
    name: str
    npos: str
    nneg: str
    dc: float = 0.0


@dataclasses.dataclass(frozen=True)
class BehavioralSource:
    """E-source with a VALUE={...} expression (behavioural neuron)."""

    name: str
    npos: str
    nneg: str
    expr: str


@dataclasses.dataclass(frozen=True)
class Instance:
    """X card: subcircuit instantiation."""

    name: str
    nodes: Tuple[str, ...]
    subckt: str


@dataclasses.dataclass(frozen=True)
class Directive:
    """A dot directive; args are the raw whitespace-separated tokens.

    Keeping args verbatim (``("1n", "2e-08")`` not ``(1e-9, 2e-8)``)
    makes emit byte-stable; `spice_number` converts on demand.
    """

    name: str  # canonical upper-case, without the leading dot
    args: Tuple[str, ...] = ()


@dataclasses.dataclass(frozen=True)
class Subckt:
    """A .SUBCKT ... .ENDS definition with its body cards in order."""

    name: str
    ports: Tuple[str, ...]
    cards: Tuple["Card", ...]

    def elements(self, kind: type) -> "list":
        return [c for c in self.cards if isinstance(c, kind)]


Card = Union[
    Comment,
    Title,
    Resistor,
    Capacitor,
    VSource,
    ISource,
    BehavioralSource,
    Instance,
    Directive,
    Subckt,
]

_SUFFIX = {
    "t": 1e12,
    "g": 1e9,
    "meg": 1e6,
    "k": 1e3,
    "mil": 25.4e-6,
    "m": 1e-3,
    "u": 1e-6,
    "n": 1e-9,
    "p": 1e-12,
    "f": 1e-15,
}

_NUM_RE = re.compile(
    r"^(?P<mant>[+-]?(?:\d+\.?\d*|\.\d+)(?:[eE][+-]?\d+)?)(?P<rest>[a-zA-Z]*)$"
)


def spice_number(tok: str) -> float:
    """Parse a SPICE number: scale suffixes + trailing units ('20ns')."""
    m = _NUM_RE.match(tok.strip())
    if not m:
        raise ValueError(f"not a SPICE number: {tok!r}")
    val = float(m["mant"])
    rest = m["rest"].lower()
    if rest:
        for suf, scale in _SUFFIX.items():  # 'meg'/'mil' before 'm'
            if rest.startswith(suf):
                return val * scale
        # Bare units ('v', 'a', 'hz', 's') scale by 1.
    return val


@dataclasses.dataclass(frozen=True)
class Circuit:
    """An ordered netlist: the cards of one file (or one merged deck)."""

    cards: Tuple[Card, ...]

    def __iter__(self) -> Iterator[Card]:
        return iter(self.cards)

    def elements(self, kind: type) -> "list":
        """Top-level cards of one IR type (subckt bodies not included)."""
        return [c for c in self.cards if isinstance(c, kind)]

    @property
    def subckts(self) -> "dict[str, Subckt]":
        return {c.name: c for c in self.cards if isinstance(c, Subckt)}

    def directives(self, name: str) -> "list[Directive]":
        name = name.upper()
        return [
            c
            for c in self.cards
            if isinstance(c, Directive) and c.name == name
        ]

    def directive(self, name: str) -> Optional[Directive]:
        found = self.directives(name)
        return found[0] if found else None

    # -- analysis accessors -------------------------------------------------

    def includes(self) -> "list[str]":
        """Filenames of .INCLUDE directives (quotes stripped)."""
        return [
            d.args[0].strip("'\"") for d in self.directives("INCLUDE") if d.args
        ]

    def tran(self) -> "Optional[tuple[float, float]]":
        """(t_step, t_stop) of the .TRAN directive, if present."""
        d = self.directive("TRAN")
        if d is None or len(d.args) < 2:
            return None
        return spice_number(d.args[0]), spice_number(d.args[1])

    def option(self, key: str) -> Optional[str]:
        """Value of an .OPTION KEY=VALUE (or '' for a bare flag)."""
        key = key.upper()
        for d in self.directives("OPTION"):
            for arg in d.args:
                k, _, v = arg.partition("=")
                if k.upper() == key:
                    return v
        return None
