"""repro.spice — SPICE netlist interchange.

Parse SPICE text into a `Circuit` IR, print it back canonically, lower
crossbar netlists onto the MNA solver structures, and (optionally)
differentially check everything against a real ngspice binary.

Typical imports:

    from repro.spice import parse_netlist, parse_files, emit
    from repro.spice import lower_crossbar, lower_network, solve_dc
    from repro.spice.oracle import find_ngspice, run_ngspice
"""
from repro.spice.emitter import emit, emit_card, fmt
from repro.spice.ir import (
    BehavioralSource,
    Capacitor,
    Card,
    Circuit,
    Comment,
    Directive,
    Instance,
    ISource,
    Resistor,
    Subckt,
    Title,
    VSource,
    spice_number,
)
from repro.spice.lower import (
    DCOperatingPoint,
    LoweredCrossbar,
    LoweredLayer,
    LoweredNetwork,
    NonCrossbarError,
    UnsupportedElementError,
    flatten,
    lower,
    lower_crossbar,
    lower_network,
    solve_dc,
)
from repro.spice.parser import ParseError, parse_files, parse_netlist

__all__ = [
    "BehavioralSource",
    "Capacitor",
    "Card",
    "Circuit",
    "Comment",
    "DCOperatingPoint",
    "Directive",
    "ISource",
    "Instance",
    "LoweredCrossbar",
    "LoweredLayer",
    "LoweredNetwork",
    "NonCrossbarError",
    "ParseError",
    "Resistor",
    "Subckt",
    "Title",
    "UnsupportedElementError",
    "VSource",
    "emit",
    "emit_card",
    "flatten",
    "fmt",
    "lower",
    "lower_crossbar",
    "lower_network",
    "parse_files",
    "parse_netlist",
    "solve_dc",
    "spice_number",
]
