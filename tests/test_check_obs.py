"""Unit tests for benchmarks/check_obs.py: the Prometheus text parser
and the v2 BENCH json schema, validated against committed fixtures
(promoted from CI-smoke-only coverage)."""
import copy
import json
import os

import pytest

check_obs = pytest.importorskip(
    "benchmarks.check_obs", reason="benchmarks/ needs repo-root cwd"
)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")


@pytest.fixture()
def bench_payload():
    with open(os.path.join(FIXTURES, "bench_v2_fixture.json")) as fh:
        return json.load(fh)


# ---------------------------------------------------------------------------
# Prometheus parser.
# ---------------------------------------------------------------------------


def test_parser_accepts_committed_fixture():
    with open(os.path.join(FIXTURES, "obs_metrics_fixture.prom")) as fh:
        families = check_obs.parse_prometheus(fh.read())
    for series in check_obs.REQUIRED_SERIES:
        assert series in families, f"fixture lost key series {series}"
    # Histogram child samples fold into their family.
    assert any(
        "_bucket" in line for line in families["solver_sweeps"]
    )


def test_parser_parses_labels_and_special_values():
    text = (
        "# TYPE x counter\n"
        'x{a="1",b="two"} 4\n'
        "# TYPE h histogram\n"
        'h_bucket{le="+Inf"} 2\n'
        "h_sum 11.5\n"
        "h_count 2\n"
        "g -3.5e-07\n"
    )
    families = check_obs.parse_prometheus(text)
    assert families["x"] == ['x{a="1",b="two"} 4']
    assert set(families["h"]) == {
        'h_bucket{le="+Inf"} 2', "h_sum 11.5", "h_count 2"
    }
    assert "g" in families


@pytest.mark.parametrize(
    "bad",
    [
        "not a sample line",
        "name{unclosed 4",
        "name 1 2 3trailing",
        "{nameless} 4",
    ],
)
def test_parser_rejects_malformed_lines(bad):
    with pytest.raises(ValueError, match="not a valid sample"):
        check_obs.parse_prometheus(f"# TYPE ok counter\nok 1\n{bad}\n")


def test_parser_skips_comments_and_blanks():
    assert check_obs.parse_prometheus("\n# HELP foo\n\n# TYPE foo gauge\n") == {}


def test_check_metrics_missing_series_exits(tmp_path):
    path = tmp_path / "m.prom"
    path.write_text("# TYPE other counter\nother 1\n")
    with pytest.raises(SystemExit, match="missing key series"):
        check_obs.check_metrics(str(path))


# ---------------------------------------------------------------------------
# v2 BENCH json schema.
# ---------------------------------------------------------------------------


def test_fixture_payload_is_valid(bench_payload):
    check_obs.validate_bench_payload(bench_payload)  # must not raise
    assert bench_payload["schema_version"] == 2
    # The committed fixture carries an embedded metrics snapshot with
    # quantiles — the shape the report CLI renders.
    series = bench_payload["metrics"]["solver_sweeps"]["series"][0]
    assert series["quantiles"]["p50"] is not None


def test_check_bench_json_on_fixture_file(capsys):
    check_obs.check_bench_json(
        os.path.join(FIXTURES, "bench_v2_fixture.json")
    )
    assert "matches the v2 BENCH schema" in capsys.readouterr().out


@pytest.mark.parametrize(
    "mutate,match",
    [
        (lambda p: p.pop("git_sha"), "missing required key 'git_sha'"),
        (lambda p: p.update(git_sha=""), "git_sha is empty"),
        (lambda p: p.update(schema_version=1), "schema_version 1 < 2"),
        (lambda p: p.update(schema_version="2"), "key 'schema_version'"),
        (lambda p: p.update(ok="yes"), "key 'ok'"),
        (lambda p: p.pop("rows"), "missing required key 'rows'"),
        (lambda p: p["rows"].append({"name": "x"}), "us_per_call"),
        (
            lambda p: p["rows"].append(
                {"name": "", "us_per_call": 1.0, "derived": ""}
            ),
            "has no name",
        ),
        (
            lambda p: p["rows"].append(
                {"name": "x", "us_per_call": True, "derived": ""}
            ),
            "us_per_call is not a number",
        ),
        (lambda p: p.update(metrics=[1, 2]), "metrics snapshot"),
        (lambda p: p.update(metrics={"m": {"series": []}}), "lacks type"),
    ],
)
def test_schema_mutations_rejected(bench_payload, mutate, match):
    payload = copy.deepcopy(bench_payload)
    mutate(payload)
    with pytest.raises(ValueError, match=match):
        check_obs.validate_bench_payload(payload)


def test_schema_allows_additive_keys(bench_payload):
    payload = copy.deepcopy(bench_payload)
    payload["git_dirty"] = True
    payload["future_field"] = {"anything": 1}
    check_obs.validate_bench_payload(payload)


def test_schema_allows_null_metrics(bench_payload):
    payload = copy.deepcopy(bench_payload)
    payload["metrics"] = None
    check_obs.validate_bench_payload(payload)


def test_check_bench_json_bad_file_exits(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({"bench": "x"}))
    with pytest.raises(SystemExit, match="violates the v2 schema"):
        check_obs.check_bench_json(str(path))
