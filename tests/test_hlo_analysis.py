"""HLO analysis parser: validated against unrolled-scan ground truth."""
import jax
import jax.numpy as jnp
import pytest

from repro.distributed.compat import shard_map_compat
from repro.launch.hlo_analysis import collective_stats, compute_stats


def _body(x, w):
    return jnp.tanh(x @ w), None


def _scanned(x, ws):
    return jax.lax.scan(_body, x, ws)[0]


def _unrolled(x, ws):
    for i in range(10):
        x, _ = _body(x, ws[i])
    return x


X = jax.ShapeDtypeStruct((128, 256), jnp.float32)
WS = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
EXPECTED_FLOPS = 2 * 128 * 256 * 256 * 10


def test_scan_flops_loop_multiplicity():
    c = jax.jit(_scanned).lower(X, WS).compile()
    stats = compute_stats(c.as_text())
    assert stats["flops"] == pytest.approx(EXPECTED_FLOPS, rel=1e-6)


def test_scan_matches_unrolled():
    cs = compute_stats(jax.jit(_scanned).lower(X, WS).compile().as_text())
    cu = compute_stats(jax.jit(_unrolled).lower(X, WS).compile().as_text())
    assert cs["flops"] == pytest.approx(cu["flops"], rel=1e-6)


def test_grad_flops_ratio():
    g = jax.jit(
        jax.grad(lambda x, ws: _scanned(x, ws).sum())
    ).lower(X, WS).compile()
    stats = compute_stats(g.as_text())
    # backward of a matmul chain costs 2x the forward.
    assert stats["flops"] == pytest.approx(2 * EXPECTED_FLOPS, rel=1e-6)


def test_plain_matmul_bytes_reasonable():
    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    c = jax.jit(lambda a, b: a @ b).lower(a, a).compile()
    stats = compute_stats(c.as_text())
    minimal = 3 * 512 * 512 * 4  # two reads + one write
    assert minimal <= stats["bytes"] <= 4 * minimal


def test_collective_counting_with_psum():
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import PartitionSpec as P

    def f(x):
        return jax.lax.psum(x, "data")

    fn = shard_map_compat(f, mesh=mesh, in_specs=P(), out_specs=P())
    c = jax.jit(fn).lower(jax.ShapeDtypeStruct((1024,), jnp.float32)).compile()
    stats = collective_stats(c.as_text())
    # single-device psum may be optimized away; stats must not crash and
    # must report a numeric total either way.
    assert isinstance(stats.total_bytes, (int, float))


def test_scan_in_scan_multiplicity():
    def inner(x, w):
        return jax.lax.scan(_body, x, w)[0]

    def outer(x, ws):
        def step(c, w3):
            return inner(c, w3), None
        return jax.lax.scan(step, x, ws)[0]

    ws = jax.ShapeDtypeStruct((4, 10, 256, 256), jnp.float32)
    c = jax.jit(outer).lower(X, ws).compile()
    stats = compute_stats(c.as_text())
    assert stats["flops"] == pytest.approx(4 * EXPECTED_FLOPS, rel=1e-6)
