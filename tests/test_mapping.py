import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.devices import MRAM, PCM, custom_tech
from repro.core.mapping import map_network, map_wb


def _rand_layer(key, fan_in=12, fan_out=7):
    kw, kb = jax.random.split(key)
    w = jax.random.normal(kw, (fan_in, fan_out))
    b = jax.random.normal(kb, (fan_out,))
    return w, b


def test_differential_mapping_exact():
    w, b = _rand_layer(jax.random.PRNGKey(0))
    m = map_wb(w, b, PCM, v_unit=0.8, quantize=False)
    wb = jnp.concatenate([w, b[None]], axis=0)
    np.testing.assert_allclose(
        np.asarray(m.effective_weights()), np.asarray(wb), rtol=1e-5, atol=1e-7
    )


def test_conductance_bounds():
    w, b = _rand_layer(jax.random.PRNGKey(1))
    m = map_wb(w, b, MRAM, v_unit=0.8)
    for g in (m.g_pos, m.g_neg):
        assert float(g.min()) >= MRAM.g_off * (1 - 1e-6)
        assert float(g.max()) <= MRAM.g_on * (1 + 1e-6)


def test_one_side_at_goff():
    """Differential scheme: for each weight, at least one side is G_off."""
    w, b = _rand_layer(jax.random.PRNGKey(2))
    m = map_wb(w, b, MRAM, v_unit=0.8, quantize=False)
    lo = jnp.minimum(m.g_pos, m.g_neg)
    np.testing.assert_allclose(np.asarray(lo), MRAM.g_off, rtol=1e-6)


def test_ideal_current_recovers_preactivation():
    """z = I_diff / (k v_unit) must equal W^T a + b for ideal crossbars."""
    w, b = _rand_layer(jax.random.PRNGKey(3))
    m = map_wb(w, b, PCM, v_unit=0.8, quantize=False)
    a = jax.random.uniform(jax.random.PRNGKey(4), (5, w.shape[0]))
    v = jnp.concatenate([a, jnp.ones((5, 1))], axis=1) * m.v_unit
    i_diff = v @ (m.g_pos - m.g_neg)
    z = i_diff / (m.k * m.v_unit)
    np.testing.assert_allclose(
        np.asarray(z), np.asarray(a @ w + b), rtol=1e-4, atol=1e-5
    )


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=1, max_value=20),
    st.integers(min_value=1, max_value=12),
    st.integers(min_value=2, max_value=32),
)
def test_quantization_error_bounded(fan_in, fan_out, levels):
    """Property: quantised effective weights deviate <= half a level."""
    tech = custom_tech(1e3, 1e6, levels=levels)
    key = jax.random.PRNGKey(fan_in * 131 + fan_out)
    w, b = _rand_layer(key, fan_in, fan_out)
    m = map_wb(w, b, tech, v_unit=0.8, quantize=True)
    wb = jnp.concatenate([w, b[None]], axis=0)
    step_w = m.w_scale / (levels - 1)
    err = jnp.max(jnp.abs(m.effective_weights() - wb))
    assert float(err) <= step_w / 2 + 1e-6 * m.w_scale


def test_map_network_shapes():
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    params = [_rand_layer(k, 8, 6) for k in keys[:1]] + [
        _rand_layer(keys[1], 6, 4), _rand_layer(keys[2], 4, 3)
    ]
    mapped = map_network(params, PCM, v_unit=0.8)
    assert [m.fan_in for m in mapped] == [8, 6, 4]
    assert [m.fan_out for m in mapped] == [6, 4, 3]


def test_zero_weights():
    w = jnp.zeros((4, 3))
    b = jnp.zeros((3,))
    m = map_wb(w, b, MRAM, v_unit=0.8)
    np.testing.assert_allclose(np.asarray(m.g_pos), MRAM.g_off)
    np.testing.assert_allclose(np.asarray(m.g_neg), MRAM.g_off)
