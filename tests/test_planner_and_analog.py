"""Deployment planner + analog-serving-mode tests."""
import dataclasses

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.core.planner import plan_arch
from repro.models import build_model


def test_planner_all_archs():
    for name, cfg in ARCHS.items():
        rep = plan_arch(cfg, tech="PCM", array_rows=512, array_cols=512)
        assert rep.total_tiles > 0
        assert rep.total_devices > 2 * cfg.n_params() * 0.5  # diff pairs
        assert rep.est_power_w > 0 and rep.area_mm2 > 0


def test_planner_device_count_tracks_params():
    yi = plan_arch(ARCHS["yi-9b"], "PCM")
    mini = plan_arch(ARCHS["minicpm-2b"], "PCM")
    assert yi.total_devices > mini.total_devices


def test_planner_tech_power_ordering():
    """Paper Table IV at LLM scale: PCM cheapest, RRAM most expensive."""
    powers = {
        t: plan_arch(ARCHS["yi-9b"], t).est_power_w
        for t in ("MRAM", "RRAM", "CBRAM", "PCM")
    }
    assert powers["PCM"] == min(powers.values())
    assert powers["RRAM"] == max(powers.values())


def test_planner_partition_arithmetic():
    rep = plan_arch(ARCHS["granite-3-8b"], "PCM", 512, 512)
    mat = {p.name: p for p in rep.matrices}
    # 4096 -> 4096 projection on 512x512: ceil(4097/512)=9, ceil(4096/512)=8
    assert (mat["wq"].hp, mat["wq"].vp) == (9, 8)


def test_analog_mvm_mode_close_to_digital():
    cfg = dataclasses.replace(
        get_config("granite-3-8b").reduced(), n_layers=2,
        param_dtype="float32", compute_dtype="float32",
    )
    digital = build_model(cfg, remat=False)
    analog = build_model(
        dataclasses.replace(cfg, analog_mvm=True, analog_tech="PCM"),
        remat=False,
    )
    params = digital.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab),
    }
    ld, _ = digital.forward(params, batch)
    la, _ = analog.forward(params, batch)
    # Analog quantisation perturbs but must stay correlated.
    d = np.asarray(ld).reshape(-1)
    a = np.asarray(la).reshape(-1)
    corr = np.corrcoef(d, a)[0, 1]
    assert corr > 0.95, corr
    assert not np.allclose(d, a)  # the non-idealities are actually applied
