"""Shared test fixtures. NOTE: no XLA_FLAGS device-count override here —
smoke tests and benches must see the single real CPU device; only
launch/dryrun.py fakes 512 devices (in its own process)."""
import os

import jax
import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, settings

    # Deterministic fuzzing: CI runs are derandomized (seed derived from the
    # test name, so failures reproduce across runs); local runs explore but
    # print a reproduction blob. JIT warm-up makes the default 200 ms
    # deadline flaky, so it is bounded but generous, and the example
    # database is disabled to keep runs hermetic.
    settings.register_profile(
        "repro",
        max_examples=25,
        derandomize=bool(os.environ.get("CI")),
        print_blob=True,
        database=None,
        deadline=60_000,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    settings.load_profile("repro")
except ImportError:  # pragma: no cover - hypothesis is optional
    pass


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def trained_tiny_mlp():
    """A small trained MLP + dataset shared across integration tests."""
    from repro.core.digital import train_mlp, accuracy
    from repro.data.digits import train_test_split

    xtr, ytr, xte, yte = train_test_split(1200, 300, seed=0)
    params = train_mlp(
        jax.random.PRNGKey(0), [400, 48, 24, 10], xtr, ytr, steps=250
    )
    acc = accuracy(params, xte, yte)
    assert acc > 0.9, f"reference MLP failed to train: {acc}"
    return params, xte, yte
