import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.partition import (
    auto_partition,
    combine_outputs,
    plan_partition,
    plan_topology,
    tile_inputs,
    tile_matrix,
    untile_matrix,
)

TOPOLOGY = [400, 120, 84, 10]  # the paper's MNIST MLP

# Paper Table III: array size -> (H_P, V_P).
TABLE_III = {
    32: ([13, 4, 3], [4, 3, 1]),
    64: ([7, 2, 2], [2, 2, 1]),
    128: ([4, 1, 1], [1, 1, 1]),
    256: ([2, 1, 1], [1, 1, 1]),
    512: ([1, 1, 1], [1, 1, 1]),
}


@pytest.mark.parametrize("size", sorted(TABLE_III))
def test_table_iii_auto_partitioning(size):
    """auto_partition reproduces the paper's Table III exactly."""
    want_hp, want_vp = TABLE_III[size]
    got = [
        auto_partition(TOPOLOGY[i], TOPOLOGY[i + 1], size, size)
        for i in range(3)
    ]
    assert [g[0] for g in got] == want_hp
    assert [g[1] for g in got] == want_vp


def test_plan_topology_defaults():
    plans = plan_topology(TOPOLOGY, 32, 32)
    assert [p.hp for p in plans] == [13, 4, 3]
    assert [p.vp for p in plans] == [4, 3, 1]
    assert plans[0].total_rows == 401  # bias row folded in


def test_plan_topology_custom():
    plans = plan_topology(TOPOLOGY, 32, 32, hp=[16, 8, 8], vp=[8, 8, 1])
    assert [p.hp for p in plans] == [16, 8, 8]


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=40),
    st.integers(min_value=1, max_value=30),
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=1, max_value=8),
)
def test_tile_untile_roundtrip(fan_in, fan_out, hp, vp):
    hp = min(hp, fan_in + 1)
    vp = min(vp, fan_out)
    plan = plan_partition(fan_in, fan_out, hp, vp)
    key = jax.random.PRNGKey(fan_in * 1000 + fan_out * 10 + hp)
    g = jax.random.normal(key, (plan.total_rows, plan.total_cols))
    tiles = tile_matrix(g, plan)
    assert tiles.shape == (plan.n_tiles, plan.rows, plan.cols)
    np.testing.assert_allclose(np.asarray(untile_matrix(tiles, plan)), np.asarray(g))


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=30),
    st.integers(min_value=1, max_value=20),
    st.integers(min_value=1, max_value=6),
    st.integers(min_value=1, max_value=6),
)
def test_partitioned_ideal_mvm_equals_full(fan_in, fan_out, hp, vp):
    """Property: tiled ideal crossbar MVM == full-matrix MVM.

    This is the core invariant of partitioning: splitting a layer across
    subarrays and summing partial currents is exact in the ideal case.
    """
    hp = min(hp, fan_in + 1)
    vp = min(vp, fan_out)
    plan = plan_partition(fan_in, fan_out, hp, vp)
    key = jax.random.PRNGKey(fan_in + 97 * fan_out + 31 * hp + vp)
    kg, kv = jax.random.split(key)
    g = jax.random.uniform(kg, (plan.total_rows, plan.total_cols))
    v = jax.random.uniform(kv, (3, plan.total_rows))

    tiles = tile_matrix(g, plan)                      # (T, M, N)
    v_t = tile_inputs(v, plan)                        # (3, hp, M)
    v_per_tile = jnp.repeat(v_t, plan.vp, axis=1)     # (3, T, M)
    i_tiles = jnp.einsum("tmn,btm->btn", tiles, v_per_tile)
    out = combine_outputs(i_tiles, plan)              # (3, fan_out)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(v @ g), rtol=2e-4, atol=1e-5
    )


def test_validation_errors():
    with pytest.raises(ValueError):
        plan_partition(10, 5, 0, 1)
    with pytest.raises(ValueError):
        plan_partition(10, 5, 12, 1)  # more partitions than rows
    with pytest.raises(ValueError):
        tile_matrix(jnp.zeros((5, 5)), plan_partition(10, 5, 2, 1))
