"""Hypothesis fuzzing of the SPICE interchange (repro.spice).

Two properties, each with fixed-example twins that run even without
hypothesis installed:

  * round-trip stability — every netlist `map_imac` emits parses back
    to an equivalent `Circuit` (byte-stable re-emit, conductances and
    drives recovered), over random topologies/techs/samples;
  * parser-vs-dense-MNA agreement — a random wire-grid crossbar
    netlist, lowered structurally, solved by the crossbar dense MNA
    oracle, matches the generic nodal solve of the *parsed text* to
    1e-6 relative on every node voltage (float64).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypcompat import given, seed, settings, st
from test_spice_lower import wired_crossbar

from repro.core.devices import CBRAM, MRAM, PCM, RRAM
from repro.core.imac import IMACConfig, build_plans
from repro.core.mapping import map_network
from repro.core.netlist import map_imac, netlist_stats
from repro.spice import emit, lower_crossbar, lower_network, parse_netlist, solve_dc

TECHS = {"MRAM": MRAM, "RRAM": RRAM, "CBRAM": CBRAM, "PCM": PCM}


# ---------------------------------------------------------------------------
# Property 1: generated netlists round-trip.
# ---------------------------------------------------------------------------


def check_generated_roundtrip(topology, array_size, tech_name, seed_, transient):
    tech = TECHS[tech_name]
    cfg = IMACConfig(
        tech=tech_name, array_rows=array_size, array_cols=array_size
    )
    key = jax.random.PRNGKey(seed_)
    params = []
    for fan_in, fan_out in zip(topology, topology[1:]):
        key, k = jax.random.split(key)
        params.append(
            (jax.random.normal(k, (fan_in, fan_out)), jnp.zeros((fan_out,)))
        )
    mapped = map_network(params, tech, v_unit=cfg.vdd)
    plans = build_plans(topology, cfg)
    rng = np.random.default_rng(seed_)
    sample = rng.uniform(0.0, 1.0, size=topology[0])
    spec = None
    if transient:
        from repro.transient.spec import TransientSpec

        spec = TransientSpec(t_stop=2e-9, n_steps=8, method="trap")
    files = map_imac(mapped, plans, cfg, sample=sample, transient=spec)

    # Byte-stable: parse each file and re-emit.
    for name, text in files.items():
        assert emit(parse_netlist(text)) == text, f"{name} not byte-stable"
    # Structural stats survive the round trip.
    reemitted = {n: emit(parse_netlist(t)) for n, t in files.items()}
    assert netlist_stats(reemitted) == netlist_stats(files)

    # Equivalent Circuit: lowering recovers the mapping and the drives.
    net = lower_network(files)
    assert net.topology == list(topology)
    for la, mp in zip(net.layers, mapped):
        np.testing.assert_allclose(la.g_pos, np.asarray(mp.g_pos), rtol=1e-5)
        np.testing.assert_allclose(la.g_neg, np.asarray(mp.g_neg), rtol=1e-5)
    np.testing.assert_allclose(net.sample, sample, atol=2e-6)
    assert net.has_pwl == transient


@seed(2026)
@given(
    n_in=st.integers(min_value=2, max_value=6),
    hidden=st.integers(min_value=1, max_value=5),
    n_out=st.integers(min_value=1, max_value=4),
    array_size=st.integers(min_value=2, max_value=5),
    tech_name=st.sampled_from(sorted(TECHS)),
    seed_=st.integers(min_value=0, max_value=2**16),
    transient=st.booleans(),
)
@settings(max_examples=20)
def test_fuzz_generated_roundtrip(
    n_in, hidden, n_out, array_size, tech_name, seed_, transient
):
    check_generated_roundtrip(
        [n_in, hidden, n_out], array_size, tech_name, seed_, transient
    )


@pytest.mark.parametrize(
    "topology,array_size,tech_name,seed_,transient",
    [
        ([6, 4, 3], 4, "MRAM", 0, False),
        ([5, 3, 2], 3, "RRAM", 7, True),
        ([2, 5, 4], 2, "PCM", 123, False),
    ],
)
def test_generated_roundtrip_examples(
    topology, array_size, tech_name, seed_, transient
):
    check_generated_roundtrip(topology, array_size, tech_name, seed_, transient)


# ---------------------------------------------------------------------------
# Property 2 (acceptance criterion): lowered netlists match the dense
# MNA oracle to 1e-6 relative on node voltages.
# ---------------------------------------------------------------------------


def check_lowered_matches_dense_mna(
    m, n, seed_, r_source, r_tia, r_row, r_col
):
    rng = np.random.default_rng(seed_)
    g = 1.0 / rng.uniform(5e3, 3e5, size=(m, n))
    v = rng.uniform(0.0, 1.0, size=m)
    text = wired_crossbar(
        g, v, r_source=r_source, r_tia=r_tia, r_row=r_row, r_col=r_col
    )
    circ = parse_netlist(text)
    xb = lower_crossbar(circ)
    np.testing.assert_allclose(xb.g, g, rtol=1e-9)
    np.testing.assert_allclose(xb.v_in, v, rtol=1e-12)

    op = solve_dc(circ)  # generic nodal solve of the parsed text
    with jax.experimental.enable_x64():
        got = xb.node_voltages(xb.solve_dense())
    assert got, "no node voltages recovered"
    for node, want in got.items():
        assert want == pytest.approx(op.voltages[node], rel=1e-6, abs=1e-12), (
            f"node {node}: dense MNA {want} vs nodal oracle "
            f"{op.voltages[node]}"
        )


@seed(2027)
@given(
    m=st.integers(min_value=2, max_value=5),
    n=st.integers(min_value=2, max_value=5),
    seed_=st.integers(min_value=0, max_value=2**16),
    r_source=st.floats(min_value=10.0, max_value=500.0),
    r_tia=st.floats(min_value=1.0, max_value=50.0),
    r_row=st.floats(min_value=1.0, max_value=60.0),
    r_col=st.floats(min_value=1.0, max_value=60.0),
)
@settings(max_examples=25)
def test_fuzz_lowered_matches_dense_mna(
    m, n, seed_, r_source, r_tia, r_row, r_col
):
    check_lowered_matches_dense_mna(m, n, seed_, r_source, r_tia, r_row, r_col)


@pytest.mark.parametrize(
    "m,n,seed_,r_source,r_tia,r_row,r_col",
    [
        (2, 2, 0, 100.0, 10.0, 13.8, 13.8),
        (4, 3, 42, 250.0, 5.0, 2.0, 55.0),
        (5, 5, 9, 33.0, 47.0, 21.5, 1.25),
    ],
)
def test_lowered_matches_dense_mna_examples(
    m, n, seed_, r_source, r_tia, r_row, r_col
):
    check_lowered_matches_dense_mna(m, n, seed_, r_source, r_tia, r_row, r_col)


# ---------------------------------------------------------------------------
# Property 3: third-party text canonicalizes in one round trip.
# ---------------------------------------------------------------------------


def check_canonicalization(m, n, seed_, upper):
    rng = np.random.default_rng(seed_)
    g = 1.0 / rng.uniform(5e3, 3e5, size=(m, n))
    v = rng.uniform(0.0, 1.0, size=m)
    text = wired_crossbar(g, v)
    if upper:
        text = text.upper()
    once = emit(parse_netlist(text))
    assert emit(parse_netlist(once)) == once


@seed(2028)
@given(
    m=st.integers(min_value=1, max_value=4),
    n=st.integers(min_value=1, max_value=4),
    seed_=st.integers(min_value=0, max_value=2**16),
    upper=st.booleans(),
)
@settings(max_examples=25)
def test_fuzz_canonicalization(m, n, seed_, upper):
    check_canonicalization(m, n, seed_, upper)


def test_canonicalization_example():
    check_canonicalization(3, 3, 5, True)
