"""Chunked online-softmax attention vs the einsum oracle (§Perf it.5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import _sdpa, _sdpa_chunked


def _inputs(seed, b=1, sq=1024, h=4, kv=2, d=16):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, sq, kv, d))
    v = jax.random.normal(ks[2], (b, sq, kv, d))
    pos = jnp.broadcast_to(jnp.arange(sq, dtype=jnp.int32), (b, sq))
    return q, k, v, pos


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("chunk", [128, 512])
def test_chunked_matches_einsum(causal, chunk):
    q, k, v, pos = _inputs(0)
    scale = q.shape[-1] ** -0.5
    ref = _sdpa(q, k, v, (pos, pos) if causal else None, scale)
    got = _sdpa_chunked(q, k, v, pos, scale, causal=causal, chunk=chunk)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref), atol=2e-5, rtol=1e-4
    )


def test_chunked_gradients_match():
    q, k, v, pos = _inputs(1, sq=512)
    scale = q.shape[-1] ** -0.5
    g_ref = jax.grad(lambda q: jnp.sum(_sdpa(q, k, v, (pos, pos), scale) ** 2))(q)
    g_chk = jax.grad(
        lambda q: jnp.sum(_sdpa_chunked(q, k, v, pos, scale, chunk=128) ** 2)
    )(q)
    np.testing.assert_allclose(
        np.asarray(g_chk), np.asarray(g_ref), atol=2e-4, rtol=1e-3
    )


def test_chunked_mqa_and_dv():
    """MQA with Dk != Dv (MLA-style shapes)."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    b, sq, h, d, dv = 2, 256, 8, 32, 16
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, sq, 1, d))
    v = jax.random.normal(ks[2], (b, sq, 1, dv))
    pos = jnp.broadcast_to(jnp.arange(sq, dtype=jnp.int32), (b, sq))
    ref = _sdpa(q, k, v, (pos, pos), d ** -0.5)
    got = _sdpa_chunked(q, k, v, pos, d ** -0.5, chunk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5, rtol=1e-4)
