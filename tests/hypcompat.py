"""Guarded hypothesis import for environments without the package.

The CI test extra installs hypothesis (`pip install -e .[test]`), but
bare containers may not have it; property-based tests import `given` /
`settings` / `st` from here so that, when hypothesis is missing, they
collect as skipped instead of erroring at import time. Every fuzz
property keeps a fixed-example parametrized twin that runs everywhere.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import HealthCheck, assume, given, seed, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the dep
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    def seed(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    def assume(_condition):
        return True

    class _AnyStrategy:
        """Stands in for `hypothesis.strategies` at decoration time."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
    HealthCheck = None

__all__ = [
    "HAVE_HYPOTHESIS",
    "HealthCheck",
    "assume",
    "given",
    "seed",
    "settings",
    "st",
]
