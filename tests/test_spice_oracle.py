"""SPICE-oracle differential test.

The generated SPICE netlist is IMAC-Sim's defining artifact; the fast
Gauss–Seidel tridiagonal solver is ours. This property test closes the
loop: for small random crossbars the netlist emitted by core.netlist is
parsed back into conductance matrices, the full dense MNA system is
assembled and solved (the oracle), and the production solver must agree
on node voltages and TIA currents to tight tolerance across random
`CircuitParams` draws — wire, source and TIA resistances included.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.devices import CBRAM, MRAM, PCM, RRAM
from repro.core.imac import IMACConfig, build_plans
from repro.core.interconnect import Interconnect
from repro.core.mapping import map_network
from repro.core.netlist import map_layer, parse_tile_conductances
from repro.core.solver import CircuitParams, solve_crossbar, solve_dense_mna


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    fan_in=st.integers(min_value=2, max_value=6),
    fan_out=st.integers(min_value=1, max_value=4),
    array_size=st.integers(min_value=3, max_value=5),
    tech=st.sampled_from([MRAM, RRAM, CBRAM, PCM]),
    wire_scale=st.floats(min_value=0.1, max_value=5.0),
    r_source=st.floats(min_value=20.0, max_value=300.0),
    r_tia=st.floats(min_value=1.0, max_value=30.0),
)
def test_netlist_mna_matches_gauss_seidel(
    seed, fan_in, fan_out, array_size, tech, wire_scale, r_source, r_tia
):
    key = jax.random.PRNGKey(seed)
    kw, kb, kv = jax.random.split(key, 3)
    params = [
        (
            jax.random.normal(kw, (fan_in, fan_out)),
            0.1 * jax.random.normal(kb, (fan_out,)),
        )
    ]
    # Scale the wire resistivity so r_segment spans ~1.4..69 ohm.
    interconnect = dataclasses.replace(
        Interconnect(), resistivity=1.9e-8 * wire_scale
    )
    cfg = IMACConfig(
        tech=tech,
        interconnect=interconnect,
        array_rows=array_size,
        array_cols=array_size,
        r_source=r_source,
        r_tia=r_tia,
    )
    mapped = map_network(params, tech, v_unit=cfg.vdd)
    plans = build_plans([fan_in, fan_out], cfg)
    plan = plans[0]

    # Netlist -> conductances: the netlist is the source of truth both
    # solvers consume (includes the 6-sig-digit resistor rounding).
    text = map_layer(0, mapped[0], plan, cfg)
    gp, gn = parse_tile_conductances(text, plan)

    r_seg = interconnect.r_segment
    cp = CircuitParams(
        r_row=r_seg,
        r_col=r_seg,
        r_source=r_source,
        r_tia=r_tia,
        gs_iters=400,
        omega=1.8,
        tol=1e-9,
    )
    v = jax.random.uniform(kv, (plan.rows,), minval=0.0, maxval=cfg.vdd)

    for tile in range(plan.n_tiles):
        for g_tile in (gp[tile], gn[tile]):
            g = jnp.asarray(g_tile)
            oracle = solve_dense_mna(g, v, cp)
            fast = solve_crossbar(g, v, cp)
            np.testing.assert_allclose(
                np.asarray(fast.i_out),
                np.asarray(oracle.i_out),
                rtol=1e-3,
                atol=1e-9,
            )
            np.testing.assert_allclose(
                np.asarray(fast.vr),
                np.asarray(oracle.vr),
                rtol=5e-3,
                atol=1e-6,
            )
            np.testing.assert_allclose(
                np.asarray(fast.vc),
                np.asarray(oracle.vc),
                rtol=5e-3,
                atol=1e-6,
            )
