"""Tests for repro.obs: tracer, metrics registry, exporters, overhead."""
import importlib
import json
import threading
import tracemalloc

import pytest

from repro import obs
from repro.obs import metrics as obs_metrics

# The package re-exports the trace() function under the submodule's
# name, so reach the module itself through importlib.
obs_trace = importlib.import_module("repro.obs.trace")


@pytest.fixture(autouse=True)
def _clean_obs():
    """Each test starts disabled with empty stores and leaves the same."""
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


# ---------------------------------------------------------------------------
# Span tracer.
# ---------------------------------------------------------------------------


def test_nested_spans_record_parent_and_depth():
    obs.enable()
    with obs.trace("outer", {"k": 1}):
        with obs.trace("inner"):
            with obs.trace("leaf"):
                pass
        with obs.trace("sibling"):
            pass
    by_name = {s.name: s for s in obs.spans()}
    assert set(by_name) == {"outer", "inner", "leaf", "sibling"}
    assert by_name["outer"].parent is None
    assert by_name["inner"].parent == by_name["outer"].sid
    assert by_name["leaf"].parent == by_name["inner"].sid
    assert by_name["sibling"].parent == by_name["outer"].sid
    assert by_name["leaf"].depth == 2
    assert by_name["outer"].duration >= by_name["inner"].duration
    tree = obs.span_tree()
    assert tree.splitlines()[0].startswith("outer")
    assert "    leaf" in tree


def test_traced_decorator_checks_flag_per_call():
    calls = []

    @obs.traced("decorated")
    def fn(v):
        calls.append(v)
        return v * 2

    assert fn(3) == 6          # disabled: no span
    assert obs.spans() == []
    obs.enable()
    assert fn(4) == 8          # enabled later: spans appear
    assert [s.name for s in obs.spans()] == ["decorated"]
    assert calls == [3, 4]


def test_span_set_attributes_appear_in_exports():
    obs.enable()
    with obs.trace("work") as sp:
        sp.set("items", 7)
    (span,) = obs.spans()
    assert span.attrs == {"items": 7}
    (ev,) = obs.chrome_trace()["traceEvents"]
    assert ev["args"] == {"items": 7}


def test_chrome_trace_round_trip(tmp_path):
    obs.enable()
    with obs.trace("root", {"grid": "2x2"}):
        with obs.trace("child"):
            pass
    obs.add_instant("mark", {"cause": "unit-test"})
    path = tmp_path / "trace.json"
    obs.export_chrome_trace(str(path))
    loaded = json.loads(path.read_text())
    events = loaded["traceEvents"]
    complete = [e for e in events if e["ph"] == "X"]
    instants = [e for e in events if e["ph"] == "i"]
    assert {e["name"] for e in complete} == {"root", "child"}
    assert [e["name"] for e in instants] == ["mark"]
    for e in complete:
        assert e["ts"] >= 0.0 and e["dur"] >= 0.0
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
    root = next(e for e in complete if e["name"] == "root")
    child = next(e for e in complete if e["name"] == "child")
    assert root["ts"] <= child["ts"]
    assert child["ts"] + child["dur"] <= root["ts"] + root["dur"] + 1e-3


def test_thread_safety_smoke():
    obs.enable()

    def worker(i):
        for j in range(50):
            with obs.trace(f"t{i}", {"j": j}):
                obs.counter("thread_ops_total").inc()

    threads = [
        threading.Thread(target=worker, args=(i,)) for i in range(8)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    recorded = obs.spans()
    assert len(recorded) == 8 * 50
    # Nesting state is thread-local: every worker span is a root span.
    assert all(s.parent is None for s in recorded)
    assert obs.counter("thread_ops_total").value == 8 * 50
    assert len({s.sid for s in recorded}) == len(recorded)


def test_span_records_error_attribute_when_body_raises():
    obs.enable()
    with pytest.raises(RuntimeError, match="boom"):
        with obs.trace("outer"):
            with obs.trace("failing"):
                raise RuntimeError("boom")
    by_name = {s.name: s for s in obs.spans()}
    assert by_name["failing"].attrs["error"] == "RuntimeError: boom"
    # The exception unwound through the parent too — both are closed,
    # both carry the error, and nesting state is intact for new spans.
    assert by_name["outer"].attrs["error"] == "RuntimeError: boom"
    with obs.trace("after"):
        pass
    assert {s.name: s.parent for s in obs.spans()}["after"] is None


def test_span_error_survives_existing_attrs():
    obs.enable()
    with pytest.raises(ValueError):
        with obs.trace("work", {"items": 3}):
            raise ValueError("bad input")
    (span,) = obs.spans()
    assert span.attrs["items"] == 3
    assert span.attrs["error"].startswith("ValueError")


def test_chrome_exporter_flushes_open_spans():
    obs.enable()
    outer = obs.trace("outer")
    outer.__enter__()
    with obs.trace("closed"):
        pass
    events = obs.chrome_trace()["traceEvents"]
    by_name = {e["name"]: e for e in events if e["ph"] == "X"}
    assert set(by_name) == {"outer", "closed"}
    assert by_name["outer"]["args"]["unfinished"] is True
    assert "unfinished" not in by_name["closed"]["args"]
    assert by_name["outer"]["dur"] >= by_name["closed"]["dur"]
    # Closing the span moves it to the finished list: no double export.
    outer.__exit__(None, None, None)
    events = obs.chrome_trace()["traceEvents"]
    outers = [e for e in events if e["name"] == "outer"]
    assert len(outers) == 1
    assert "unfinished" not in outers[0]["args"]
    assert obs_trace.live_spans() == []


def test_export_chrome_trace_with_open_span_round_trips(tmp_path):
    obs.enable()
    sp = obs.trace("crashing_stage")
    sp.__enter__()
    path = tmp_path / "trace.json"
    obs.export_chrome_trace(str(path))
    loaded = json.loads(path.read_text())
    (ev,) = [
        e for e in loaded["traceEvents"] if e["name"] == "crashing_stage"
    ]
    assert ev["args"]["unfinished"] is True
    assert ev["dur"] >= 0.0
    sp.__exit__(None, None, None)


def test_instrument_jit_splits_compile_and_run():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    obs.enable()
    fn = obs.instrument_jit(jax.jit(lambda v: v * 2.0), "double")
    x = jnp.arange(4.0)
    fn(x)
    fn(x)
    fn(jnp.arange(8.0))  # new shape: fresh lowering + compile
    names = [s.name for s in obs.spans()]
    assert names == ["double[compile]", "double[run]", "double[compile]"]


# ---------------------------------------------------------------------------
# Metrics registry.
# ---------------------------------------------------------------------------


def test_counter_and_gauge_basics():
    obs.enable()
    c = obs.counter("reqs_total", {"engine": "explore"})
    c.inc()
    c.inc(2)
    assert c.value == 3
    assert obs.counter("reqs_total", {"engine": "explore"}) is c
    with pytest.raises(ValueError):
        c.inc(-1)
    g = obs.gauge("depth")
    g.set(5)
    g.inc()
    assert g.value == 6


def test_histogram_bucket_edges():
    obs.enable()
    h = obs.histogram("lat", buckets=obs.exponential_buckets(1.0, 2.0, 3))
    assert h.edges == (1.0, 2.0, 4.0)
    for v in (0.5, 1.0, 1.5, 4.0, 100.0):
        h.observe(v)
    # bisect_left: observations equal to an edge land in that bucket.
    assert h.counts == [2, 1, 1, 1]
    cum = h.cumulative()
    assert cum == [(1.0, 2), (2.0, 3), (4.0, 4), (float("inf"), 5)]
    assert h.count == 5
    assert h.sum == pytest.approx(107.0)


def test_histogram_quantiles_within_bucket_edge_error():
    np = pytest.importorskip("numpy")

    obs.enable()
    h = obs.histogram(
        "lat_q", buckets=obs.exponential_buckets(1.0, 2.0, 12)
    )
    rng = np.random.default_rng(0)
    vals = rng.uniform(0.5, 900.0, size=2000)
    for v in vals:
        h.observe(float(v))
    edges = (0.0,) + h.edges
    for q in (0.5, 0.95, 0.99):
        est = h.quantile(q)
        exact = float(np.quantile(vals, q))
        # The estimate must land in the same bucket as the exact value,
        # i.e. within that bucket's width of it.
        idx = int(np.searchsorted(edges, exact, side="left"))
        width = edges[min(idx, len(edges) - 1)] - edges[max(idx - 1, 0)]
        assert abs(est - exact) <= width, (q, est, exact, width)


def test_histogram_quantile_exact_edges_and_interpolation():
    obs.enable()
    h = obs.histogram("q_edges", buckets=(1.0, 2.0, 4.0))
    for v in (2.0, 2.0, 4.0, 4.0):
        h.observe(v)
    # All mass sits on the (1,2] and (2,4] buckets: p50 = the 2.0 edge.
    assert h.quantile(0.5) == pytest.approx(2.0)
    # p100 = upper edge of the last occupied bucket.
    assert h.quantile(1.0) == pytest.approx(4.0)
    # Halfway into the second bucket's mass: linear interpolation.
    assert 2.0 < h.quantile(0.75) <= 4.0


def test_histogram_quantile_overflow_clamps_to_top_edge():
    obs.enable()
    h = obs.histogram("q_over", buckets=(1.0, 2.0))
    h.observe(1000.0)
    h.observe(2000.0)
    assert h.quantile(0.5) == pytest.approx(2.0)
    assert h.quantiles()["p99"] == pytest.approx(2.0)


def test_histogram_quantile_empty_and_validation():
    obs.enable()
    h = obs.histogram("q_empty", buckets=(1.0,))
    assert h.quantile(0.5) is None
    assert h.quantiles() == {"p50": None, "p95": None, "p99": None}
    h.observe(0.5)
    with pytest.raises(ValueError):
        h.quantile(1.5)
    assert obs.quantile_from_cumulative([], 0.5) is None


def test_snapshot_surfaces_quantiles():
    obs.enable()
    h = obs.histogram("snap_q", buckets=(1.0, 2.0, 4.0))
    for v in (1.5, 1.5, 3.0, 3.0):
        h.observe(v)
    snap = json.loads(obs.export_json())
    series = snap["snap_q"]["series"][0]
    assert set(series["quantiles"]) == {"p50", "p95", "p99"}
    assert 1.0 <= series["quantiles"]["p50"] <= 2.0
    assert 2.0 < series["quantiles"]["p99"] <= 4.0


def test_exponential_buckets_validation():
    assert obs.exponential_buckets(1e-2, 10.0, 3) == (1e-2, 1e-1, 1.0)
    with pytest.raises(ValueError):
        obs.exponential_buckets(0.0, 2.0, 4)
    with pytest.raises(ValueError):
        obs.exponential_buckets(1.0, 1.0, 4)


def test_metric_kind_conflict_raises():
    obs.enable()
    obs.counter("x_total")
    with pytest.raises(ValueError):
        obs.gauge("x_total")


def test_event_increments_labeled_counter_and_log():
    obs.enable()
    obs.event("backend_fallback", cause="vmem_budget", tile="512x512")
    obs.event("backend_fallback", cause="vmem_budget", tile="512x512")
    obs.event("backend_fallback", cause="interpret_mode", extra=[1, 2])
    recs = obs.events("backend_fallback")
    assert len(recs) == 3
    assert recs[-1]["fields"]["extra"] == [1, 2]
    exp = obs.export_prometheus()
    assert (
        'backend_fallback_total{cause="vmem_budget",tile="512x512"} 2' in exp
    )
    assert 'cause="interpret_mode"' in exp
    # Events also mark the span timeline.
    instants = [
        e for e in obs.chrome_trace()["traceEvents"] if e["ph"] == "i"
    ]
    assert len(instants) == 3


def test_prometheus_export_format():
    obs.enable()
    obs.counter("hits_total").inc(4)
    h = obs.histogram("sw", buckets=(1.0, 2.0))
    h.observe(1.5)
    h.observe(10.0)
    text = obs.export_prometheus()
    lines = text.splitlines()
    assert "# TYPE hits_total counter" in lines
    assert "hits_total 4" in lines
    assert "# TYPE sw histogram" in lines
    assert 'sw_bucket{le="1"} 0' in lines
    assert 'sw_bucket{le="2"} 1' in lines
    assert 'sw_bucket{le="+Inf"} 2' in lines
    assert "sw_sum 11.5" in lines
    assert "sw_count 2" in lines


def test_snapshot_json_round_trip():
    obs.enable()
    obs.counter("c_total", {"k": "v"}).inc()
    obs.histogram("h", buckets=(1.0,)).observe(0.5)
    snap = json.loads(obs.export_json())
    assert snap["c_total"]["type"] == "counter"
    assert snap["c_total"]["series"][0]["labels"] == {"k": "v"}
    hseries = snap["h"]["series"][0]
    assert hseries["count"] == 1
    assert hseries["buckets"][0]["count"] == 1


# ---------------------------------------------------------------------------
# Disabled mode.
# ---------------------------------------------------------------------------


def test_disabled_mode_returns_shared_noops_and_records_nothing():
    assert obs.trace("x") is obs_trace._NOOP
    assert obs.trace("y", {"a": 1}) is obs_trace._NOOP
    assert obs.counter("c_total") is obs_metrics._NOOP
    assert obs.histogram("h") is obs_metrics._NOOP
    with obs.trace("x") as sp:
        sp.set("k", "v")
    obs.counter("c_total").inc()
    obs.event("nothing", cause="disabled")
    obs.add_instant("nothing")
    obs.enable()
    assert obs.spans() == []
    assert obs.events() == []
    assert obs.export_prometheus() == ""


def test_disabled_mode_zero_allocations_on_hot_path():
    c = obs.counter("hot_total")
    span_fn, counter_fn = obs.trace, obs.counter
    # Warm up any lazy interning, then measure.
    for _ in range(10):
        with span_fn("hot"):
            c.inc()
    tracemalloc.start()
    for _ in range(1000):
        with span_fn("hot"):
            counter_fn("hot_total").inc()
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    # The loop itself allocates nothing measurable: no span objects, no
    # metric instances, no dicts (slack covers tracemalloc's own frame
    # bookkeeping).
    assert peak < 4096, f"disabled-mode hot path allocated {peak} bytes"


def test_env_var_enables(monkeypatch):
    from repro.obs import state

    monkeypatch.setenv("REPRO_OBS", "1")
    # Fresh evaluation of the env logic (module already imported).
    assert state._env_enabled()
    monkeypatch.setenv("REPRO_OBS", "0")
    assert not state._env_enabled()
    monkeypatch.delenv("REPRO_OBS")
    assert not state._env_enabled()
