"""Multi-device sharded sweep execution.

Covers repro.distributed.sweep (MeshPlan, padding, staging pipeline),
the run_sweep/run_variability ``shard=`` path's bitwise identity with
the single-device engine, the concurrency-safe ResultCache, and the
ledger's mesh tagging.

Single-device hosts run the pure-helper and 1-device-mesh tests; the
genuinely multi-device cases skip unless the process was launched with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
mesh-smoke job does exactly that).
"""
import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import IMACConfig
from repro.core.evaluate import IMACResult
from repro.distributed.sweep import (
    MeshPlan,
    as_mesh_plan,
    pad_count,
    pad_stacked,
    shard_put,
    stacked_spec,
    stage_pipeline,
)
from repro.explore import ResultCache, SweepSpec, run_sweep

N_DEVICES = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEVICES < 2,
    reason="needs a multi-device mesh "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


@pytest.fixture(autouse=True)
def _clean_obs():
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


# ----------------------------------------------------------- pure helpers


def test_pad_count():
    assert pad_count(3, 8) == 8
    assert pad_count(8, 8) == 8
    assert pad_count(9, 8) == 16
    assert pad_count(1, 1) == 1


@pytest.mark.parametrize("c", [1, 3, 5, 7])
def test_pad_stacked_replicates_entry_zero(c):
    x = jnp.arange(c * 6, dtype=jnp.float32).reshape(c, 2, 3)
    padded = pad_stacked(x, 8)
    assert padded.shape == (8, 2, 3)
    np.testing.assert_array_equal(np.asarray(padded[:c]), np.asarray(x))
    for lane in range(c, 8):
        np.testing.assert_array_equal(
            np.asarray(padded[lane]), np.asarray(x[0])
        )


def test_pad_stacked_noop_when_divisible():
    x = jnp.ones((8, 3))
    assert pad_stacked(x, 8) is x
    assert pad_stacked(x, 4) is x


def test_as_mesh_plan_coercions():
    assert as_mesh_plan(None) is None
    assert as_mesh_plan(False) is None
    assert as_mesh_plan(True) == MeshPlan()
    assert as_mesh_plan(4) == MeshPlan(devices=4)
    plan = MeshPlan(devices=2, overlap=False)
    assert as_mesh_plan(plan) is plan
    with pytest.raises(TypeError, match="shard="):
        as_mesh_plan("data")


def test_stage_pipeline_double_buffers():
    """Group i+1 must be staged before group i is yielded."""
    staged = []
    out = []
    for i, item in stage_pipeline(
        ["a", "b", "c"], lambda g: staged.append(g) or g.upper()
    ):
        # By the time we consume item i, item i+1 is already staged.
        assert len(staged) == min(i + 2, 3)
        out.append((i, item))
    assert out == [(0, "A"), (1, "B"), (2, "C")]
    assert staged == ["a", "b", "c"]


def test_stage_pipeline_empty():
    assert list(stage_pipeline([], lambda g: g)) == []


def test_meshplan_shape_str_and_axis_size():
    plan = MeshPlan(devices=1)
    assert plan.axis_size() == 1
    assert plan.shape_str() == "data1"
    if N_DEVICES > 1:
        assert MeshPlan().shape_str() == f"data{N_DEVICES}"


@multi_device
def test_stacked_spec_divisibility_fallback():
    mesh = jax.make_mesh((N_DEVICES,), ("data",))
    # Leading dim divides the mesh axis: sharded on it.
    sharded = stacked_spec(jnp.zeros((N_DEVICES * 2, 4)), mesh)
    assert sharded[0] == "data"
    # Non-divisible leading dim: falls back to replicated, not an error.
    repl = stacked_spec(jnp.zeros((3, 4)), mesh)
    assert all(s is None for s in repl)


@multi_device
def test_shard_put_places_divisible_leaves():
    mesh = jax.make_mesh((N_DEVICES,), ("data",))
    tree = {
        "even": jnp.zeros((N_DEVICES * 2, 3)),
        "odd": jnp.zeros((3, 3)),
        "scalar": 1.5,
    }
    out = shard_put(tree, mesh)
    assert not out["even"].sharding.is_fully_replicated
    assert out["odd"].sharding.is_fully_replicated
    assert out["scalar"] == 1.5


# ------------------------------------------------- bitwise identity (1 dev)


def _assert_results_equal(a, b):
    assert [r.name for r in a] == [r.name for r in b]
    for ra, rb in zip(a, b):
        assert ra.result == rb.result  # NamedTuple: full bitwise equality


def test_sharded_identical_on_one_device(trained_tiny_mlp):
    """shard= on a 1-device mesh still routes through shard_map + the
    solver's pmax cond and must change nothing."""
    params, xte, yte = trained_tiny_mlp
    spec = SweepSpec.grid(IMACConfig(), tech=["MRAM", "PCM"])
    plain = run_sweep(params, xte, yte, spec, n_samples=8, chunk=8)
    sharded = run_sweep(
        params, xte, yte, spec, n_samples=8, chunk=8,
        shard=MeshPlan(devices=1),
    )
    _assert_results_equal(plain, sharded)


def test_small_groups_fall_back(trained_tiny_mlp):
    """Groups below min_group use the plain path (and still match)."""
    params, xte, yte = trained_tiny_mlp
    cfgs = [("solo", IMACConfig(tech="PCM", parasitics=False))]
    plain = run_sweep(params, xte, yte, cfgs, n_samples=8, chunk=8)
    sharded = run_sweep(
        params, xte, yte, cfgs, n_samples=8, chunk=8,
        shard=MeshPlan(devices=1, min_group=2),
    )
    _assert_results_equal(plain, sharded)


# --------------------------------------------- bitwise identity (n devices)


@multi_device
@pytest.mark.parametrize("c", [3, 5, 7])
def test_odd_group_sizes_bitwise_identical(trained_tiny_mlp, c):
    """Non-divisible group sizes: pad lanes must not perturb results."""
    params, xte, yte = trained_tiny_mlp
    cfgs = [
        (f"r{i}", IMACConfig(r_tia=5.0 + 0.5 * i)) for i in range(c)
    ]
    plain = run_sweep(params, xte, yte, cfgs, n_samples=8, chunk=8)
    sharded = run_sweep(params, xte, yte, cfgs, n_samples=8, chunk=8,
                        shard=True)
    _assert_results_equal(plain, sharded)


@multi_device
def test_multi_group_sweep_identical(trained_tiny_mlp):
    """Two structure groups (different array sizes) with the
    largest-first scheduler reordering groups internally: per-point
    results must still land in spec order, bitwise equal."""
    params, xte, yte = trained_tiny_mlp
    spec = SweepSpec.grid(
        IMACConfig(),
        tech=["MRAM", "RRAM", "PCM"],
        array_size=[32, 64],
    )
    plain = run_sweep(params, xte, yte, spec, n_samples=8, chunk=8)
    sharded = run_sweep(params, xte, yte, spec, n_samples=8, chunk=8,
                        shard=True)
    _assert_results_equal(plain, sharded)


@multi_device
def test_sweepspec_carries_shard(trained_tiny_mlp):
    params, xte, yte = trained_tiny_mlp
    spec = SweepSpec.grid(
        IMACConfig(), shard=True, tech=["MRAM", "RRAM", "PCM"],
    )
    plain = run_sweep(
        params, xte, yte,
        SweepSpec.grid(IMACConfig(), tech=["MRAM", "RRAM", "PCM"]),
        n_samples=8, chunk=8,
    )
    sharded = run_sweep(params, xte, yte, spec, n_samples=8, chunk=8)
    _assert_results_equal(plain, sharded)


@multi_device
def test_ideal_path_sharded_predictions_bitwise(trained_tiny_mlp):
    """parasitics=False: predictions stay bitwise; the power einsum's
    reduction order follows the local batch shape, so power agrees only
    to float32 reassociation (the documented ideal-MVM caveat)."""
    params, xte, yte = trained_tiny_mlp
    cfgs = [
        (t, IMACConfig(parasitics=False, tech=t))
        for t in ("MRAM", "RRAM", "CBRAM", "PCM")
    ]
    plain = run_sweep(params, xte, yte, cfgs, n_samples=8, chunk=8)
    sharded = run_sweep(params, xte, yte, cfgs, n_samples=8, chunk=8,
                        shard=True)
    for ra, rb in zip(plain, sharded):
        assert ra.result.accuracy == rb.result.accuracy
        assert ra.result.avg_power == pytest.approx(
            rb.result.avg_power, rel=1e-6
        )
        np.testing.assert_allclose(
            ra.result.per_layer_power, rb.result.per_layer_power,
            rtol=1e-6,
        )


@multi_device
def test_shared_variation_key_sharded(trained_tiny_mlp):
    """A paired variation_key + noise-free sweep shards and matches."""
    params, xte, yte = trained_tiny_mlp
    from repro.core.devices import custom_tech

    noisy = custom_tech(5e3, 1e5, name="VAR", sigma_rel=0.05)
    cfgs = [
        (f"r{i}", IMACConfig(tech=noisy, r_tia=5.0 + i))
        for i in range(3)
    ]
    key = jax.random.PRNGKey(7)
    plain = run_sweep(params, xte, yte, cfgs, n_samples=8, chunk=8,
                      variation_key=key)
    sharded = run_sweep(params, xte, yte, cfgs, n_samples=8, chunk=8,
                        variation_key=key, shard=True)
    _assert_results_equal(plain, sharded)


@multi_device
def test_run_variability_sharded_identical(trained_tiny_mlp):
    from repro.core.devices import custom_tech
    from repro.variability import VariabilitySpec
    from repro.variability.engine import run_variability

    params, xte, yte = trained_tiny_mlp
    cfg = IMACConfig(
        tech=custom_tech(5e3, 1e5, name="VAR", sigma_rel=0.1),
    )
    spec = VariabilitySpec(trials=6, seed=11)
    plain = run_variability(params, xte, yte, cfg, spec, n_samples=8,
                            chunk=8)
    sharded = run_variability(params, xte, yte, cfg, spec, n_samples=8,
                              chunk=8, shard=True)
    assert plain == sharded  # ReliabilityReport NamedTuple equality


@multi_device
def test_per_config_noise_falls_back_sharded_identical(trained_tiny_mlp):
    """Per-trial read-noise draws depend on the full stacked shape, so
    the engine must fall back unsharded — and therefore stay identical."""
    from repro.core.devices import custom_tech
    from repro.variability import VariabilitySpec
    from repro.variability.engine import run_variability

    params, xte, yte = trained_tiny_mlp
    cfg = IMACConfig(
        tech=custom_tech(
            5e3, 1e5, name="VARN", sigma_rel=0.1, read_noise_rel=0.02
        ),
        parasitics=False,
    )
    spec = VariabilitySpec(trials=4, seed=5)
    plain = run_variability(params, xte, yte, cfg, spec, n_samples=8,
                            chunk=8)
    sharded = run_variability(params, xte, yte, cfg, spec, n_samples=8,
                              chunk=8, shard=True)
    assert plain == sharded


# ------------------------------------------------------- cache concurrency


def _fake_result(acc: float) -> IMACResult:
    return IMACResult(
        accuracy=acc, error_rate=1 - acc, avg_power=1e-3, latency=2e-8,
        digital_accuracy=0.97, per_layer_power=(1e-3, 2e-3),
        worst_residual=1e-7, n_samples=16, hp=(13, 4, 3), vp=(4, 3, 1),
    )


def test_cache_concurrent_writers_same_key(tmp_path):
    """Two threads hammering one key: every get sees either a miss or a
    complete entry — never an exception, never a torn read."""
    cache = ResultCache(str(tmp_path / "c"))
    key = "k" * 64
    errors = []

    def hammer(acc):
        try:
            for _ in range(200):
                cache.put(key, _fake_result(acc), name="race")
                got = cache.get(key)
                assert got is not None
                assert got.accuracy in (0.25, 0.75)
        except Exception as e:  # surfaced below; threads swallow asserts
            errors.append(e)

    threads = [
        threading.Thread(target=hammer, args=(a,)) for a in (0.25, 0.75)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    # Last writer won with a complete entry; no temp debris left behind.
    final = cache.get(key)
    assert final is not None and final.n_samples == 16
    assert len(cache) == 1
    assert not [f for f in os.listdir(cache.path) if f.endswith(".tmp")]


def test_cache_tolerates_corrupt_entries(tmp_path):
    cache = ResultCache(str(tmp_path / "c"))
    key = "a" * 64
    # Torn write: invalid JSON.
    with open(cache._file(key), "w") as fh:
        fh.write('{"name": "torn", "resu')
    assert cache.get(key) is None
    # Valid JSON, wrong shape (missing result fields).
    with open(cache._file(key), "w") as fh:
        json.dump({"kind": "imac", "result": {"accuracy": 0.5}}, fh)
    assert cache.get(key) is None
    assert cache.misses == 2 and cache.hits == 0
    # put heals the slot.
    cache.put(key, _fake_result(0.5))
    assert cache.get(key).accuracy == 0.5


def test_cache_corrupt_entry_counts_event(tmp_path):
    obs.enable()
    cache = ResultCache(str(tmp_path / "c"))
    key = "b" * 64
    with open(cache._file(key), "w") as fh:
        fh.write("not json at all")
    assert cache.get(key) is None
    assert len(obs.events("cache_corrupt_entry")) == 1
    assert "cache_corrupt_entry_total" in obs.snapshot()


# ---------------------------------------------------- ledger mesh tagging


def test_mesh_context_scopes_and_restores():
    from repro.obs import ledger

    assert ledger.current_mesh_context() is None
    with ledger.mesh_context("data8"):
        assert ledger.current_mesh_context() == "data8"
        with ledger.mesh_context(None):  # None leaves the tag as-is
            assert ledger.current_mesh_context() == "data8"
    assert ledger.current_mesh_context() is None


def test_ledger_entries_carry_mesh_shape(tmp_path):
    from repro.obs import ledger

    with ledger.mesh_context("data8"):
        entry = ledger.make_entry("sweep", [("row", 1.0, "")])
    assert entry["mesh_shape"] == "data8"
    plain = ledger.make_entry("sweep", [("row", 1.0, "")])
    assert plain["mesh_shape"] is None
    # Env matching: sharded history never gates single-device runs.
    assert ledger.matching([entry], env_of=plain) == []
    assert ledger.matching([entry], env_of=entry) == [entry]
    # Entries predating the key read back as None and keep matching
    # unsharded runs.
    legacy = {k: v for k, v in plain.items() if k != "mesh_shape"}
    assert ledger.matching([legacy], env_of=plain) == [legacy]


def test_sharded_run_sweep_tags_ledger(
    trained_tiny_mlp, tmp_path, monkeypatch
):
    from repro.obs import ledger

    params, xte, yte = trained_tiny_mlp
    path = str(tmp_path / "ledger.jsonl")
    monkeypatch.setenv("REPRO_OBS_LEDGER", path)
    obs.enable()
    run_sweep(
        params, xte, yte,
        [("a", IMACConfig(parasitics=False)),
         ("b", IMACConfig(parasitics=False, r_tia=7.0))],
        n_samples=8, chunk=8, shard=MeshPlan(devices=1),
    )
    run_sweep(
        params, xte, yte, [("a", IMACConfig(parasitics=False))],
        n_samples=8, chunk=8,
    )
    entries = ledger.load(path)
    assert len(entries) == 2
    assert entries[0]["mesh_shape"] == "data1"
    assert "mesh=data1" in entries[0]["rows"][0]["derived"]
    assert entries[1]["mesh_shape"] is None
    # The throughput gauge rode along in the embedded snapshot.
    series = entries[0]["metrics"]["sweep_points_per_s"]["series"]
    assert series[0]["value"] > 0
