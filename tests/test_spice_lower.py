"""Lowering tests: parsed netlists -> MNA structures (repro.spice.lower).

The tight (1e-6-relative) parser-vs-dense-MNA comparisons run under
`jax.experimental.enable_x64`: ideal crossbars lower with R_WIRE_EPS
wire segments (1e-6 ohm), which makes the float32 dense solve
ill-conditioned while the float64 one is exact to round-off.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.devices import MRAM
from repro.core.imac import IMACConfig, build_plans
from repro.core.mapping import map_network
from repro.core.netlist import map_imac
from repro.core.solver import CircuitParams, Stamps, solve_crossbar, solve_dense_mna
from repro.spice import (
    NonCrossbarError,
    UnsupportedElementError,
    flatten,
    lower,
    lower_crossbar,
    lower_network,
    parse_netlist,
    solve_dc,
)
from repro.spice.lower import R_WIRE_EPS


# ---------------------------------------------------------------------------
# Netlist builders (plain text, third-party style names).
# ---------------------------------------------------------------------------


def ideal_crossbar(g, v, r_source=100.0, r_tia=10.0):
    """Bare crossbar: driver -> Rsource -> row node; devices bridge row
    nodes straight to column nodes; TIA to ground."""
    m, n = g.shape
    lines = ["* ideal crossbar"]
    for i in range(m):
        lines.append(f"Vdrive{i} d{i} 0 DC {v[i]}")
        lines.append(f"Rsrc{i} d{i} row{i} {r_source}")
    for i in range(m):
        for j in range(n):
            if g[i, j] > 0:
                lines.append(f"Rdev{i}_{j} row{i} col{j} {1.0 / g[i, j]}")
    for j in range(n):
        lines.append(f"Rtia{j} col{j} 0 {r_tia}")
    return "\n".join(lines) + "\n"


def wired_crossbar(
    g, v, r_source=100.0, r_tia=10.0, r_row=13.8, r_col=13.8,
    c_row=None, c_col=None, pwl_rows=(),
):
    """Crossbar with explicit uniform wire chains (one node per (i,j))."""
    m, n = g.shape
    lines = ["* wired crossbar"]
    for i in range(m):
        if i in pwl_rows:
            lines.append(f"Vdrive{i} d{i} 0 PWL(0 0 1e-09 {v[i]} 2e-08 {v[i]})")
        else:
            lines.append(f"Vdrive{i} d{i} 0 DC {v[i]}")
        lines.append(f"Rsrc{i} d{i} r{i}_0 {r_source}")
        for j in range(1, n):
            lines.append(f"Rrw{i}_{j} r{i}_{j - 1} r{i}_{j} {r_row}")
    for i in range(m):
        for j in range(n):
            if g[i, j] > 0:
                lines.append(f"Rdev{i}_{j} r{i}_{j} c{i}_{j} {1.0 / g[i, j]}")
            if c_row is not None and c_row[i, j] > 0:
                lines.append(f"Crow{i}_{j} r{i}_{j} 0 {c_row[i, j]}")
            if c_col is not None and c_col[i, j] > 0:
                lines.append(f"Ccol{i}_{j} c{i}_{j} 0 {c_col[i, j]}")
    for j in range(n):
        for i in range(1, m):
            lines.append(f"Rcw{i}_{j} c{i - 1}_{j} c{i}_{j} {r_col}")
        lines.append(f"Rtia{j} c{m - 1}_{j} 0 {r_tia}")
    return "\n".join(lines) + "\n"


def demo_g(m, n, seed=0):
    rng = np.random.default_rng(seed)
    r = rng.uniform(1.0 / MRAM.g_on, 1.0 / MRAM.g_off, size=(m, n))
    return 1.0 / r


# ---------------------------------------------------------------------------
# flatten
# ---------------------------------------------------------------------------


def test_flatten_inlines_instances():
    circ = parse_netlist(
        "* t\n.SUBCKT cell a b\nRx a mid 1000\nRy mid b 2000\n.ENDS\n"
        "Xc1 in GND cell\nV1 in 0 DC 1\n"
    )
    flat = flatten(circ)
    names = {c.name for c in flat.cards if hasattr(c, "name")}
    assert "Rx@Xc1" in names and "Ry@Xc1" in names
    rx = next(c for c in flat.cards if getattr(c, "name", "") == "Rx@Xc1")
    assert rx.n1 == "in" and rx.n2 == "Xc1.mid"  # internal node scoped
    ry = next(c for c in flat.cards if getattr(c, "name", "") == "Ry@Xc1")
    # Outer connection names pass through verbatim; ground aliases are
    # resolved at solve time (see test_solve_dc_ground_aliases).
    assert ry.n2 == "GND"
    assert not flat.subckts


def test_flatten_nested_instances():
    circ = parse_netlist(
        "* t\n.SUBCKT inner p\nRi p 0 1000\n.ENDS\n"
        ".SUBCKT outer q\nXo q inner\n.ENDS\n"
        "Xtop n1 outer\n"
    )
    flat = flatten(circ)
    (ri,) = [c for c in flat.cards if getattr(c, "name", "").startswith("Ri")]
    assert ri.name == "Ri@Xtop.Xo" and ri.n1 == "n1"


def test_flatten_errors():
    from repro.spice import ParseError

    with pytest.raises(ParseError, match="undefined subckt"):
        flatten(parse_netlist("* t\nXa n1 n2 nosuch\n"))
    with pytest.raises(ParseError, match="nodes for"):
        flatten(
            parse_netlist(
                "* t\n.SUBCKT one p\nR1 p 0 1\n.ENDS\nXa n1 n2 one\n"
            )
        )
    with pytest.raises(UnsupportedElementError, match="behavioural"):
        flatten(
            parse_netlist(
                "* t\n.SUBCKT act p q\nE1 q 0 VALUE={v(p)*2}\n.ENDS\n"
                "Xa a b act\n"
            )
        )


# ---------------------------------------------------------------------------
# solve_dc — the generic linear oracle
# ---------------------------------------------------------------------------


def test_solve_dc_divider():
    op = solve_dc(
        parse_netlist("* t\nV1 in 0 DC 1\nR1 in mid 1000\nR2 mid 0 3000\n")
    )
    assert op.voltages["mid"] == pytest.approx(0.75, rel=1e-12)
    assert op.voltages["in"] == pytest.approx(1.0)
    assert op.voltages["0"] == 0.0
    # Branch current convention: + terminal through the source. The
    # source supplies 0.25 mA, so the MNA branch current is negative.
    assert op.currents["V1"] == pytest.approx(-0.25e-3, rel=1e-9)


def test_solve_dc_isource_and_pwl():
    op = solve_dc(
        parse_netlist(
            "* t\nI1 a 0 DC 1m\nR1 a 0 1000\n"
            "V2 b 0 PWL(0 0 1e-09 0.5)\nR2 b 0 500\n"
        )
    )
    # I flows a -> 0 through the source: it is pulled out of node a.
    assert op.voltages["a"] == pytest.approx(-1.0, rel=1e-9)
    assert op.voltages["b"] == pytest.approx(0.5)  # PWL settles at 0.5


def test_solve_dc_caps_open():
    op = solve_dc(
        parse_netlist(
            "* t\nV1 in 0 DC 1\nR1 in mid 1000\nR2 mid 0 1000\n"
            "Cl mid 0 1e-12\n"
        )
    )
    assert op.voltages["mid"] == pytest.approx(0.5, rel=1e-12)


def test_solve_dc_flattens_subckts():
    op = solve_dc(
        parse_netlist(
            "* t\n.SUBCKT div a b\nR1 a m 1000\nR2 m b 1000\n.ENDS\n"
            "Xd in 0 div\nV1 in 0 DC 2\n"
        )
    )
    assert op.voltages["Xd.m"] == pytest.approx(1.0, rel=1e-12)


def test_solve_dc_rejections():
    with pytest.raises(UnsupportedElementError, match="behavioural"):
        solve_dc(parse_netlist("* t\nE1 o 0 VALUE={1+1}\nR1 o 0 1\n"))
    with pytest.raises(UnsupportedElementError, match="non-positive"):
        solve_dc(parse_netlist("* t\nV1 a 0 DC 1\nR1 a 0 0\n"))
    with pytest.raises(UnsupportedElementError, match="singular"):
        solve_dc(parse_netlist("* t\nR1 a b 1000\nR2 b a 1000\n"))


def test_solve_dc_ground_aliases():
    op = solve_dc(
        parse_netlist("* t\nV1 in gnd DC 1\nR1 in mid 1000\nR2 mid VSS! 1000\n")
    )
    assert op.voltages["mid"] == pytest.approx(0.5, rel=1e-12)


# ---------------------------------------------------------------------------
# lower_crossbar — structural recognition
# ---------------------------------------------------------------------------


def test_lower_ideal_crossbar_recovers_structure():
    g = demo_g(3, 2)
    v = np.array([0.3, 0.5, 0.8])
    xb = lower_crossbar(parse_netlist(ideal_crossbar(g, v)))
    assert xb.shape == (3, 2)
    np.testing.assert_allclose(xb.g, g, rtol=1e-9)
    np.testing.assert_allclose(xb.v_in, v, rtol=1e-12)
    assert xb.r_source == 100.0 and xb.r_tia == 10.0
    assert xb.r_row == xb.r_col == R_WIRE_EPS


def test_lower_ideal_crossbar_matches_generic_solve():
    """Acceptance: dense MNA on the lowered structure == the generic
    nodal solve of the netlist, node by node (x64; the eps wires bound
    the agreement at ~1e-7 relative)."""
    g = demo_g(3, 2, seed=1)
    v = np.array([0.3, 0.5, 0.8])
    circ = parse_netlist(ideal_crossbar(g, v))
    xb = lower_crossbar(circ)
    op = solve_dc(circ)
    with jax.experimental.enable_x64():
        got = xb.node_voltages(xb.solve_dense())
    for node, want in op.voltages.items():
        if node in got:
            assert got[node] == pytest.approx(want, rel=1e-5, abs=1e-9), node


def test_lower_wired_crossbar_matches_generic_solve():
    """Wire-grid form: float64 agreement is essentially exact (1e-9)."""
    g = demo_g(3, 4, seed=2)
    v = np.array([0.1, 0.7, 0.4])
    circ = parse_netlist(wired_crossbar(g, v))
    xb = lower_crossbar(circ)
    np.testing.assert_allclose(xb.g, g, rtol=1e-9)
    assert xb.r_row == 13.8 and xb.r_col == 13.8
    op = solve_dc(circ)
    with jax.experimental.enable_x64():
        got = xb.node_voltages(xb.solve_dense())
    for node, want in got.items():
        assert want == pytest.approx(op.voltages[node], rel=1e-9, abs=1e-15)


def test_lower_wired_crossbar_backend_agreement():
    """The production iterative solve on the lowered tile matches the
    dense oracle at float32 tolerances."""
    g = demo_g(4, 4, seed=3)
    v = np.array([0.2, 0.8, 0.5, 0.3])
    xb = lower_crossbar(parse_netlist(wired_crossbar(g, v)))
    dense = xb.solve_dense(gs_iters=96)
    fast = xb.solve(gs_iters=96)
    np.testing.assert_allclose(
        np.asarray(fast.i_out), np.asarray(dense.i_out), rtol=1e-3
    )


def test_lower_crossbar_ammeter_merge():
    """0 V sources in series (SPICE ammeters) are merged as shorts."""
    g = demo_g(2, 2, seed=4)
    v = np.array([0.4, 0.6])
    text = wired_crossbar(g, v)
    # Splice an ammeter between the column foot and its TIA.
    text = text.replace(
        "Rtia0 c1_0 0 10.0", "Vsense0 c1_0 foot0 DC 0\nRtia0 foot0 0 10.0"
    )
    xb = lower_crossbar(parse_netlist(text))
    np.testing.assert_allclose(xb.g, g, rtol=1e-9)


def test_lower_crossbar_pwl_and_reversed_driver():
    g = demo_g(2, 2, seed=5)
    v = np.array([0.25, 0.5])
    text = wired_crossbar(g, v, pwl_rows=(0,))
    # Reverse row 1's driver: V 0 node DC -v is the same drive.
    text = text.replace("Vdrive1 d1 0 DC 0.5", "Vdrive1 0 d1 DC -0.5")
    xb = lower_crossbar(parse_netlist(text))
    np.testing.assert_allclose(xb.v_in, v, rtol=1e-12)
    assert 0 in xb.pwl and xb.pwl[0][-1] == (2e-8, 0.25)
    assert 1 not in xb.pwl


def test_lower_crossbar_caps():
    g = demo_g(2, 3, seed=6)
    v = np.array([0.3, 0.6])
    c_row = np.full_like(g, 1e-15)
    c_col = np.full_like(g, 2e-15)
    xb = lower_crossbar(
        parse_netlist(wired_crossbar(g, v, c_row=c_row, c_col=c_col))
    )
    np.testing.assert_allclose(xb.c_row, c_row)
    np.testing.assert_allclose(xb.c_col, c_col)


def test_lower_crossbar_floating_cap_rejected():
    g = demo_g(2, 2, seed=7)
    text = wired_crossbar(g, np.array([0.1, 0.2]))
    text += "Cfloat r0_0 c1_1 1e-15\n"
    with pytest.raises(NonCrossbarError, match="floats between"):
        lower_crossbar(parse_netlist(text))


@pytest.mark.parametrize(
    "mutate,msg",
    [
        (lambda t: t + "I1 r0_0 0 DC 1m\n", "current source"),
        (lambda t: t + "Vf r0_0 c1_1 DC 0.2\n", "floats between"),
        (lambda t: t + "Vdup d0 0 DC 0.9\n", "drive the same node"),
        (
            lambda t: t.replace("Rtia0 c1_0 0 10.0", "Rhalf c1_0 0 10.0\n"
                                "Rtia0 c1_0 0 10.0"),
            "share a column foot",
        ),
        (
            lambda t: t.replace("Rtia1 c1_1 0 10.0", "Rtia1 c1_1 0 22.0"),
            "must be uniform",
        ),
        (lambda t: t + "Rextra d0 r0_1 50\n", "resistor connections"),
    ],
)
def test_lower_crossbar_diagnostics(mutate, msg):
    g = demo_g(2, 2, seed=8)
    text = mutate(wired_crossbar(g, np.array([0.3, 0.4])))
    with pytest.raises(NonCrossbarError, match=msg):
        lower_crossbar(parse_netlist(text))


def test_lower_crossbar_no_tia():
    text = "* t\nV0 d0 0 DC 1\nRs d0 row0 100\nRd row0 col0 10000\n"
    with pytest.raises(NonCrossbarError, match="TIA"):
        lower_crossbar(parse_netlist(text))


def test_lower_crossbar_duplicate_device():
    g = demo_g(2, 2, seed=9)
    text = ideal_crossbar(g, np.array([0.5, 0.5]))
    text += "Rdup row0 col1 5000\n"
    with pytest.raises(NonCrossbarError, match="two devices bridge"):
        lower_crossbar(parse_netlist(text))


def test_lower_crossbar_behavioral_rejected():
    text = "* t\nE1 out 0 VALUE={v(a)}\nV1 a 0 DC 1\nR1 a 0 100\n"
    with pytest.raises(NonCrossbarError, match="behavioural"):
        lower_crossbar(parse_netlist(text))


# ---------------------------------------------------------------------------
# lower_network — generated netlists back to engine structures
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def gen_net():
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    params = [
        (jax.random.normal(k1, (6, 4)), jnp.zeros((4,))),
        (jax.random.normal(k2, (4, 3)), jnp.zeros((3,))),
    ]
    cfg = IMACConfig(tech="MRAM", array_rows=4, array_cols=4)
    mapped = map_network(params, MRAM, v_unit=cfg.vdd)
    plans = build_plans([6, 4, 3], cfg)
    return cfg, mapped, plans


def test_lower_network_recovers_conductances(gen_net):
    cfg, mapped, plans = gen_net
    sample = np.linspace(0.0, 1.0, 6)
    files = map_imac(mapped, plans, cfg, sample=sample)
    net = lower_network(files)
    assert net.topology == [6, 4, 3]
    assert net.v_unit == pytest.approx(cfg.vdd)
    for la, mp in zip(net.layers, mapped):
        np.testing.assert_allclose(la.g_pos, np.asarray(mp.g_pos), rtol=1e-5)
        np.testing.assert_allclose(la.g_neg, np.asarray(mp.g_neg), rtol=1e-5)
    np.testing.assert_allclose(net.sample, sample, atol=2e-6)
    assert net.tran is not None and not net.has_pwl


def test_lower_network_to_mapped_and_config(gen_net):
    cfg, mapped, plans = gen_net
    files = map_imac(mapped, plans, cfg)
    net = lower_network(files)
    for got, want in zip(net.to_mapped(), mapped):
        assert got.sense_r == pytest.approx(want.sense_r, rel=1e-4)
        assert got.k == pytest.approx(want.k, rel=1e-4)
    cfg2 = net.to_config()
    assert (cfg2.array_rows, cfg2.array_cols) == (4, 4)
    assert cfg2.hp == [la.plan.hp for la in net.layers]
    assert cfg2.vp == [la.plan.vp for la in net.layers]
    assert cfg2.r_source == pytest.approx(cfg.r_source)
    assert cfg2.r_tia == pytest.approx(cfg.r_tia)
    assert cfg2.interconnect.r_segment == pytest.approx(
        cfg.interconnect.r_segment, rel=1e-4
    )
    assert cfg2.transient is None  # DC deck: .TRAN header but no PWL


def test_lower_network_transient_roundtrip(gen_net):
    from repro.transient.spec import TransientSpec

    cfg, mapped, plans = gen_net
    spec = TransientSpec(t_stop=2e-9, n_steps=8, method="trap")
    files = map_imac(mapped, plans, cfg, transient=spec)
    net = lower_network(files)
    assert net.has_pwl and net.method == "trap"
    got = net.to_config().transient
    assert got is not None
    assert got.t_stop == pytest.approx(spec.t_stop, rel=1e-5)
    assert got.n_steps == spec.n_steps
    assert got.method == "trap"
    assert got.t_rise == pytest.approx(spec.resolved_t_rise(), rel=1e-5)


def test_lower_network_missing_bias(gen_net):
    cfg, mapped, plans = gen_net
    files = dict(map_imac(mapped, plans, cfg))
    files["imac_main.sp"] = "\n".join(
        ln
        for ln in files["imac_main.sp"].splitlines()
        if not ln.startswith("Vbias_")
    ) + "\n"
    with pytest.raises(NonCrossbarError, match="Vbias"):
        lower_network(files)


def test_lower_dispatch(gen_net):
    cfg, mapped, plans = gen_net
    from repro.spice import LoweredCrossbar, LoweredNetwork

    assert isinstance(lower(map_imac(mapped, plans, cfg)), LoweredNetwork)
    g = demo_g(2, 2)
    flat = {"tile.sp": wired_crossbar(g, np.array([0.2, 0.4]))}
    assert isinstance(lower(flat), LoweredCrossbar)


def test_evaluate_netlist_matches_direct_eval(gen_net):
    from repro.core.evaluate import evaluate_batch, evaluate_netlist

    cfg, mapped, plans = gen_net
    files = map_imac(mapped, plans, cfg)
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.uniform(0.0, 1.0, size=(16, 6)), dtype=jnp.float32)
    y = jnp.asarray(rng.integers(0, 3, size=(16,)))
    result, net = evaluate_netlist(files, x, y)
    params = [
        (jnp.asarray(w), jnp.asarray(b)) for w, b in net.to_params()
    ]
    want = evaluate_batch(
        params, x, y, [net.to_config()], mapped=[net.to_mapped()]
    )[0]
    assert result.accuracy == pytest.approx(want.accuracy, abs=1e-9)
    assert result.avg_power == pytest.approx(want.avg_power, rel=1e-6)
    assert result.latency_source == "analytic"


# ---------------------------------------------------------------------------
# solve_dense_mna with companion stamps (transient oracle support)
# ---------------------------------------------------------------------------


def test_dense_mna_accepts_stamps():
    g = jnp.asarray(demo_g(3, 3, seed=12))
    v = jnp.asarray(np.array([0.2, 0.5, 0.8]), dtype=jnp.float32)
    cp = CircuitParams(gs_iters=96, tol=0.0)
    shunt = jnp.full((3, 3), 1e-4)
    inj = jnp.full((3, 3), 1e-6)
    stamps = Stamps(
        g_shunt_row=shunt, g_shunt_col=shunt, i_inj_row=inj, i_inj_col=inj
    )
    dense = solve_dense_mna(g, v, cp, stamps=stamps)
    fast = solve_crossbar(g, v, cp, stamps=stamps)
    np.testing.assert_allclose(
        np.asarray(fast.vc), np.asarray(dense.vc), rtol=1e-3, atol=1e-6
    )
    # The stamps must actually perturb the solution.
    plain = solve_dense_mna(g, v, cp)
    assert not np.allclose(np.asarray(plain.vc), np.asarray(dense.vc))
