"""Chunk-parallel WKV vs the step recurrence (property-based)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.rwkv6 import WKV_CHUNK, _wkv_chunked, _wkv_scan


def _make(key, b, t, h, d, extreme=False):
    ks = jax.random.split(key, 6)
    r = jax.random.normal(ks[0], (b, t, h, d))
    k = jax.random.normal(ks[1], (b, t, h, d))
    v = jax.random.normal(ks[2], (b, t, h, d))
    logw = -jnp.exp(jax.random.normal(ks[3], (b, t, h, d)))
    if extreme:
        mask = jax.random.uniform(ks[4], (b, t, h, d)) < 0.3
        logw = jnp.where(mask, -50.0, logw)
    u = 0.5 * jax.random.normal(ks[5], (h, d))
    s0 = jax.random.normal(jax.random.PRNGKey(99), (b, h, d, d))
    return r, k, v, logw, u, s0


@pytest.mark.parametrize("t", [WKV_CHUNK * 2, WKV_CHUNK * 4])
@pytest.mark.parametrize("extreme", [False, True])
def test_chunked_matches_scan(t, extreme):
    r, k, v, logw, u, s0 = _make(jax.random.PRNGKey(0), 2, t, 4, 8, extreme)
    o1, s1 = _wkv_scan(r, k, v, jnp.exp(logw), u, s0)
    o2, s2 = _wkv_chunked(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4, rtol=1e-3)
    assert bool(jnp.all(jnp.isfinite(o2)))


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.integers(2, 12), st.integers(0, 2**31 - 1))
def test_chunked_property(b, h, d, seed):
    t = WKV_CHUNK * 2
    r, k, v, logw, u, s0 = _make(jax.random.PRNGKey(seed), b, t, h, d)
    o1, s1 = _wkv_scan(r, k, v, jnp.exp(logw), u, s0)
    o2, s2 = _wkv_chunked(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=5e-4, rtol=5e-3)


def test_gradients_flow():
    r, k, v, logw, u, s0 = _make(jax.random.PRNGKey(1), 1, WKV_CHUNK * 2, 2, 4)

    def loss_chunk(r):
        return jnp.sum(_wkv_chunked(r, k, v, logw, u, s0)[0] ** 2)

    def loss_scan(r):
        return jnp.sum(_wkv_scan(r, k, v, jnp.exp(logw), u, s0)[0] ** 2)

    g1 = jax.grad(loss_chunk)(r)
    g2 = jax.grad(loss_scan)(r)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-3, rtol=1e-2)
    assert bool(jnp.all(jnp.isfinite(g1)))
