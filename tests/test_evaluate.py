import pytest

from repro.core import IMACConfig
from repro.core.evaluate import test_imac as imac_eval  # alias: pytest must not collect it
from repro.core.evaluate import sweep


def test_testimac_end_to_end(trained_tiny_mlp):
    params, xte, yte = trained_tiny_mlp
    cfg = IMACConfig(tech="PCM", array_rows=32, array_cols=32)
    res = imac_eval(params, xte, yte, cfg, n_samples=60, chunk=30)
    assert res.n_samples == 60
    assert res.digital_accuracy > 0.9
    assert res.accuracy > 0.8  # PCM, small tiles: near-digital
    assert res.avg_power > 0
    assert res.latency >= cfg.t_sampling
    assert res.worst_residual < 1e-3
    assert len(res.per_layer_power) == 3
    assert res.error_rate == pytest.approx(1.0 - res.accuracy)


def test_partitioning_arithmetic_in_result(trained_tiny_mlp):
    params, xte, yte = trained_tiny_mlp
    cfg = IMACConfig(tech="PCM", array_rows=64, array_cols=64)
    res = imac_eval(params, xte, yte, cfg, n_samples=20, chunk=20)
    # topology [400, 48, 24, 10] on 64x64 arrays: ceil(401/64)=7, etc.
    assert res.hp == (7, 1, 1)
    assert res.vp == (1, 1, 1)


def test_large_array_accuracy_collapse(trained_tiny_mlp):
    """Paper Table III: monolithic large arrays collapse to ~chance."""
    params, xte, yte = trained_tiny_mlp
    good = imac_eval(
        params, xte, yte,
        IMACConfig(tech="MRAM", array_rows=32, array_cols=32),
        n_samples=40, chunk=20,
    )
    bad = imac_eval(
        params, xte, yte,
        IMACConfig(tech="MRAM", hp=[1, 1, 1], vp=[1, 1, 1]),
        n_samples=40, chunk=20,
    )
    assert good.accuracy > bad.accuracy
    assert bad.accuracy < 0.5  # collapsed
    assert bad.avg_power < good.avg_power  # voltages collapse => less power


def test_sweep_api(trained_tiny_mlp):
    params, xte, yte = trained_tiny_mlp
    cfgs = [
        ("pcm32", IMACConfig(tech="PCM", array_rows=32, array_cols=32)),
        ("mram32", IMACConfig(tech="MRAM", array_rows=32, array_cols=32)),
    ]
    out = sweep(params, xte, yte, cfgs, n_samples=20, chunk=20)
    assert [name for name, _ in out] == ["pcm32", "mram32"]
    # PCM (high R) dissipates less than MRAM (low R) — Table IV trend.
    assert out[0][1].avg_power < out[1][1].avg_power
