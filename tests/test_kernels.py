"""Per-kernel shape/dtype sweeps, interpret=True vs the ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.imac_mvm.ops import analog_linear, imac_mvm
from repro.kernels.imac_mvm.ref import imac_mvm_ref
from repro.kernels.tridiag.ops import tridiag
from repro.kernels.tridiag.ref import tridiag_ref

# ----------------------------------------------------------------- tridiag


@pytest.mark.parametrize("shape", [(1, 4), (7, 16), (3, 5, 33), (260, 8), (2, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_tridiag_shapes(shape, dtype):
    key = jax.random.PRNGKey(sum(shape))
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = (2.0 + jax.random.uniform(k1, shape)).astype(dtype)
    dl = (-jax.random.uniform(k2, shape)).astype(dtype)
    du = (-jax.random.uniform(k3, shape)).astype(dtype)
    b = jax.random.normal(k4, shape).astype(dtype)
    x = tridiag(dl, d, du, b, interpret=True)
    want = tridiag_ref(dl, d, du, b)
    np.testing.assert_allclose(np.asarray(x), np.asarray(want), rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 40), st.integers(1, 40))
def test_tridiag_property_random_dd_systems(n, batch):
    """Diagonally-dominant systems: kernel == oracle == dense solve."""
    key = jax.random.PRNGKey(n * 131 + batch)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    shape = (batch, n)
    dl = -jax.random.uniform(k1, shape)
    du = -jax.random.uniform(k2, shape)
    d = 2.2 + jax.random.uniform(k3, shape)
    b = jax.random.normal(k4, shape)
    x = tridiag(dl, d, du, b, interpret=True)
    # Verify against the dense system directly (independent oracle).
    for bi in range(min(batch, 3)):
        a = np.diag(np.asarray(d[bi]))
        a += np.diag(np.asarray(dl[bi, 1:]), -1)
        a += np.diag(np.asarray(du[bi, :-1]), 1)
        want = np.linalg.solve(a, np.asarray(b[bi]))
        np.testing.assert_allclose(np.asarray(x[bi]), want, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------- imac_mvm


@pytest.mark.parametrize(
    "m,k,n", [(1, 1, 1), (4, 7, 5), (130, 257, 96), (128, 128, 128), (256, 64, 33)]
)
def test_imac_mvm_shapes(m, k, n):
    x = jax.random.uniform(jax.random.PRNGKey(1), (m, k))
    w = jax.random.uniform(jax.random.PRNGKey(2), (k, n), minval=-1, maxval=1)
    got = imac_mvm(x, w, dac_bits=8, levels=16, interpret=True)
    want = imac_mvm_ref(x, w, dac_bits=8, levels=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("dac_bits,levels", [(0, 1), (4, 4), (8, 16), (2, 32)])
def test_imac_mvm_quantization_params(dac_bits, levels):
    x = jax.random.uniform(jax.random.PRNGKey(3), (17, 40))
    w = jax.random.uniform(jax.random.PRNGKey(4), (40, 9), minval=-1, maxval=1)
    got = imac_mvm(x, w, dac_bits=dac_bits, levels=levels, interpret=True)
    want = imac_mvm_ref(x, w, dac_bits=dac_bits, levels=levels)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_imac_mvm_batch_dims():
    x = jax.random.uniform(jax.random.PRNGKey(5), (3, 5, 20))
    w = jax.random.uniform(jax.random.PRNGKey(6), (20, 8), minval=-1, maxval=1)
    got = imac_mvm(x, w, interpret=True)
    assert got.shape == (3, 5, 8)
    want = imac_mvm_ref(x.reshape(15, 20), w).reshape(3, 5, 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_analog_linear_approximates_digital():
    """High-resolution analog linear ≈ digital y = xW + b."""
    x = jax.random.normal(jax.random.PRNGKey(7), (6, 32))
    w = 0.3 * jax.random.normal(jax.random.PRNGKey(8), (32, 16))
    b = 0.1 * jax.random.normal(jax.random.PRNGKey(9), (16,))
    y = analog_linear(x, w, b, tech="PCM", dac_bits=12, levels=256, interpret=True)
    want = x @ w + b
    err = float(jnp.max(jnp.abs(y - want)) / (jnp.max(jnp.abs(want)) + 1e-9))
    assert err < 0.05, err


# --------------------------------------------------------- decode_attention


@pytest.mark.parametrize(
    "b,h,hkv,s,dk,dv",
    [
        (2, 8, 2, 256, 64, 64),     # GQA
        (1, 16, 1, 512, 128, 96),   # MQA, Dk != Dv (MLA-style)
        (2, 4, 4, 128, 64, 64),     # MHA
        (1, 4, 2, 640, 128, 128),   # non-power-of-two S
    ],
)
def test_decode_attention_shapes(b, h, hkv, s, dk, dv):
    kq, kk, kv, kl = jax.random.split(jax.random.PRNGKey(b * 100 + s), 4)
    q = jax.random.normal(kq, (b, h, dk), jnp.float32)
    k = jax.random.normal(kk, (b, s, hkv, dk), jnp.float32)
    v = jax.random.normal(kv, (b, s, hkv, dv), jnp.float32)
    lens = jax.random.randint(kl, (b,), 1, s + 1)
    got = decode_attention(q, k, v, lens, bs=128, interpret=True)
    want = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_dtypes(dtype):
    b, h, hkv, s, d = 2, 8, 4, 256, 64
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (b, h, d)).astype(dtype)
    k = jax.random.normal(kk, (b, s, hkv, d)).astype(dtype)
    v = jax.random.normal(kv, (b, s, hkv, d)).astype(dtype)
    got = decode_attention(q, k, v, bs=128, interpret=True)
    want = decode_attention_ref(q, k, v)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_decode_attention_full_cache_equals_no_mask():
    b, h, hkv, s, d = 1, 4, 2, 256, 64
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(kq, (b, h, d))
    k = jax.random.normal(kk, (b, s, hkv, d))
    v = jax.random.normal(kv, (b, s, hkv, d))
    a = decode_attention(q, k, v, jnp.array([s]), bs=64, interpret=True)
    c = decode_attention(q, k, v, None, bs=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-5)
