"""repro.explore: spec materialization, batched-vs-loop equivalence,
Pareto extraction, and on-disk memoization."""
import dataclasses

import numpy as np
import pytest

from repro.core import IMACConfig
from repro.core.evaluate import evaluate_batch, structure_key, sweep
from repro.explore import (
    ResultCache,
    SweepSpec,
    pareto_front,
    pareto_mask,
    run_sweep,
)

TOPOLOGY = [400, 48, 24, 10]


# ------------------------------------------------------------------ spec


def test_grid_spec_materializes_cross_product():
    spec = SweepSpec.grid(
        IMACConfig(), tech=["MRAM", "PCM"], array_size=[32, 64]
    )
    points = spec.materialize()
    assert spec.n_points == len(points) == 4
    names = [n for n, _ in points]
    assert names[0] == "tech=MRAM,array_size=32"
    cfg = dict(points)["tech=PCM,array_size=64"]
    assert cfg.tech == "PCM"
    assert (cfg.array_rows, cfg.array_cols) == (64, 64)


def test_partition_axis_and_scalar_axis():
    spec = SweepSpec.grid(
        IMACConfig(),
        partition=[([13, 4, 3], [4, 3, 1]), ([16, 8, 8], [8, 8, 1])],
        r_tia=[5.0, 10.0],
    )
    points = spec.materialize()
    assert len(points) == 4
    cfg = points[0][1]
    assert cfg.hp == [13, 4, 3] and cfg.vp == [4, 3, 1]
    assert cfg.r_tia == 5.0


def test_random_spec_is_seeded_and_sized():
    spec = SweepSpec.random(
        IMACConfig(), samples=7, seed=3, tech=["MRAM", "RRAM", "PCM"],
        array_size=[32, 64, 128],
    )
    a = spec.materialize()
    b = spec.materialize()
    assert len(a) == 7
    assert [n for n, _ in a] == [n for n, _ in b]  # deterministic


def test_unknown_axis_raises():
    with pytest.raises(ValueError, match="unknown sweep axis"):
        SweepSpec.grid(IMACConfig(), not_a_field=[1]).materialize()


# -------------------------------------------------------- structure_key


def test_structure_key_groups_techs_not_sizes():
    k_mram = structure_key(TOPOLOGY, IMACConfig(tech="MRAM"))
    k_pcm = structure_key(TOPOLOGY, IMACConfig(tech="PCM"))
    k_big = structure_key(TOPOLOGY, IMACConfig(tech="MRAM", array_rows=64,
                                               array_cols=64))
    assert k_mram == k_pcm
    assert k_mram != k_big


def test_evaluate_batch_rejects_incompatible(trained_tiny_mlp):
    params, xte, yte = trained_tiny_mlp
    with pytest.raises(ValueError, match="structurally-compatible"):
        evaluate_batch(
            params, xte, yte,
            [IMACConfig(array_rows=32, array_cols=32),
             IMACConfig(array_rows=64, array_cols=64)],
            n_samples=4, chunk=4,
        )


# ------------------------------------------- batched vs per-config loop


def test_batched_matches_per_config_loop_2x2(trained_tiny_mlp):
    """The tentpole equivalence: a 2x2 (tech x array size) grid evaluated
    by the engine matches the per-config loop."""
    params, xte, yte = trained_tiny_mlp
    spec = SweepSpec.grid(
        IMACConfig(), tech=["MRAM", "PCM"], array_size=[32, 64]
    )
    items = spec.materialize()
    loop = sweep(params, xte, yte, items, n_samples=16, chunk=16)
    batched = run_sweep(params, xte, yte, spec, n_samples=16, chunk=16)
    assert [r.name for r in batched] == [n for n, _ in loop]
    for (_, want), got in zip(loop, batched):
        assert got.result.accuracy == pytest.approx(want.accuracy, abs=1e-12)
        assert got.result.avg_power == pytest.approx(want.avg_power, rel=1e-5)
        assert got.result.latency == pytest.approx(want.latency, rel=1e-6)
        np.testing.assert_allclose(
            got.result.per_layer_power, want.per_layer_power, rtol=1e-5
        )
        assert got.result.hp == want.hp and got.result.vp == want.vp


def test_batched_matches_loop_ideal_path(trained_tiny_mlp):
    """parasitics=False (ideal MVM) exercise of the same stacking."""
    params, xte, yte = trained_tiny_mlp
    cfgs = [
        (t, IMACConfig(tech=t, parasitics=False))
        for t in ("MRAM", "RRAM", "CBRAM", "PCM")
    ]
    loop = sweep(params, xte, yte, cfgs, n_samples=64, chunk=32)
    batched = run_sweep(params, xte, yte, cfgs, n_samples=64, chunk=32)
    for (_, want), got in zip(loop, batched):
        assert got.result.accuracy == pytest.approx(want.accuracy, abs=1e-12)
        assert got.result.avg_power == pytest.approx(want.avg_power, rel=1e-5)


# ----------------------------------------------------------------- pareto


def test_pareto_mask_hand_built_frontier():
    # (maximize, minimize): the frontier is the upper-left staircase.
    pts = np.array([
        [1.0, 1.0],   # front
        [0.9, 0.5],   # front
        [0.9, 0.9],   # dominated by (0.9, 0.5)
        [0.5, 0.2],   # front
        [0.4, 0.3],   # dominated by (0.5, 0.2)
        [1.0, 2.0],   # dominated by (1.0, 1.0)
    ])
    mask = pareto_mask(pts, maximize=[True, False])
    np.testing.assert_array_equal(
        mask, [True, True, False, True, False, False]
    )


def test_pareto_mask_keeps_duplicates():
    pts = np.array([[1.0, 1.0], [1.0, 1.0], [0.5, 2.0]])
    mask = pareto_mask(pts, maximize=[True, False])
    np.testing.assert_array_equal(mask, [True, True, False])


def test_pareto_front_on_results():
    class R:
        def __init__(self, acc, p, lat):
            self.accuracy, self.avg_power, self.latency = acc, p, lat

    results = [
        R(0.95, 1.0, 2e-8),   # dominated by r2 (same acc, cheaper)
        R(0.95, 0.5, 2e-8),   # front
        R(0.99, 2.0, 2e-8),   # front (best accuracy)
        R(0.90, 0.4, 1e-8),   # front (cheapest + fastest)
    ]
    idx = pareto_front(results)
    assert set(idx) == {1, 2, 3}
    assert idx[0] == 2  # sorted by accuracy, best first


def test_pareto_front_direction_validation():
    with pytest.raises(ValueError, match="max"):
        pareto_front([], objectives=(("accuracy", "up"),))


# ------------------------------------------------------------------ cache


def test_cache_hit_skips_solver(trained_tiny_mlp, tmp_path, monkeypatch):
    params, xte, yte = trained_tiny_mlp
    cfgs = [("pcm", IMACConfig(tech="PCM", parasitics=False))]
    cache = ResultCache(str(tmp_path / "sweep"))
    cold = run_sweep(
        params, xte, yte, cfgs, n_samples=32, chunk=32, cache=cache
    )
    assert not cold[0].cached
    assert cache.misses == 1 and len(cache) == 1

    import repro.explore.engine as engine

    def boom(*a, **k):
        raise AssertionError("cache hit must not re-solve")

    monkeypatch.setattr(engine, "evaluate_batch", boom)
    warm = run_sweep(
        params, xte, yte, cfgs, n_samples=32, chunk=32, cache=cache
    )
    assert warm[0].cached
    assert warm[0].result == cold[0].result  # bit-identical via JSON round-trip


def test_cache_key_sensitivity(trained_tiny_mlp, tmp_path):
    params, xte, yte = trained_tiny_mlp
    cache = ResultCache(str(tmp_path / "sweep"))
    base = [("a", IMACConfig(tech="PCM", parasitics=False))]
    run_sweep(params, xte, yte, base, n_samples=16, chunk=16, cache=cache)
    # Different config or different n_samples must miss.
    other = [("a", IMACConfig(tech="MRAM", parasitics=False))]
    run_sweep(params, xte, yte, other, n_samples=16, chunk=16, cache=cache)
    run_sweep(params, xte, yte, base, n_samples=8, chunk=16, cache=cache)
    assert cache.misses == 3
    assert len(cache) == 3
    # A different digital-reference activation must also miss (it changes
    # digital_accuracy in the stored result).
    run_sweep(
        params, xte, yte, base, n_samples=16, chunk=16, cache=cache,
        activation="relu",
    )
    assert cache.misses == 4
    assert len(cache) == 4


# ------------------------------------------------------------ engine misc


def test_run_sweep_accepts_bare_configs(trained_tiny_mlp):
    params, xte, yte = trained_tiny_mlp
    out = run_sweep(
        params, xte, yte,
        [IMACConfig(tech="PCM", parasitics=False)],
        n_samples=8, chunk=8,
    )
    assert len(out) == 1 and out[0].name == "cfg0"
    assert out[0].accuracy == out[0].result.accuracy  # attribute proxy


def test_variation_key_is_paired_across_configs(trained_tiny_mlp):
    """The same Monte-Carlo draw applies to every point in a sweep."""
    import jax

    from repro.core.devices import custom_tech

    params, xte, yte = trained_tiny_mlp
    noisy = custom_tech(5e3, 1e5, name="VAR", sigma_rel=0.05)
    cfg = IMACConfig(tech=noisy, parasitics=False)
    key = jax.random.PRNGKey(7)
    solo = sweep(
        params, xte, yte, [("v", cfg)], n_samples=16, chunk=16,
        variation_key=key,
    )[0][1]
    pair = run_sweep(
        params, xte, yte,
        [("v", cfg), ("m", dataclasses.replace(cfg, tech="MRAM"))],
        n_samples=16, chunk=16, variation_key=key,
    )[0].result
    assert pair.accuracy == pytest.approx(solo.accuracy, abs=1e-12)
    assert pair.avg_power == pytest.approx(solo.avg_power, rel=1e-5)
