"""repro.variability: batched Monte-Carlo reliability engine.

The load-bearing regression is loop-equivalence: the batched engine must
reproduce the seed per-trial loop's accuracies bitwise for identical
per-trial keys (examples/monte_carlo.py's port and
benchmarks/variability_bench.py both rest on it).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import IMACConfig
from repro.core.devices import custom_tech, get_tech
from repro.core.evaluate import test_imac as imac_eval  # alias: pytest must not collect it
from repro.explore import (
    RELIABILITY_OBJECTIVES,
    ResultCache,
    SweepSpec,
    pareto_front,
    run_sweep,
)
from repro.variability import (
    ReliabilityReport,
    VariabilitySpec,
    run_variability,
    trial_keys,
)


# ------------------------------------------------------------------ spec


def test_spec_validation():
    with pytest.raises(ValueError, match="at least one trial"):
        VariabilitySpec(trials=0)
    with pytest.raises(ValueError, match="probability"):
        VariabilitySpec(p_stuck_on=1.5)
    with pytest.raises(ValueError, match="<= 1"):
        VariabilitySpec(p_stuck_on=0.6, p_stuck_off=0.6)


def test_spec_resolves_tech_overrides():
    spec = VariabilitySpec(sigma_rel=0.2, levels=8, read_noise_rel=0.01)
    tech = spec.resolve_tech(get_tech("MRAM"))
    assert tech.sigma_rel == 0.2
    assert tech.levels == 8
    assert tech.read_noise_rel == 0.01
    # No overrides -> the tech passes through unchanged.
    assert VariabilitySpec().resolve_tech(get_tech("MRAM")) is get_tech("MRAM")


# ------------------------------------- regression: batched == seed loop


def test_batched_matches_per_trial_loop(trained_tiny_mlp):
    """Pin examples/monte_carlo.py's accuracy distribution across the
    port: for identical per-trial keys, the batched engine reproduces
    the per-trial test_imac loop bitwise."""
    params, xte, yte = trained_tiny_mlp
    tech = dataclasses.replace(get_tech("PCM"), sigma_rel=0.25)
    cfg = IMACConfig(tech=tech, array_rows=32, array_cols=32)
    n_trials = 4
    keys = jnp.stack([jax.random.PRNGKey(100 + t) for t in range(n_trials)])

    loop = [
        imac_eval(
            params, xte, yte, cfg,
            n_samples=16, chunk=16, variation_key=keys[t],
        )
        for t in range(n_trials)
    ]
    report = run_variability(
        params, xte, yte, cfg, VariabilitySpec(trials=n_trials),
        keys=keys, n_samples=16, chunk=16,
    )
    assert list(report.per_trial_accuracy) == [r.accuracy for r in loop]
    np.testing.assert_allclose(
        report.per_trial_power, [r.avg_power for r in loop], rtol=1e-6
    )
    assert report.latency == pytest.approx(loop[0].latency, rel=1e-6)
    assert report.acc_mean == pytest.approx(
        np.mean([r.accuracy for r in loop]), abs=1e-12
    )


def test_sigma_zero_trials_are_exact(trained_tiny_mlp):
    """sigma_rel=0, no faults: every trial equals the deterministic
    evaluation exactly."""
    params, xte, yte = trained_tiny_mlp
    cfg = IMACConfig(tech="MRAM", array_rows=32, array_cols=32)
    det = imac_eval(params, xte, yte, cfg, n_samples=16, chunk=16)
    report = run_variability(
        params, xte, yte, cfg, VariabilitySpec(trials=3, sigma_rel=0.0),
        n_samples=16, chunk=16,
    )
    assert report.per_trial_accuracy == (det.accuracy,) * 3
    assert report.acc_std == 0.0
    np.testing.assert_allclose(
        report.per_trial_power, [det.avg_power] * 3, rtol=1e-6
    )


def test_same_seed_is_reproducible(trained_tiny_mlp):
    params, xte, yte = trained_tiny_mlp
    cfg = IMACConfig(tech="PCM", parasitics=False)
    spec = VariabilitySpec(trials=4, seed=11, sigma_rel=0.3)
    a = run_variability(params, xte, yte, cfg, spec, n_samples=32, chunk=32)
    b = run_variability(params, xte, yte, cfg, spec, n_samples=32, chunk=32)
    assert a == b
    c = run_variability(
        params, xte, yte, cfg, dataclasses.replace(spec, seed=12),
        n_samples=32, chunk=32,
    )
    assert c.per_trial_accuracy != a.per_trial_accuracy or (
        c.per_trial_power != a.per_trial_power
    )


# ------------------------------------------------------ fault injection


def test_stuck_off_faults_collapse_accuracy(trained_tiny_mlp):
    """p_stuck_off=1: every device reads G_off, the differential currents
    vanish and accuracy collapses to ~chance."""
    params, xte, yte = trained_tiny_mlp
    cfg = IMACConfig(tech="MRAM", parasitics=False)
    healthy = run_variability(
        params, xte, yte, cfg, VariabilitySpec(trials=2),
        n_samples=32, chunk=32,
    )
    dead = run_variability(
        params, xte, yte, cfg, VariabilitySpec(trials=2, p_stuck_off=1.0),
        n_samples=32, chunk=32,
    )
    assert healthy.acc_mean > 0.9
    assert dead.acc_mean < 0.5
    assert dead.yield_frac == 0.0


def test_moderate_fault_rate_degrades_yield(trained_tiny_mlp):
    params, xte, yte = trained_tiny_mlp
    cfg = IMACConfig(tech="MRAM", parasitics=False)
    spec = VariabilitySpec(
        trials=6, sigma_rel=0.1, p_stuck_on=0.02, p_stuck_off=0.02,
        acc_threshold=0.95,
    )
    rep = run_variability(params, xte, yte, cfg, spec, n_samples=32, chunk=32)
    assert 0.0 <= rep.yield_frac <= 1.0
    assert rep.acc_min <= rep.acc_q05 <= rep.acc_q50 <= rep.acc_q95 <= rep.acc_max
    # Faults make trials differ from the fault-free run.
    clean = run_variability(
        params, xte, yte, cfg,
        dataclasses.replace(spec, p_stuck_on=0.0, p_stuck_off=0.0),
        n_samples=32, chunk=32,
    )
    assert rep.per_trial_accuracy != clean.per_trial_accuracy or (
        rep.per_trial_power != clean.per_trial_power
    )


# ----------------------------------------------------------- read noise


def test_per_trial_read_noise_decorrelates_trials(trained_tiny_mlp):
    """With read noise on and sigma off, stacked trials still differ
    (noise_per_config draws independently per trial) but are
    reproducible for the same seed."""
    params, xte, yte = trained_tiny_mlp
    cfg = IMACConfig(
        tech=custom_tech(8.5e3, 25.5e3, name="NOISY", read_noise_rel=0.2),
        parasitics=False,
    )
    spec = VariabilitySpec(trials=3, sigma_rel=0.0)
    rep = run_variability(params, xte, yte, cfg, spec, n_samples=64, chunk=64)
    powers = rep.per_trial_power
    accs = rep.per_trial_accuracy
    assert len(set(accs)) > 1 or len(set(powers)) > 1
    rep2 = run_variability(params, xte, yte, cfg, spec, n_samples=64, chunk=64)
    assert rep == rep2


# ------------------------------------------------- explore integration


def test_sweep_spec_reliability_axes():
    spec = SweepSpec.grid(
        IMACConfig(), tech=["MRAM", "PCM"], sigma_rel=[0.1, 0.2], trials=[4]
    )
    points = spec.materialize()
    assert len(points) == 4
    for name, cfg in points:
        assert cfg.variability.trials == 4
        assert cfg.variability.sigma_rel in (0.1, 0.2)
    assert points[0][0] == "tech=MRAM,sigma_rel=0.1,trials=4"


def test_sweep_spec_fault_rate_axis():
    spec = SweepSpec.grid(IMACConfig(), fault_rate=[0.0, 0.01])
    (_, a), (_, b) = spec.materialize()
    assert a.variability.p_stuck_on == 0.0
    assert b.variability.p_stuck_on == pytest.approx(0.005)
    assert b.variability.p_stuck_off == pytest.approx(0.005)


def test_run_sweep_reliability_points_and_pareto(trained_tiny_mlp, tmp_path):
    """SweepSpec sigma axis through run_sweep: cached, Pareto-extractable
    ReliabilityReports."""
    params, xte, yte = trained_tiny_mlp
    spec = SweepSpec.grid(
        IMACConfig(parasitics=False),
        tech=["MRAM", "PCM"],
        sigma_rel=[0.1, 0.3],
        fault_rate=[0.0, 0.01],
        trials=[3],
    )
    cache = ResultCache(str(tmp_path / "rel"))
    res = run_sweep(params, xte, yte, spec, n_samples=32, chunk=32, cache=cache)
    assert len(res) == 8
    for r in res:
        assert isinstance(r.result, ReliabilityReport)
        assert r.result.n_trials == 3
        assert r.acc_q05 <= r.acc_mean <= r.acc_q95 or r.acc_std == 0.0
    # The fault axis must change results for at least one (tech, sigma).
    by_name = {r.name: r.result for r in res}
    assert any(
        by_name[n].per_trial_accuracy
        != by_name[n.replace("fault_rate=0.01", "fault_rate=0")].per_trial_accuracy
        or by_name[n].per_trial_power
        != by_name[n.replace("fault_rate=0.01", "fault_rate=0")].per_trial_power
        for n in by_name if "fault_rate=0.01" in n
    )

    front = pareto_front(res, RELIABILITY_OBJECTIVES)
    assert front  # non-empty, indices valid
    assert all(0 <= i < len(res) for i in front)

    # Warm re-run: all hits, bit-identical reports via the JSON round-trip.
    warm = run_sweep(
        params, xte, yte, spec, n_samples=32, chunk=32, cache=cache
    )
    assert all(r.cached for r in warm)
    for a, b in zip(res, warm):
        assert a.result == b.result


def test_run_sweep_read_noise_axis_matches_run_variability(trained_tiny_mlp):
    """A read_noise_rel sweep axis must actually inject noise: points
    with different values differ, and each equals the direct
    run_variability evaluation (spec-seeded noise, position-independent)."""
    params, xte, yte = trained_tiny_mlp
    base = IMACConfig(parasitics=False)
    spec = SweepSpec.grid(
        base, read_noise_rel=[0.0, 0.3], trials=[3], sigma_rel=[0.0]
    )
    res = run_sweep(params, xte, yte, spec, n_samples=64, chunk=64)
    quiet, noisy = res[0].result, res[1].result
    assert quiet.per_trial_accuracy != noisy.per_trial_accuracy or (
        quiet.per_trial_power != noisy.per_trial_power
    )
    # Noisy trials decorrelate (independent per-trial draws).
    assert len(set(noisy.per_trial_accuracy)) > 1 or (
        len(set(noisy.per_trial_power)) > 1
    )
    direct = run_variability(
        params, xte, yte, res[1].config, res[1].config.variability,
        n_samples=64, chunk=64,
    )
    assert noisy == direct


def test_deterministic_result_is_single_trial_distribution(trained_tiny_mlp):
    """IMACResult proxies the reliability fields as a degenerate T=1
    distribution so mixed sweeps share Pareto objectives."""
    params, xte, yte = trained_tiny_mlp
    res = imac_eval(
        params, xte, yte, IMACConfig(tech="PCM", parasitics=False),
        n_samples=16, chunk=16,
    )
    assert res.n_trials == 1
    assert res.acc_q05 == res.acc_q95 == res.acc_mean == res.accuracy
    assert res.acc_std == 0.0
    assert res.power_worst == res.power_mean == res.avg_power


def test_mixed_sweep_pareto_with_reliability_objectives(trained_tiny_mlp):
    """pareto_front(RELIABILITY_OBJECTIVES) must work on a sweep mixing
    deterministic and Monte-Carlo points."""
    params, xte, yte = trained_tiny_mlp
    plain = IMACConfig(tech="MRAM", parasitics=False)
    mc = dataclasses.replace(
        IMACConfig(tech="PCM", parasitics=False),
        variability=VariabilitySpec(trials=3, sigma_rel=0.2),
    )
    res = run_sweep(
        params, xte, yte, [("plain", plain), ("mc", mc)],
        n_samples=32, chunk=32,
    )
    front = pareto_front(res, RELIABILITY_OBJECTIVES)
    assert front and all(0 <= i < len(res) for i in front)


def test_run_sweep_mixes_plain_and_reliability_points(trained_tiny_mlp):
    """A deterministic point and a Monte-Carlo point of the same traced
    structure share one batched solve; the plain point's result matches
    its solo evaluation."""
    params, xte, yte = trained_tiny_mlp
    plain = IMACConfig(tech="MRAM", parasitics=False)
    mc = dataclasses.replace(
        plain, variability=VariabilitySpec(trials=3, sigma_rel=0.2)
    )
    res = run_sweep(
        params, xte, yte, [("plain", plain), ("mc", mc)],
        n_samples=32, chunk=32,
    )
    solo = imac_eval(params, xte, yte, plain, n_samples=32, chunk=32)
    assert res[0].result.accuracy == pytest.approx(solo.accuracy, abs=1e-12)
    assert isinstance(res[1].result, ReliabilityReport)
    assert res[1].result.n_trials == 3


def test_deterministic_spec_collapses_to_one_solve(
    trained_tiny_mlp, monkeypatch
):
    """A spec with no stochastic content solves once and replicates —
    not T identical circuit evaluations."""
    import repro.variability.engine as vengine

    params, xte, yte = trained_tiny_mlp
    stacked_sizes = []
    orig = vengine.evaluate_batch

    def spy(params_, x_, y_, cfgs_, **kw):
        stacked_sizes.append(len(cfgs_))
        return orig(params_, x_, y_, cfgs_, **kw)

    monkeypatch.setattr(vengine, "evaluate_batch", spy)
    rep = run_variability(
        params, xte, yte, IMACConfig(tech="MRAM", parasitics=False),
        VariabilitySpec(trials=5, sigma_rel=0.0),
        n_samples=16, chunk=16,
    )
    assert stacked_sizes == [1]
    assert rep.n_trials == 5
    assert len(set(rep.per_trial_accuracy)) == 1


def test_mc_cache_keys_ignore_sweep_level_keys(trained_tiny_mlp, tmp_path):
    """Reliability results derive only from their spec's seed, so adding
    a sweep-level variation_key must not invalidate their cache entries
    (while deterministic points' entries do miss)."""
    params, xte, yte = trained_tiny_mlp
    plain = IMACConfig(tech="MRAM", parasitics=False)
    mc = dataclasses.replace(
        plain, variability=VariabilitySpec(trials=3, sigma_rel=0.2)
    )
    points = [("plain", plain), ("mc", mc)]
    cache = ResultCache(str(tmp_path / "mc"))
    cold = run_sweep(params, xte, yte, points, n_samples=16, chunk=16,
                     cache=cache)
    warm = run_sweep(
        params, xte, yte, points, n_samples=16, chunk=16, cache=cache,
        variation_key=jax.random.PRNGKey(0),
    )
    assert not warm[0].cached    # paired draw changes the plain point
    assert warm[1].cached        # MC point untouched by variation_key
    assert warm[1].result == cold[1].result


def test_trial_keys_match_spec():
    spec = VariabilitySpec(trials=5, seed=3)
    keys = trial_keys(spec)
    assert keys.shape[0] == 5
    np.testing.assert_array_equal(
        np.asarray(keys),
        np.asarray(jax.random.split(jax.random.PRNGKey(3), 5)),
    )


def test_report_proxies_point_attributes():
    rep_fields = dict(
        n_trials=2, acc_mean=0.9, acc_std=0.01, acc_min=0.89, acc_max=0.91,
        acc_q05=0.89, acc_q25=0.9, acc_q50=0.9, acc_q75=0.91, acc_q95=0.91,
        acc_threshold=0.85, yield_frac=1.0, power_mean=0.1, power_worst=0.12,
        latency=1e-7, digital_accuracy=0.95, worst_residual=1e-6,
        n_samples=32, per_trial_accuracy=(0.89, 0.91),
        per_trial_power=(0.1, 0.12), hp=(1,), vp=(1,),
    )
    rep = ReliabilityReport(**rep_fields)
    assert rep.accuracy == rep.acc_mean
    assert rep.avg_power == rep.power_mean
    assert rep.error_rate == pytest.approx(1.0 - rep.acc_mean)
