"""Differential + API tests of the solver backend registry.

Every registered backend ("scan" / "pallas" / "fused") must produce the
same `CrossbarSolution` as the pure-scan sweep loop and, where the dense
MNA oracle applies, as `solve_dense_mna` — including degenerate shapes
(M=N=1, single row/column), non-power-of-two tiles, batch sizes that are
not lane multiples, and transient companion stamps. The Pallas backends
run in interpret mode here so the exact kernel code paths execute on
CPU CI.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backends import (
    SolverBackend,
    available_backends,
    default_backend_name,
    get_backend,
    register_backend,
)
from repro.core.devices import MRAM
from repro.core.solver import (
    CircuitParams,
    SolveOptions,
    Stamps,
    solve_crossbar,
    solve_dense_mna,
    tridiag_scan,
)

CP = CircuitParams(r_row=13.8, r_col=13.8, gs_iters=96, tol=0.0)
BACKENDS = ("scan", "pallas", "fused")


def _opts(backend):
    # interpret=True is consumed by the Pallas backends and ignored by
    # "scan"; forcing it keeps the tests identical on and off TPU.
    return SolveOptions(backend=backend, interpret=True)


def _random_tile(key, m, n):
    kg, kv = jax.random.split(key)
    g = jax.random.uniform(kg, (m, n), minval=MRAM.g_off, maxval=MRAM.g_on)
    v = jax.random.uniform(kv, (m,), minval=0.0, maxval=0.8)
    return g, v


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("m,n", [(1, 1), (1, 8), (8, 1), (5, 7)])
def test_backend_matches_oracle(backend, m, n):
    g, v = _random_tile(jax.random.PRNGKey(m * 100 + n), m, n)
    oracle = solve_dense_mna(g, v, CP)
    got = solve_crossbar(g, v, CP, options=_opts(backend))
    np.testing.assert_allclose(
        np.asarray(got.i_out), np.asarray(oracle.i_out), rtol=5e-4, atol=1e-9
    )
    np.testing.assert_allclose(
        np.asarray(got.vc), np.asarray(oracle.vc), rtol=5e-3, atol=1e-6
    )


@pytest.mark.parametrize("backend", ("pallas", "fused"))
def test_backend_matches_scan_batched(backend):
    """Batch axes that are not multiples of the 128-lane tile."""
    key = jax.random.PRNGKey(7)
    g = jax.random.uniform(key, (3, 6, 5), minval=MRAM.g_off, maxval=MRAM.g_on)
    v = jax.random.uniform(jax.random.PRNGKey(8), (5, 3, 6), maxval=0.8)
    ref = solve_crossbar(g, v, CP, options=_opts("scan"))
    got = solve_crossbar(g, v, CP, options=_opts(backend))
    assert got.i_out.shape == (5, 3, 5)
    np.testing.assert_allclose(
        np.asarray(got.i_out), np.asarray(ref.i_out), rtol=1e-4, atol=1e-9
    )
    np.testing.assert_allclose(
        np.asarray(got.vc), np.asarray(ref.vc), rtol=1e-4, atol=1e-7
    )


@pytest.mark.parametrize("backend", ("pallas", "fused"))
def test_backend_matches_scan_with_companion_stamps(backend):
    """Transient companion stamps (shunts + injections + warm start)."""
    m, n = 6, 5
    g, v = _random_tile(jax.random.PRNGKey(21), m, n)
    k1, k2, k3, k4, k5 = jax.random.split(jax.random.PRNGKey(22), 5)
    stamps = Stamps(
        g_shunt_row=jax.random.uniform(k1, (m, n), minval=1e-4, maxval=1e-2),
        g_shunt_col=jax.random.uniform(k2, (m, n), minval=1e-4, maxval=1e-2),
        i_inj_row=1e-4 * jax.random.normal(k3, (m, n)),
        i_inj_col=1e-4 * jax.random.normal(k4, (m, n)),
        v_init=0.1 * jax.random.uniform(k5, (m, n)),
    )
    ref = solve_crossbar(g, v, CP, stamps=stamps, options=_opts("scan"))
    got = solve_crossbar(g, v, CP, stamps=stamps, options=_opts(backend))
    np.testing.assert_allclose(
        np.asarray(got.vc), np.asarray(ref.vc), rtol=1e-4, atol=1e-8
    )
    np.testing.assert_allclose(
        np.asarray(got.vr), np.asarray(ref.vr), rtol=1e-4, atol=1e-8
    )
    np.testing.assert_allclose(
        np.asarray(got.i_out), np.asarray(ref.i_out), rtol=1e-4, atol=1e-10
    )


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_per_config_params(backend):
    """Leading-axis electrical scalars (the explore engine's shape)."""
    g, v = _random_tile(jax.random.PRNGKey(31), 4, 6)
    cp = CircuitParams(
        r_row=jnp.asarray([5.0, 13.8, 40.0]),
        r_col=jnp.asarray([5.0, 13.8, 40.0]),
        gs_iters=96,
        tol=0.0,
    )
    g3 = jnp.broadcast_to(g, (3, 4, 6))
    v3 = jnp.broadcast_to(v, (3, 4))
    ref = solve_crossbar(g3, v3, cp, options=_opts("scan"))
    got = solve_crossbar(g3, v3, cp, options=_opts(backend))
    assert got.i_out.shape == (3, 6)
    np.testing.assert_allclose(
        np.asarray(got.i_out), np.asarray(ref.i_out), rtol=1e-4, atol=1e-9
    )


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_on_netlist_conductances(backend):
    """The SPICE-netlist round-trip fixture, solved by every backend."""
    from repro.core.imac import IMACConfig, build_plans
    from repro.core.mapping import map_network
    from repro.core.netlist import map_layer, parse_tile_conductances

    key = jax.random.PRNGKey(61)
    kw, kb, kv = jax.random.split(key, 3)
    params = [(jax.random.normal(kw, (5, 3)), 0.1 * jax.random.normal(kb, (3,)))]
    cfg = IMACConfig(
        tech=MRAM, array_rows=4, array_cols=4, r_source=120.0, r_tia=10.0
    )
    mapped = map_network(params, MRAM, v_unit=cfg.vdd)
    plans = build_plans([5, 3], cfg)
    text = map_layer(0, mapped[0], plans[0], cfg)
    gp, gn = parse_tile_conductances(text, plans[0])
    r_seg = cfg.interconnect.r_segment
    cp = CircuitParams(
        r_row=r_seg, r_col=r_seg, r_source=120.0, r_tia=10.0,
        gs_iters=200, tol=0.0,
    )
    v = jax.random.uniform(kv, (plans[0].rows,), maxval=cfg.vdd)
    for g_tile in (gp[0], gn[0]):
        g = jnp.asarray(g_tile)
        oracle = solve_dense_mna(g, v, cp)
        got = solve_crossbar(g, v, cp, options=_opts(backend))
        np.testing.assert_allclose(
            np.asarray(got.i_out), np.asarray(oracle.i_out),
            rtol=1e-3, atol=1e-9,
        )
        np.testing.assert_allclose(
            np.asarray(got.vc), np.asarray(oracle.vc), rtol=5e-3, atol=1e-6
        )


def test_stamps_is_pytree():
    st = Stamps(v_init=jnp.ones((2, 2)))
    leaves = jax.tree_util.tree_leaves(st)
    assert len(leaves) == 1
    mapped = jax.tree_util.tree_map(lambda x: 2.0 * x, st)
    np.testing.assert_allclose(np.asarray(mapped.v_init), 2.0)


# ---------------------------------------------------------------------------
# Deprecation shim
# ---------------------------------------------------------------------------


def test_deprecated_stamp_kwargs_warn_and_forward():
    m, n = 5, 4
    g, v = _random_tile(jax.random.PRNGKey(41), m, n)
    gsh = jnp.full((m, n), 1e-3)
    v0 = jnp.full((m, n), 0.05)
    with pytest.warns(DeprecationWarning, match="Stamps"):
        old = solve_crossbar(g, v, CP, g_shunt_row=gsh, v_init=v0)
    new = solve_crossbar(g, v, CP, stamps=Stamps(g_shunt_row=gsh, v_init=v0))
    np.testing.assert_allclose(
        np.asarray(old.vc), np.asarray(new.vc), rtol=0, atol=0
    )


def test_deprecated_tridiag_warns_and_forwards():
    g, v = _random_tile(jax.random.PRNGKey(42), 9, 11)
    with pytest.warns(DeprecationWarning, match="tridiag"):
        old = solve_crossbar(g, v, CP, tridiag=tridiag_scan)
    new = solve_crossbar(g, v, CP, options=SolveOptions(backend="scan"))
    np.testing.assert_allclose(
        np.asarray(old.i_out), np.asarray(new.i_out), rtol=0, atol=0
    )


def test_mixing_old_and_new_spellings_raises():
    g, v = _random_tile(jax.random.PRNGKey(43), 4, 4)
    v0 = jnp.zeros((4, 4))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        with pytest.raises(ValueError, match="Stamps"):
            solve_crossbar(g, v, CP, stamps=Stamps(v_init=v0), v_init=v0)
        with pytest.raises(ValueError, match="tridiag"):
            solve_crossbar(
                g, v, CP,
                tridiag=tridiag_scan,
                options=SolveOptions(backend="scan"),
            )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_custom_callable_backend_is_used():
    g, v = _random_tile(jax.random.PRNGKey(51), 6, 5)
    calls = []

    def my_tridiag(dl, d, du, b):
        calls.append(1)
        return tridiag_scan(dl, d, du, b)

    got = solve_crossbar(g, v, CP, options=SolveOptions(backend=my_tridiag))
    ref = solve_crossbar(g, v, CP, options=_opts("scan"))
    assert calls, "custom tridiag callable was never traced"
    np.testing.assert_allclose(
        np.asarray(got.i_out), np.asarray(ref.i_out), rtol=1e-6
    )


def test_unknown_backend_name_lists_available():
    with pytest.raises(KeyError, match="scan"):
        get_backend("no-such-backend")


def test_env_default_backend(monkeypatch):
    monkeypatch.delenv("REPRO_SOLVER_BACKEND", raising=False)
    assert default_backend_name() == "scan"
    assert get_backend(None).name == "scan"
    monkeypatch.setenv("REPRO_SOLVER_BACKEND", "fused")
    assert default_backend_name() == "fused"
    assert get_backend(None).name == "fused"


def test_register_backend_roundtrip():
    name = "test-echo-scan"
    register_backend(
        SolverBackend(name=name, make_tridiag=lambda options: tridiag_scan)
    )
    assert name in available_backends()
    g, v = _random_tile(jax.random.PRNGKey(52), 5, 5)
    got = solve_crossbar(g, v, CP, options=SolveOptions(backend=name))
    ref = solve_crossbar(g, v, CP, options=_opts("scan"))
    np.testing.assert_allclose(
        np.asarray(got.i_out), np.asarray(ref.i_out), rtol=0, atol=0
    )


def test_fused_vmem_fallback():
    """Tiles past the VMEM residency budget delegate to 'pallas'."""
    from repro.kernels.gs_fused.ops import fused_lane_block

    assert fused_lane_block(16, 16) >= 1
    assert fused_lane_block(512, 512) == 0
    # The solve must still succeed (falls back internally).
    g, v = _random_tile(jax.random.PRNGKey(53), 16, 16)
    got = solve_crossbar(g, v, CP, options=_opts("fused"))
    assert got.i_out.shape == (16,)


# ---------------------------------------------------------------------------
# gs_fused fallback regressions
# ---------------------------------------------------------------------------


def test_fused_vmem_fallback_selects_pallas(monkeypatch, caplog):
    """Past-budget tiles must delegate to the 'pallas' backend (not
    scan), still produce correct currents, and log the fallback notice
    exactly once per process."""
    import logging

    import repro.core.backends as backends
    import repro.kernels.gs_fused.ops as ops

    # fused_lane_block's budget default binds at def time, so patch the
    # function itself (not the constant) to force "tile does not fit".
    monkeypatch.setattr(ops, "fused_lane_block", lambda *a, **k: 0)
    monkeypatch.setattr(ops, "_fallback_notice_emitted", False)

    calls = []
    real = backends._REGISTRY["pallas"]

    def spy_factory(options):
        calls.append(options)
        return real.make_tridiag(options)

    monkeypatch.setitem(
        backends._REGISTRY,
        "pallas",
        SolverBackend(name="pallas", make_tridiag=spy_factory),
    )

    g, v = _random_tile(jax.random.PRNGKey(54), 8, 8)
    with caplog.at_level(logging.WARNING, logger="repro.kernels.gs_fused.ops"):
        got = solve_crossbar(g, v, CP, options=_opts("fused"))
        again = solve_crossbar(g, v, CP, options=_opts("fused"))
    assert calls, "fallback did not route through the 'pallas' backend"
    notices = [r for r in caplog.records if "VMEM" in r.getMessage()]
    assert len(notices) == 1, "fallback notice must fire once per process"
    ref = solve_crossbar(g, v, CP, options=_opts("scan"))
    np.testing.assert_allclose(
        np.asarray(got.i_out), np.asarray(ref.i_out), rtol=1e-4, atol=1e-9
    )
    np.testing.assert_allclose(
        np.asarray(again.i_out), np.asarray(got.i_out), rtol=0, atol=0
    )


def test_fused_fallback_notice_already_emitted(monkeypatch, caplog):
    """Once the per-process notice has fired, later fallbacks are silent."""
    import logging

    import repro.kernels.gs_fused.ops as ops

    monkeypatch.setattr(ops, "fused_lane_block", lambda *a, **k: 0)
    monkeypatch.setattr(ops, "_fallback_notice_emitted", True)
    g, v = _random_tile(jax.random.PRNGKey(55), 8, 8)
    with caplog.at_level(logging.WARNING, logger="repro.kernels.gs_fused.ops"):
        solve_crossbar(g, v, CP, options=_opts("fused"))
    assert not [r for r in caplog.records if "VMEM" in r.getMessage()]


def test_fused_vmem_fallback_emits_obs_event(monkeypatch):
    """Each VMEM-budget fallback records exactly one structured
    backend_fallback event (cause=vmem_budget) and the solve actually
    routes through the 'pallas' backend it names."""
    import repro.core.backends as backends
    import repro.kernels.gs_fused.ops as ops
    from repro import obs

    monkeypatch.setattr(ops, "fused_lane_block", lambda *a, **k: 0)
    # Notice already emitted: proves the event is independent of the
    # legacy once-per-process log guard (one event PER occurrence).
    monkeypatch.setattr(ops, "_fallback_notice_emitted", True)

    calls = []
    real = backends._REGISTRY["pallas"]

    def spy_factory(options):
        calls.append(options)
        return real.make_tridiag(options)

    monkeypatch.setitem(
        backends._REGISTRY,
        "pallas",
        SolverBackend(name="pallas", make_tridiag=spy_factory),
    )

    obs.enable()
    obs.reset()
    try:
        g, v = _random_tile(jax.random.PRNGKey(56), 8, 8)
        solve_crossbar(g, v, CP, options=_opts("fused"))
        events = obs.events("backend_fallback")
        vmem = [e for e in events if e["fields"]["cause"] == "vmem_budget"]
        assert len(vmem) == 1, "one event per fallback occurrence"
        assert vmem[0]["fields"]["from_backend"] == "fused"
        assert vmem[0]["fields"]["to_backend"] == "pallas"
        assert vmem[0]["fields"]["tile"] == "8x8"
        assert calls, "event names 'pallas' but the solve never used it"
        # A second fallback is a second occurrence -> a second event.
        solve_crossbar(g, v, CP, options=_opts("fused"))
        vmem = [
            e
            for e in obs.events("backend_fallback")
            if e["fields"]["cause"] == "vmem_budget"
        ]
        assert len(vmem) == 2
    finally:
        obs.disable()
        obs.reset()


@pytest.mark.skipif(
    __import__("repro.core.backends", fromlist=["on_tpu"]).on_tpu(),
    reason="interpret auto-fallback only happens off-TPU",
)
def test_interpret_fallback_emits_obs_event_once(monkeypatch):
    """The off-TPU interpret auto-fallback is a process-level condition:
    exactly one backend_fallback event (cause=interpret_mode) no matter
    how many resolutions happen, and the resolution itself holds."""
    import repro.core.backends as backends
    from repro import obs

    monkeypatch.setattr(backends, "_interpret_notice_emitted", False)
    obs.enable()
    obs.reset()
    try:
        assert backends.resolve_interpret(None) is True
        assert backends.resolve_interpret(None) is True
        events = [
            e
            for e in obs.events("backend_fallback")
            if e["fields"]["cause"] == "interpret_mode"
        ]
        assert len(events) == 1, "interpret event must fire once per process"
        assert events[0]["fields"]["jax_backend"] == jax.default_backend()
        # Explicit flags bypass the autodetect entirely: no new events.
        assert backends.resolve_interpret(False) is False
        assert len(obs.events("backend_fallback")) == 1
    finally:
        obs.disable()
        obs.reset()


@pytest.mark.skipif(
    __import__("repro.core.backends", fromlist=["on_tpu"]).on_tpu(),
    reason="interpret auto-fallback only happens off-TPU",
)
def test_interpret_notice_logged_once(monkeypatch, caplog):
    """resolve_interpret(None) off-TPU: interpret mode on, one notice."""
    import logging

    import repro.core.backends as backends

    monkeypatch.setattr(backends, "_interpret_notice_emitted", False)
    with caplog.at_level(logging.WARNING, logger="repro.core.backends"):
        assert backends.resolve_interpret(None) is True
        assert backends.resolve_interpret(None) is True
    notices = [r for r in caplog.records if "interpret mode" in r.getMessage()]
    assert len(notices) == 1, "auto-interpret notice must fire once"
    caplog.clear()
    # Explicit flags bypass both the autodetect and the notice.
    with caplog.at_level(logging.WARNING, logger="repro.core.backends"):
        assert backends.resolve_interpret(True) is True
        assert backends.resolve_interpret(False) is False
    assert not caplog.records
