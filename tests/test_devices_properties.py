"""Property tests for device quantization and variability sampling.

Separate from test_devices.py so its deterministic tests keep running
in environments without hypothesis (importorskip guards this module).
"""
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.devices import custom_tech


techs = st.builds(
    lambda r_low, ratio, levels: custom_tech(
        r_low, r_low * ratio, levels=levels
    ),
    r_low=st.floats(min_value=1e3, max_value=1e5),
    ratio=st.floats(min_value=1.5, max_value=200.0),
    levels=st.integers(min_value=0, max_value=16),
)


@settings(max_examples=40, deadline=None)
@given(tech=techs, seed=st.integers(min_value=0, max_value=2**16))
def test_quantize_range_levels_monotone_idempotent(tech, seed):
    g = jax.random.uniform(
        jax.random.PRNGKey(seed), (64,),
        minval=0.0, maxval=2.0 * tech.g_on,
    )
    q = tech.quantize(g)
    # Always inside the programmable range (float32 grid vs float64
    # bounds -> relative tolerance).
    assert float(q.min()) >= tech.g_off * (1 - 1e-6)
    assert float(q.max()) <= tech.g_on * (1 + 1e-6)
    # Level count bound (continuous/degenerate levels impose none).
    if tech.levels > 1:
        assert int(jnp.unique(q).shape[0]) <= tech.levels
    else:
        assert jnp.array_equal(q, jnp.clip(g, tech.g_off, tech.g_on))
    # Monotone in the input and idempotent.
    order = jnp.argsort(g)
    assert bool(jnp.all(jnp.diff(q[order]) >= -1e-18))
    assert jnp.array_equal(tech.quantize(q), q)


@settings(max_examples=25, deadline=None)
@given(
    sigma=st.floats(min_value=0.0, max_value=0.8),
    seed=st.integers(min_value=0, max_value=2**16),
    trials=st.integers(min_value=1, max_value=6),
)
def test_variability_sampling_properties(sigma, seed, trials):
    tech = custom_tech(1e3, 1e5, sigma_rel=sigma)
    g = jnp.linspace(tech.g_off, tech.g_on, 32)
    keys = jax.random.split(jax.random.PRNGKey(seed), trials)
    a = tech.perturb_trials(keys, g)
    assert a.shape == (trials, 32)
    # Same PRNG keys -> bitwise-identical trials.
    b = tech.perturb_trials(keys, g)
    assert jnp.array_equal(a, b)
    # Batched == sequential per-key perturb, bitwise.
    seq = jnp.stack([tech.perturb(k, g) for k in keys])
    assert jnp.array_equal(a, seq)
    # Physical range always respected.
    assert float(a.min()) >= tech.g_off * (1 - 1e-6)
    assert float(a.max()) <= tech.g_on * (1 + 1e-6)
    if sigma == 0.0:
        # sigma_rel=0 is exact, not merely close.
        assert jnp.array_equal(a, jnp.broadcast_to(g, (trials, 32)))
