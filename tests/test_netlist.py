import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypcompat import given, seed, settings, st

from repro.core.devices import MRAM
from repro.core.imac import IMACConfig, build_plans
from repro.core.mapping import map_network
from repro.core.netlist import (
    map_imac,
    map_layer,
    netlist_stats,
    parse_tile_conductances,
)
from repro.core.partition import tile_matrix
from repro.core.solver import solve_dense_mna


@pytest.fixture(scope="module")
def small_net():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    params = [
        (jax.random.normal(k1, (6, 4)), jnp.zeros((4,))),
        (jax.random.normal(k2, (4, 3)), jnp.zeros((3,))),
    ]
    cfg = IMACConfig(tech="MRAM", array_rows=4, array_cols=4)
    topology = [6, 4, 3]
    mapped = map_network(params, MRAM, v_unit=cfg.vdd)
    plans = build_plans(topology, cfg)
    return cfg, mapped, plans


def test_layer_subckt_structure(small_net):
    cfg, mapped, plans = small_net
    text = map_layer(0, mapped[0], plans[0], cfg)
    assert text.startswith("* Layer 0")
    assert ".SUBCKT layer0" in text and ".ENDS layer0" in text
    # 7 input nodes (6 + bias) and 4 outputs in the port list.
    ports = text.split(".SUBCKT layer0 ")[1].split("\n")[0].split()
    assert ports[:7] == [f"in_{i}" for i in range(7)]
    assert ports[7:] == [f"out_{j}" for j in range(4)]


def test_netlist_roundtrip_conductances(small_net):
    """Parse the netlist back and compare to the mapped tile stacks."""
    cfg, mapped, plans = small_net
    for li in range(2):
        text = map_layer(li, mapped[li], plans[li], cfg)
        gp, gn = parse_tile_conductances(text, plans[li])
        want_p = np.asarray(tile_matrix(mapped[li].g_pos, plans[li]))
        want_n = np.asarray(tile_matrix(mapped[li].g_neg, plans[li]))
        np.testing.assert_allclose(gp, want_p, rtol=1e-4)
        np.testing.assert_allclose(gn, want_n, rtol=1e-4)


def test_netlist_solver_agreement(small_net):
    """Netlist-parsed conductances solved by the MNA oracle must match the
    direct solver output — netlist ⇄ simulator consistency."""
    from repro.core.solver import solve_crossbar

    cfg, mapped, plans = small_net
    text = map_layer(0, mapped[0], plans[0], cfg)
    gp, _ = parse_tile_conductances(text, plans[0])
    cp = cfg.circuit_params(plans[0].rows, plans[0].cols)
    v = jnp.linspace(0.1, 0.8, plans[0].rows)
    for t in range(plans[0].n_tiles):
        oracle = solve_dense_mna(jnp.asarray(gp[t]), v, cp)
        fast = solve_crossbar(jnp.asarray(gp[t]), v, cp)
        np.testing.assert_allclose(
            np.asarray(fast.i_out), np.asarray(oracle.i_out), rtol=1e-3
        )


def test_map_imac_main_file(small_net):
    cfg, mapped, plans = small_net
    files = map_imac(mapped, plans, cfg, sample=np.linspace(0, 1, 6))
    assert set(files) == {"layer0.sp", "layer1.sp", "imac_main.sp"}
    main = files["imac_main.sp"]
    assert ".INCLUDE 'layer0.sp'" in main
    assert ".INCLUDE 'layer1.sp'" in main
    assert "Xlayer0" in main and "Xlayer1" in main
    assert ".TRAN" in main and main.rstrip().endswith(".END")
    # Input sources for every pixel + bias per layer.
    assert main.count("Vin_") == 6
    assert main.count("Vbias_") == 2


def test_netlist_stats(small_net):
    cfg, mapped, plans = small_net
    files = map_imac(mapped, plans, cfg)
    stats = netlist_stats(files)
    assert stats["subckts"] == 2
    assert stats["esources"] == 7  # 4 + 3 neurons
    # Every mapped device appears (g_off > 0 everywhere -> no padding holes
    # except the actual pad cells).
    n_devices = sum(
        int((np.asarray(tile_matrix(m.g_pos, p)) > 0).sum())
        + int((np.asarray(tile_matrix(m.g_neg, p)) > 0).sum())
        for m, p in zip(mapped, plans)
    )
    text = files["layer0.sp"] + files["layer1.sp"]
    assert text.count("Rmem_") == n_devices


# ---------------------------------------------------------------------------
# Round-trip properties through the repro.spice interchange: generation
# and parsing share one IR printer, so emit -> parse -> emit must be
# byte-stable and structure-preserving for every generated deck.
# ---------------------------------------------------------------------------


def _roundtrip_files(files):
    from repro.spice import emit, parse_netlist

    reemitted = {n: emit(parse_netlist(t)) for n, t in files.items()}
    assert reemitted == files, "emit -> parse -> emit not byte-stable"
    assert netlist_stats(reemitted) == netlist_stats(files)


def test_roundtrip_byte_stable(small_net):
    cfg, mapped, plans = small_net
    _roundtrip_files(map_imac(mapped, plans, cfg, sample=np.linspace(0, 1, 6)))


def test_roundtrip_byte_stable_transient(small_net):
    from repro.transient.spec import TransientSpec

    cfg, mapped, plans = small_net
    _roundtrip_files(
        map_imac(
            mapped,
            plans,
            cfg,
            sample=np.linspace(0, 1, 6),
            transient=TransientSpec(t_stop=2e-9, n_steps=8, method="be"),
        )
    )


@seed(2029)
@given(
    sample=st.lists(
        st.floats(min_value=0.0, max_value=1.0), min_size=6, max_size=6
    ),
    use_tran=st.booleans(),
)
@settings(max_examples=15)
def test_fuzz_roundtrip_over_samples(small_net, sample, use_tran):
    """Property: byte stability and stats invariance hold for arbitrary
    input drives, DC and transient decks alike."""
    cfg, mapped, plans = small_net
    spec = None
    if use_tran:
        from repro.transient.spec import TransientSpec

        spec = TransientSpec(t_stop=2e-9, n_steps=8)
    _roundtrip_files(
        map_imac(
            mapped, plans, cfg, sample=np.asarray(sample), transient=spec
        )
    )
