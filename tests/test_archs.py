"""Per-architecture smoke tests (reduced configs, CPU, 1 device).

For each assigned arch: instantiate the reduced same-family config, run
one forward/train step, assert output shapes and finiteness; check the
param tree and the logical-axes tree match; validate decode-vs-forward
consistency (capacity-dropping neutralised for MoE archs).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config
from repro.models import build_model

ALL_ARCHS = sorted(ARCHS)


def make_batch(cfg, B=2, S=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            ks[2], (B, cfg.modality_prefix, cfg.d_model), jnp.float32
        )
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.encoder_seq, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_reduced_train_step(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(model.loss_fn, has_aux=True)
    )(params, batch)
    assert jnp.isfinite(loss), f"{name}: loss not finite"
    assert float(loss) > 0
    # Gradients exist, are finite, and match the param tree.
    gflat, _ = jax.tree_util.tree_flatten(grads)
    pflat, _ = jax.tree_util.tree_flatten(params)
    assert len(gflat) == len(pflat)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in gflat), name
    # Forward logits shape.
    logits, aux = model.forward(params, batch)
    s_expect = batch["tokens"].shape[1] + (
        cfg.modality_prefix if cfg.family == "vlm" else 0
    )
    assert logits.shape == (2, s_expect, cfg.vocab)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_param_axes_tree_matches(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    axes = model.param_axes()
    is_axes_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )
    pt = jax.tree_util.tree_structure(jax.tree_util.tree_map(lambda x: 0, params))
    at = jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda x: 0, axes, is_leaf=is_axes_leaf)
    )
    assert pt == at, f"{name}: param/axes tree mismatch"
    # Rank agreement per leaf.
    flat_p = jax.tree_util.tree_leaves(params)
    flat_a, _ = jax.tree_util.tree_flatten(axes, is_leaf=is_axes_leaf)
    for p, a in zip(flat_p, flat_a):
        assert len(a) == p.ndim, f"{name}: {a} vs {p.shape}"


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_decode_matches_forward(name):
    cfg = get_config(name).reduced()
    if cfg.moe_enabled:
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)  # no drops
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 12
    batch = make_batch(cfg, B, S, seed=1)
    logits_full, _ = model.forward(params, batch)
    prefix = cfg.modality_prefix if cfg.family == "vlm" else 0
    pre = {k: v for k, v in batch.items() if k != "labels"}
    pre["tokens"] = batch["tokens"][:, : S - 1]
    lg_pre, cache = model.prefill(params, pre, cache_len=S + prefix + 4)
    lg_dec, cache2 = model.decode_step(
        params, cache, batch["tokens"][:, S - 1 : S]
    )
    ref_last = np.asarray(logits_full[:, -1])
    got = np.asarray(lg_dec[:, 0])
    rel = np.max(np.abs(got - ref_last)) / (np.max(np.abs(ref_last)) + 1e-9)
    assert rel < 1e-4, f"{name}: decode diverges from forward ({rel:.2e})"
    assert int(cache2["pos"][0]) == S + prefix


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_multi_step_decode(name):
    cfg = get_config(name).reduced()
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(2))
    B = 2
    prefix = cfg.modality_prefix if cfg.family == "vlm" else 0
    batch = make_batch(cfg, B, 8, seed=2)
    pre = {k: v for k, v in batch.items() if k != "labels"}
    _, cache = model.prefill(params, pre, cache_len=prefix + 16)
    step = jax.jit(model.decode_step)
    tok = batch["tokens"][:, -1:]
    for _ in range(4):
        logits, cache = step(params, cache, tok)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert int(cache["pos"][0]) == prefix + 12


def test_n_params_estimates():
    """Printed parameter counts should be in the right ballpark."""
    approx = {
        "yi-9b": (8e9, 10e9),
        "deepseek-v3-671b": (6e11, 7.5e11),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "jamba-1.5-large-398b": (3.2e11, 4.6e11),
        "minicpm-2b": (2e9, 3.3e9),
    }
    for name, (lo, hi) in approx.items():
        n = get_config(name).n_params()
        assert lo <= n <= hi, f"{name}: {n:.3e} not in [{lo:.1e}, {hi:.1e}]"


def test_moe_active_params():
    ds = get_config("deepseek-v3-671b")
    active = ds.n_active_params()
    assert 3e10 <= active <= 4.5e10, active  # ~37B active
    k2 = get_config("kimi-k2-1t-a32b")
    assert 2.5e10 <= k2.n_active_params() <= 4e10  # ~32B active


def test_registry():
    from repro.configs.registry import get_config as gc, list_archs

    assert len(list_archs()) == 10
    assert gc("yi_9b").name == "yi-9b"
    with pytest.raises(KeyError):
        gc("nope")


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].kind == "prefill"
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1
