"""repro.distributed under a real multi-device mesh.

tests/test_substrate.py exercises ring_allgather_matmul and
compressed_allreduce on a degenerate (1,) mesh; these tests run the
same collectives across every visible device, so the ring permutation
and the psum averaging actually cross device boundaries. They skip on
single-device hosts — CI's mesh-smoke job launches pytest with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import (
    compressed_allreduce,
    ef_state_init,
    ring_allgather_matmul,
)

N_DEVICES = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEVICES < 2,
    reason="needs a multi-device mesh "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


def _mesh():
    return jax.make_mesh((N_DEVICES,), ("data",))


# ------------------------------------------------- ring allgather matmul


@multi_device
def test_ring_allgather_matmul_matches_dense():
    """The ring permutation over n real shards reproduces x @ w."""
    mesh = _mesh()
    k = 8 * N_DEVICES  # contraction dim must split evenly over the ring
    x = jax.random.normal(jax.random.PRNGKey(0), (5, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, 6))
    y = ring_allgather_matmul(x, w, mesh)
    assert y.shape == (5, 6)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(x @ w), rtol=2e-5, atol=1e-5
    )


@multi_device
def test_ring_allgather_matmul_rejects_indivisible_k():
    mesh = _mesh()
    x = jax.random.normal(jax.random.PRNGKey(0), (3, N_DEVICES * 4 + 1))
    w = jax.random.normal(
        jax.random.PRNGKey(1), (N_DEVICES * 4 + 1, 2)
    )
    with pytest.raises((AssertionError, ValueError)):
        ring_allgather_matmul(x, w, mesh)


@multi_device
def test_ring_allgather_matmul_custom_axis_name():
    mesh = jax.make_mesh((N_DEVICES,), ("ring",))
    k = 4 * N_DEVICES
    x = jax.random.normal(jax.random.PRNGKey(2), (2, k))
    w = jax.random.normal(jax.random.PRNGKey(3), (k, 3))
    y = ring_allgather_matmul(x, w, mesh, axis_name="ring")
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(x @ w), rtol=2e-5, atol=1e-5
    )


# --------------------------------------------------- compressed allreduce


@multi_device
def test_compressed_allreduce_multi_device_mean():
    """int8 quantize → psum-mean across n real ranks → dequantized mean
    stays within quantization error of the true gradients."""
    mesh = _mesh()
    grads = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (64, 32)),
        "b": jax.random.normal(jax.random.PRNGKey(1), (32,)),
    }
    st = ef_state_init(grads)
    mean, st2 = compressed_allreduce(grads, st, mesh)
    for leaf in ("w", "b"):
        # mean + residual reconstructs the input (error feedback invariant).
        np.testing.assert_allclose(
            np.asarray(mean[leaf] + st2.residual[leaf]),
            np.asarray(grads[leaf]),
            rtol=1e-5, atol=1e-6,
        )
        err = np.max(np.abs(np.asarray(mean[leaf] - grads[leaf])))
        assert err < np.max(np.abs(np.asarray(grads[leaf]))) / 100


@multi_device
def test_error_feedback_accumulates_multi_device():
    mesh = _mesh()
    g = {"w": jnp.full((256,), 1e-4)}  # vanishes under int8 alone
    st = ef_state_init(g)
    total = jnp.zeros((256,))
    for _ in range(50):
        mean, st = compressed_allreduce(g, st, mesh)
        total = total + mean["w"]
    np.testing.assert_allclose(float(jnp.mean(total)) / 50, 1e-4, rtol=0.05)


@multi_device
def test_compressed_allreduce_output_replicated():
    """Every device must hold the same averaged gradient."""
    mesh = _mesh()
    g = {"w": jax.random.normal(jax.random.PRNGKey(4), (16, 8))}
    mean, _ = compressed_allreduce(g, ef_state_init(g), mesh)
    assert mean["w"].sharding.is_fully_replicated
