"""Substrate tests: optimizer, schedules, checkpointing, data pipeline,
fault tolerance, sharding rules, serving engine, distributed tricks."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.configs import get_config
from repro.data.synthetic_lm import DataConfig, Prefetcher, SyntheticLM
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update, make_schedule
from repro.optim.adamw import dequantise, quantise
from repro.sharding.rules import logical_to_spec
from repro.train import TrainConfig, Trainer, plan_mesh
from repro.train.fault import StragglerWatchdog

# ----------------------------------------------------------------- optim


def _toy_params(key):
    k1, k2 = jax.random.split(key)
    return {
        "w": jax.random.normal(k1, (8, 4)),
        "b": jnp.zeros((4,)),
        "deep": {"u": jax.random.normal(k2, (4, 4))},
    }


def test_adamw_reduces_quadratic_loss():
    params = _toy_params(jax.random.PRNGKey(0))
    target = _toy_params(jax.random.PRNGKey(1))
    cfg = AdamWConfig(weight_decay=0.0)
    opt = adamw_init(params, cfg)

    def loss(p):
        return sum(
            jnp.sum((a - b) ** 2)
            for a, b in zip(
                jax.tree_util.tree_leaves(p), jax.tree_util.tree_leaves(target)
            )
        )

    l0 = float(loss(params))
    for step in range(50):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, jnp.float32(0.05), cfg)
    assert float(loss(params)) < 0.05 * l0


def test_adamw_8bit_matches_fp32_closely():
    params = _toy_params(jax.random.PRNGKey(2))
    target = _toy_params(jax.random.PRNGKey(3))

    def loss(p):
        return sum(
            jnp.sum((a - b) ** 2)
            for a, b in zip(
                jax.tree_util.tree_leaves(p), jax.tree_util.tree_leaves(target)
            )
        )

    outs = {}
    for bits in (32, 8):
        cfg = AdamWConfig(weight_decay=0.0, state_bits=bits)
        p = jax.tree_util.tree_map(lambda x: x, params)
        opt = adamw_init(p, cfg)
        for _ in range(30):
            g = jax.grad(loss)(p)
            p, opt, _ = adamw_update(g, opt, p, jnp.float32(0.05), cfg)
        outs[bits] = float(loss(p))
    # 8-bit optimizer should track fp32 within a small factor.
    assert outs[8] < 4 * outs[32] + 1e-3, outs


def test_quantise_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(4), (1000,)) * 3.0
    q = quantise(x)
    err = float(jnp.max(jnp.abs(dequantise(q) - x)))
    bound = float(jnp.max(jnp.abs(x))) / 127 / 2 + 1e-6  # half-step absmax
    assert err <= bound, (err, bound)


def test_grad_clip():
    params = {"w": jnp.ones((4,))}
    cfg = AdamWConfig(grad_clip=1.0, weight_decay=0.0)
    opt = adamw_init(params, cfg)
    g = {"w": 1e6 * jnp.ones((4,))}
    _, _, metrics = adamw_update(g, opt, params, jnp.float32(0.1), cfg)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_wsd_schedule_shape():
    s = make_schedule("wsd", 1.0, 1000)
    assert float(s(0)) < 0.2
    assert float(s(500)) == pytest.approx(1.0)
    assert float(s(999)) < 0.2
    c = make_schedule("cosine", 1.0, 1000)
    assert float(c(999)) < float(c(500)) < float(c(100))


# ------------------------------------------------------------ checkpoint


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "nest": {"b": jnp.ones((3, 4), jnp.bfloat16)}}
    d = os.path.join(tmp_path, "ck")
    save_pytree(tree, d)
    like = jax.tree_util.tree_map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    out = load_pytree(d, like)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_manager_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in [10, 20, 30]:
        mgr.save(step, {"x": jnp.full((4,), float(step))})
    mgr.wait()
    assert mgr.all_steps() == [20, 30]
    restored, got_step = mgr.restore({"x": jnp.zeros((4,))})
    assert got_step == 30
    np.testing.assert_allclose(np.asarray(restored["x"]), 30.0)


def test_checkpoint_detects_tree_mismatch(tmp_path):
    d = os.path.join(tmp_path, "ck")
    save_pytree({"a": jnp.zeros((2,))}, d)
    with pytest.raises(ValueError):
        load_pytree(d, {"wrong": jnp.zeros((2,))})


def test_interrupted_write_invisible(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    # Simulate a crash mid-write: a tmp dir without COMMIT.
    os.makedirs(os.path.join(tmp_path, "step_00000099.tmp-dead"))
    assert mgr.latest_step() is None
    mgr2 = CheckpointManager(str(tmp_path))  # gc on construction
    assert not any(".tmp-" in n for n in os.listdir(tmp_path))


# ------------------------------------------------------------------ data


def test_synthetic_lm_deterministic_and_restart_safe():
    cfg = DataConfig(vocab=100, seq_len=16, batch_size=4, seed=7)
    a = SyntheticLM(cfg).batch_at(5)
    b = SyntheticLM(cfg).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(cfg).batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    assert a["tokens"].shape == a["labels"].shape == (4, 16)


def test_synthetic_lm_learnable_structure():
    cfg = DataConfig(vocab=50, seq_len=64, batch_size=32, seed=0, run_len=4)
    lm = SyntheticLM(cfg)
    b = lm.batch_at(0)
    nxt = lm.successor[b["tokens"]]
    frac = np.mean(nxt == b["labels"])
    assert frac > 0.6  # (run_len-1)/run_len of positions are deterministic


def test_prefetcher():
    seen = []
    pf = Prefetcher(lambda step: {"s": np.full((2,), step)}, depth=2)
    for _ in range(4):
        seen.append(int(pf.get()["s"][0]))
    pf.close()
    assert seen == [0, 1, 2, 3]


# ------------------------------------------------------- fault tolerance


def test_straggler_watchdog():
    wd = StragglerWatchdog(threshold=2.0, warmup_steps=2)
    for _ in range(10):
        assert not wd.record(1.0)
    assert wd.record(5.0)  # straggler
    assert wd.slow_steps == 1
    for _ in range(9):
        wd.record(5.0)
    assert wd.should_remesh


def test_plan_mesh_elastic():
    p = plan_mesh(512, prefer_model=16, pods=2)
    assert (p.pod, p.data, p.model) == (2, 16, 16)
    assert p.dropped_devices == 0
    # degraded: lost 32 devices of one pod
    p2 = plan_mesh(480, prefer_model=16, pods=2)
    assert p2.model == 16 and p2.used_devices <= 480
    assert p2.dropped_devices == 480 - p2.used_devices
    p3 = plan_mesh(8, prefer_model=16)
    assert p3.model <= 8 and p3.used_devices <= 8


# --------------------------------------------------------- sharding rules


def test_logical_to_spec_divisibility_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # heads=56 on model=1: trivially divisible
    spec = logical_to_spec(("embed", "heads"), (64, 56), mesh)
    assert spec == jax.sharding.PartitionSpec("data", "model")


def test_logical_to_spec_handles_unknown_axis():
    mesh = jax.make_mesh((1,), ("data",))
    spec = logical_to_spec(("act_batch", "act_seq", None), (4, 8, 2), mesh)
    # 'pod'/'model' absent from this mesh: falls back to available axes.
    assert spec[0] in (("data",), "data", None) or spec[0] is not None


def test_no_axis_reuse_in_one_spec():
    mesh = jax.make_mesh((1,), ("model",))
    # both dims want 'model'; only one may take it.
    spec = logical_to_spec(("ff", "heads"), (4, 4), mesh)
    taken = [s for s in spec if s is not None]
    assert len(taken) == 1


# ----------------------------------------------------- interconnect pin


def test_interconnect_r_segment_is_13_8_ohm():
    """Pin the resistivity-typo correction. The paper prints
    rho = 1.9e9 ohm.m (an exponent typo); we use the copper-like BEOL
    value 1.9e-8 ohm.m, which with Table II's printed geometry gives
    ~13.8 ohm per bitcell segment. If this drifts, someone 'fixed' the
    resistivity back to the paper's literal value — see the
    core/interconnect.py module docstring."""
    from repro.core.interconnect import DEFAULT_INTERCONNECT, Interconnect

    assert DEFAULT_INTERCONNECT.resistivity == pytest.approx(1.9e-8)
    assert DEFAULT_INTERCONNECT.r_segment == pytest.approx(13.8, rel=0.01)
    # The paper's literal rho would give a ~1e17-ohm segment — the typo
    # is unambiguous.
    literal = dataclasses.replace(Interconnect(), resistivity=1.9e9)
    assert literal.r_segment > 1e15


# --------------------------------------------------- trainer integration


def test_trainer_end_to_end_with_resume(tmp_path):
    cfg = dataclasses.replace(get_config("minicpm-2b").reduced(), n_layers=2)
    model = build_model(cfg)
    from repro.configs.base import ShapeConfig
    from repro.data.synthetic_lm import make_train_stream

    shape = ShapeConfig("tiny", 32, 8, "train")
    tcfg = TrainConfig(
        peak_lr=1e-3,
        total_steps=8,
        schedule="wsd",
        checkpoint_dir=str(tmp_path),
        checkpoint_every=4,
        log_every=2,
    )
    trainer = Trainer(model, tcfg)
    stream = make_train_stream(cfg, shape, seed=3)
    params, hist = trainer.fit(jax.random.PRNGKey(0), stream)
    stream.close()
    losses = [m["loss"] for _, m in hist]
    assert losses[-1] < losses[0], losses  # learning happens
    assert trainer.ckpt.latest_step() == 8

    # Resume from checkpoint continues at step 8 (no retraining of 0-7).
    trainer2 = Trainer(model, dataclasses.replace(tcfg, total_steps=10))
    stream2 = make_train_stream(cfg, shape, seed=3, start_step=8)
    params2, hist2 = trainer2.fit(jax.random.PRNGKey(0), stream2)
    stream2.close()
    assert hist2[0][0] >= 8


def test_trainer_microbatching_matches_full_batch():
    cfg = dataclasses.replace(get_config("yi-9b").reduced(), n_layers=2)
    model = build_model(cfg)
    from repro.train.loop import make_train_step
    from repro.optim import adamw_init

    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(0), (4, 16), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab),
    }
    params = model.init(jax.random.PRNGKey(2))
    sched = make_schedule("constant", 1e-3, 10)

    outs = {}
    for nm in (1, 2):
        tcfg = TrainConfig(microbatches=nm, adamw=AdamWConfig(weight_decay=0.0))
        step = make_train_step(model, tcfg, sched)
        opt = adamw_init(params, tcfg.adamw)
        p2, _, m = jax.jit(step)(params, opt, batch, jnp.int32(0))
        outs[nm] = (p2, float(m["loss"]))
    # Same loss and near-identical updated params.
    assert outs[1][1] == pytest.approx(outs[2][1], rel=1e-4)
    for a, b in zip(
        jax.tree_util.tree_leaves(outs[1][0]), jax.tree_util.tree_leaves(outs[2][0])
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5)


# ----------------------------------------------------------- serving


def test_serving_engine_continuous_batching():
    from repro.serving import Request, ServeConfig, ServingEngine

    cfg = dataclasses.replace(get_config("granite-3-8b").reduced(), n_layers=2)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, ServeConfig(slots=2, cache_len=64))
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=(5 + i,)), max_tokens=6)
        for i in range(5)  # more requests than slots -> queueing
    ]
    eng.run(reqs, max_ticks=200)
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 6 for r in reqs)


def test_serving_matches_direct_decode():
    from repro.serving import Request, ServeConfig, ServingEngine

    cfg = dataclasses.replace(get_config("yi-9b").reduced(), n_layers=2)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(1))
    prompt = np.arange(7) % cfg.vocab

    eng = ServingEngine(model, params, ServeConfig(slots=2, cache_len=32))
    (req,) = eng.run([Request(rid=0, prompt=prompt, max_tokens=5)])

    # Direct greedy decode.
    tokens = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = model.prefill(params, {"tokens": tokens}, cache_len=32)
    toks = [int(jnp.argmax(logits[0, -1]))]
    tok = jnp.asarray([[toks[-1]]], jnp.int32)
    for _ in range(4):
        logits, cache = model.decode_step(params, cache, tok)
        toks.append(int(jnp.argmax(logits[0, 0])))
        tok = jnp.asarray([[toks[-1]]], jnp.int32)
    assert req.output == toks


# -------------------------------------------------------- distributed


def test_compressed_allreduce_single_device():
    from repro.distributed import compressed_allreduce, ef_state_init

    mesh = jax.make_mesh((1,), ("data",))
    grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 32))}
    st = ef_state_init(grads)
    mean, st2 = compressed_allreduce(grads, st, mesh)
    # Single rank: mean == dequant(quant(g)); residual == g - mean.
    np.testing.assert_allclose(
        np.asarray(mean["w"] + st2.residual["w"]), np.asarray(grads["w"]),
        rtol=1e-5, atol=1e-6,
    )
    err = np.max(np.abs(np.asarray(mean["w"] - grads["w"])))
    assert err < np.max(np.abs(np.asarray(grads["w"]))) / 100  # int8 accurate


def test_error_feedback_accumulates():
    from repro.distributed import compressed_allreduce, ef_state_init

    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.full((256,), 1e-4)}  # tiny grads vanish under int8 alone
    st = ef_state_init(g)
    total = jnp.zeros((256,))
    for _ in range(50):
        mean, st = compressed_allreduce(g, st, mesh)
        total = total + mean["w"]
    # With EF, the long-run average converges to the true value.
    np.testing.assert_allclose(float(jnp.mean(total)) / 50, 1e-4, rtol=0.05)


def test_ring_allgather_matmul():
    from repro.distributed import ring_allgather_matmul

    mesh = jax.make_mesh((1,), ("data",))
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 16))
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    y = ring_allgather_matmul(x, w, mesh)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=2e-5, atol=1e-5)
