import jax
import jax.numpy as jnp
import pytest

from repro.core.devices import (
    CBRAM, MRAM, PCM, RRAM, custom_tech, get_tech,
)


def test_table_iv_values():
    # Exact R_low/R_high pairs from paper Table IV.
    assert (MRAM.r_low, MRAM.r_high) == (8.5e3, 25.5e3)
    assert (RRAM.r_low, RRAM.r_high) == (2.5e3, 100e3)
    assert (CBRAM.r_low, CBRAM.r_high) == (5e3, 1e6)
    assert (PCM.r_low, PCM.r_high) == (50e3, 1e6)


def test_conductance_range():
    assert MRAM.g_on == pytest.approx(1 / 8.5e3)
    assert MRAM.g_off == pytest.approx(1 / 25.5e3)
    assert PCM.on_off_ratio == pytest.approx(20.0)


def test_quantize_levels():
    tech = custom_tech(1e3, 1e6, levels=4)
    g = jnp.linspace(tech.g_off, tech.g_on, 101)
    q = tech.quantize(g)
    uniq = jnp.unique(q)
    assert uniq.shape[0] == 4
    assert float(jnp.max(jnp.abs(q - g))) <= tech.g_range / 6 + 1e-12


def test_quantize_continuous_passthrough():
    g = jnp.linspace(MRAM.g_off, MRAM.g_on, 17)
    assert jnp.allclose(MRAM.quantize(g), g)


def test_perturb_bounds():
    tech = custom_tech(1e3, 1e5, sigma_rel=0.5)
    g = jnp.full((1000,), (tech.g_on + tech.g_off) / 2)
    p = tech.perturb(jax.random.PRNGKey(0), g)
    tol = 1e-6
    assert float(p.min()) >= tech.g_off * (1 - tol)
    assert float(p.max()) <= tech.g_on * (1 + tol)
    assert not jnp.allclose(p, g)


def test_get_tech():
    assert get_tech("mram") is MRAM
    assert get_tech(PCM) is PCM
    with pytest.raises(KeyError):
        get_tech("nosuch")


def test_custom_tech_validation():
    with pytest.raises(ValueError):
        custom_tech(1e6, 1e3)
