import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.devices import (
    CBRAM, MRAM, PCM, RRAM, apply_stuck_faults, custom_tech, get_tech,
    sample_stuck_faults,
)


def test_table_iv_values():
    # Exact R_low/R_high pairs from paper Table IV.
    assert (MRAM.r_low, MRAM.r_high) == (8.5e3, 25.5e3)
    assert (RRAM.r_low, RRAM.r_high) == (2.5e3, 100e3)
    assert (CBRAM.r_low, CBRAM.r_high) == (5e3, 1e6)
    assert (PCM.r_low, PCM.r_high) == (50e3, 1e6)


def test_conductance_range():
    assert MRAM.g_on == pytest.approx(1 / 8.5e3)
    assert MRAM.g_off == pytest.approx(1 / 25.5e3)
    assert PCM.on_off_ratio == pytest.approx(20.0)


def test_quantize_levels():
    tech = custom_tech(1e3, 1e6, levels=4)
    g = jnp.linspace(tech.g_off, tech.g_on, 101)
    q = tech.quantize(g)
    uniq = jnp.unique(q)
    assert uniq.shape[0] == 4
    assert float(jnp.max(jnp.abs(q - g))) <= tech.g_range / 6 + 1e-12


def test_quantize_continuous_passthrough():
    g = jnp.linspace(MRAM.g_off, MRAM.g_on, 17)
    assert jnp.allclose(MRAM.quantize(g), g)


def test_perturb_bounds():
    tech = custom_tech(1e3, 1e5, sigma_rel=0.5)
    g = jnp.full((1000,), (tech.g_on + tech.g_off) / 2)
    p = tech.perturb(jax.random.PRNGKey(0), g)
    tol = 1e-6
    assert float(p.min()) >= tech.g_off * (1 - tol)
    assert float(p.max()) <= tech.g_on * (1 + tol)
    assert not jnp.allclose(p, g)


def test_quantize_levels_zero_and_one_are_clip_only():
    """levels=0 (continuous) and levels=1 (degenerate) both pass values
    through untouched apart from range clipping."""
    g_in = jnp.linspace(0.5 * MRAM.g_off, 2.0 * MRAM.g_on, 33)
    for levels in (0, 1):
        tech = custom_tech(MRAM.r_low, MRAM.r_high, levels=levels)
        q = tech.quantize(g_in)
        expect = jnp.clip(g_in, tech.g_off, tech.g_on)
        assert jnp.array_equal(q, expect)


def test_quantize_clips_out_of_range():
    tech = custom_tech(1e3, 1e6, levels=4)
    q = tech.quantize(jnp.asarray([0.0, -1.0, 2 * tech.g_on]))
    assert float(q[0]) == pytest.approx(tech.g_off)
    assert float(q[1]) == pytest.approx(tech.g_off)
    assert float(q[2]) == pytest.approx(tech.g_on)


def test_quantize_monotone_and_idempotent():
    tech = custom_tech(1e3, 1e6, levels=5)
    g = jnp.linspace(0.5 * tech.g_off, 1.5 * tech.g_on, 201)
    q = tech.quantize(g)
    assert bool(jnp.all(jnp.diff(q) >= 0))  # monotone in the input
    assert jnp.array_equal(tech.quantize(q), q)  # idempotent


def test_perturb_trials_matches_sequential_bitwise():
    """The vectorized trial sampler must equal a per-key perturb loop
    exactly — the batched Monte-Carlo engine's equivalence rests on it."""
    tech = custom_tech(1e3, 1e5, sigma_rel=0.3)
    g = jnp.linspace(tech.g_off, tech.g_on, 24).reshape(6, 4)
    keys = jax.random.split(jax.random.PRNGKey(5), 4)
    batched = tech.perturb_trials(keys, g)
    seq = jnp.stack([tech.perturb(k, g) for k in keys])
    assert jnp.array_equal(batched, seq)


def test_perturb_trials_sigma_zero_is_exact():
    tech = custom_tech(1e3, 1e5, sigma_rel=0.0)
    g = jnp.linspace(tech.g_off, tech.g_on, 8)
    out = tech.perturb_trials(jax.random.split(jax.random.PRNGKey(0), 3), g)
    assert out.shape == (3, 8)
    assert jnp.array_equal(out, jnp.broadcast_to(g, (3, 8)))


def test_stuck_fault_masks_disjoint_and_rates():
    key = jax.random.PRNGKey(0)
    on, off = sample_stuck_faults(key, (40000,), 0.1, 0.2)
    assert not bool(jnp.any(jnp.logical_and(on, off)))  # disjoint
    assert float(jnp.mean(on)) == pytest.approx(0.1, abs=0.01)
    assert float(jnp.mean(off)) == pytest.approx(0.2, abs=0.01)
    # Same key -> same masks.
    on2, off2 = sample_stuck_faults(key, (40000,), 0.1, 0.2)
    assert jnp.array_equal(on, on2) and jnp.array_equal(off, off2)


def test_stuck_fault_validation():
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="probabilit"):
        sample_stuck_faults(key, (4,), -0.1, 0.0)
    with pytest.raises(ValueError, match="<= 1"):
        sample_stuck_faults(key, (4,), 0.7, 0.7)


def test_apply_stuck_faults_values():
    g = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    on = jnp.asarray([True, False, False, False])
    off = jnp.asarray([False, True, False, False])
    out = apply_stuck_faults(g, on, off, g_on=9.0, g_off=0.5)
    np.testing.assert_allclose(np.asarray(out), [9.0, 0.5, 3.0, 4.0])


def test_get_tech():
    assert get_tech("mram") is MRAM
    assert get_tech(PCM) is PCM
    with pytest.raises(KeyError):
        get_tech("nosuch")


def test_custom_tech_validation():
    with pytest.raises(ValueError):
        custom_tech(1e6, 1e3)
