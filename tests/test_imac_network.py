import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.digital import mlp_forward
from repro.core.imac import IMACConfig, IMACNetwork
from repro.core.interconnect import Interconnect


@pytest.fixture(scope="module")
def tiny_params():
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    sizes = [(10, 8), (8, 6), (6, 4)]
    return [
        (0.5 * jax.random.normal(k, s), 0.1 * jax.random.normal(k, (s[1],)))
        for k, s in zip(ks, sizes)
    ]


def test_ideal_mode_matches_digital(tiny_params):
    """parasitics=False + continuous conductances == digital forward."""
    cfg = IMACConfig(
        tech="PCM", parasitics=False, quantize=False, array_rows=8, array_cols=8
    )
    net = IMACNetwork(tiny_params, cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (9, 10))
    out, stats = net(x)
    ref = mlp_forward(tiny_params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-4)


def test_parasitic_mode_close_but_degraded(tiny_params):
    cfg = IMACConfig(tech="PCM", array_rows=8, array_cols=8, quantize=False)
    net = IMACNetwork(tiny_params, cfg)
    x = jax.random.uniform(jax.random.PRNGKey(2), (5, 10))
    out, stats = net(x)
    ref = mlp_forward(tiny_params, x)
    # Close (PCM high-R, small tiles) but NOT identical (parasitics).
    assert float(jnp.max(jnp.abs(out - ref))) < 0.5
    assert float(jnp.max(jnp.abs(out - ref))) > 1e-6
    assert all(float(jnp.max(s.residual)) < 1e-4 for s in stats)


def test_degradation_monotone_in_wire_resistance(tiny_params):
    """Fidelity to the digital model decays as wires get worse."""
    x = jax.random.uniform(jax.random.PRNGKey(3), (8, 10))
    ref = mlp_forward(tiny_params, x)
    errs = []
    for rho_scale in [0.1, 1.0, 10.0, 50.0]:
        ic = Interconnect(resistivity=1.9e-8 * rho_scale)
        cfg = IMACConfig(
            tech="MRAM", array_rows=8, array_cols=8, interconnect=ic,
            quantize=False,
        )
        net = IMACNetwork(tiny_params, cfg)
        out, _ = net(x)
        errs.append(float(jnp.mean(jnp.abs(out - ref))))
    assert errs == sorted(errs), errs


def test_power_increases_with_partitioning(tiny_params):
    """Paper Table III trend: more partitions => more power."""
    x = jax.random.uniform(jax.random.PRNGKey(4), (8, 10))
    powers = []
    for hp, vp in [([1, 1, 1], [1, 1, 1]), ([4, 3, 3], [3, 2, 2])]:
        cfg = IMACConfig(tech="MRAM", hp=hp, vp=vp, array_rows=16, array_cols=16)
        net = IMACNetwork(tiny_params, cfg)
        _, stats = net(x)
        powers.append(float(net.total_power(stats)))
    assert powers[1] > powers[0], powers


def test_accuracy_improves_with_partitioning(tiny_params):
    """Paper Table III trend: more partitions => closer to digital."""
    x = jax.random.uniform(jax.random.PRNGKey(5), (8, 10))
    ref = mlp_forward(tiny_params, x)
    # Stress wires so the difference is visible on a tiny network.
    ic = Interconnect(resistivity=1.9e-7)
    errs = []
    for hp, vp in [([1, 1, 1], [1, 1, 1]), ([4, 3, 3], [3, 2, 2])]:
        cfg = IMACConfig(
            tech="MRAM", hp=hp, vp=vp, array_rows=16, array_cols=16,
            interconnect=ic, quantize=False,
        )
        net = IMACNetwork(tiny_params, cfg)
        out, _ = net(x)
        errs.append(float(jnp.mean(jnp.abs(out - ref))))
    assert errs[1] < errs[0], errs


def test_noise_injection(tiny_params):
    cfg = IMACConfig(tech="PCM", parasitics=False, array_rows=8, array_cols=8)
    tech = dataclasses.replace(cfg.resolved_tech(), read_noise_rel=0.05)
    cfg = dataclasses.replace(cfg, tech=tech)
    net = IMACNetwork(tiny_params, cfg)
    x = jax.random.uniform(jax.random.PRNGKey(6), (4, 10))
    o1, _ = net(x, noise_key=jax.random.PRNGKey(10))
    o2, _ = net(x, noise_key=jax.random.PRNGKey(11))
    o3, _ = net(x, noise_key=jax.random.PRNGKey(10))
    assert not jnp.allclose(o1, o2)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o3))


def test_latency_structure(tiny_params):
    cfg = IMACConfig(tech="MRAM", array_rows=8, array_cols=8)
    net = IMACNetwork(tiny_params, cfg)
    x = jax.random.uniform(jax.random.PRNGKey(8), (2, 10))
    _, stats = net(x)
    lat = float(net.total_latency(stats))
    assert lat >= cfg.t_sampling
    # Bigger arrays (fewer partitions) -> longer lines -> more latency.
    cfg_big = IMACConfig(tech="MRAM", hp=[1, 1, 1], vp=[1, 1, 1])
    net_big = IMACNetwork(tiny_params, cfg_big)
    _, stats_big = net_big(x)
    assert float(net_big.total_latency(stats_big)) >= lat
