import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.devices import MRAM, PCM, RRAM
from repro.core.solver import (
    CircuitParams,
    crossbar_power,
    solve_crossbar,
    solve_dense_mna,
    solve_ideal,
    suggest_iters,
    tridiag_scan,
)

CP = CircuitParams(r_row=13.8, r_col=13.8, gs_iters=64)


def _random_tile(key, m, n, tech=MRAM):
    kg, kv = jax.random.split(key)
    g = jax.random.uniform(kg, (m, n), minval=tech.g_off, maxval=tech.g_on)
    v = jax.random.uniform(kv, (m,), minval=0.0, maxval=0.8)
    return g, v


@pytest.mark.parametrize("m,n", [(1, 1), (1, 8), (8, 1), (5, 7), (16, 12)])
def test_matches_dense_mna(m, n):
    g, v = _random_tile(jax.random.PRNGKey(m * 100 + n), m, n)
    oracle = solve_dense_mna(g, v, CP)
    fast = solve_crossbar(g, v, CP)
    np.testing.assert_allclose(
        np.asarray(fast.i_out), np.asarray(oracle.i_out), rtol=5e-4, atol=1e-9
    )
    np.testing.assert_allclose(
        np.asarray(fast.vc), np.asarray(oracle.vc), rtol=5e-3, atol=1e-6
    )


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=1, max_value=12),
    st.integers(min_value=1, max_value=12),
    st.sampled_from([MRAM, RRAM, PCM]),
)
def test_property_matches_oracle(m, n, tech):
    g, v = _random_tile(jax.random.PRNGKey(m * 31 + n), m, n, tech)
    oracle = solve_dense_mna(g, v, CP)
    fast = solve_crossbar(g, v, CP)
    np.testing.assert_allclose(
        np.asarray(fast.i_out), np.asarray(oracle.i_out), rtol=1e-3, atol=1e-9
    )


def test_near_zero_wire_resistance_approaches_ideal():
    g, v = _random_tile(jax.random.PRNGKey(0), 12, 9)
    cp = CircuitParams(r_row=1e-4, r_col=1e-4, r_source=1e-4, r_tia=1e-4, gs_iters=80)
    sol = solve_crossbar(g, v, cp)
    ideal = solve_ideal(g, v)
    np.testing.assert_allclose(np.asarray(sol.i_out), np.asarray(ideal), rtol=1e-4)


def test_ir_drop_reduces_current():
    """Physics: parasitic wires can only lose current vs ideal."""
    g, v = _random_tile(jax.random.PRNGKey(1), 24, 24)
    sol = solve_crossbar(g, v, CP)
    ideal = solve_ideal(g, v)
    assert bool(jnp.all(sol.i_out <= ideal + 1e-9))
    assert bool(jnp.all(sol.i_out >= 0))


def test_monotone_in_wire_resistance():
    """More wire resistance => strictly less output current."""
    g, v = _random_tile(jax.random.PRNGKey(2), 16, 16)
    currents = []
    for r in [1.0, 10.0, 50.0, 200.0]:
        cp = CircuitParams(r_row=r, r_col=r, gs_iters=128)
        currents.append(float(jnp.sum(solve_crossbar(g, v, cp).i_out)))
    assert currents == sorted(currents, reverse=True)


def test_batch_broadcasting():
    g, _ = _random_tile(jax.random.PRNGKey(3), 6, 5)
    v = jax.random.uniform(jax.random.PRNGKey(4), (4, 3, 6), maxval=0.8)
    sol = solve_crossbar(g, v, CP)
    assert sol.i_out.shape == (4, 3, 5)
    one = solve_crossbar(g, v[2, 1], CP)
    np.testing.assert_allclose(
        np.asarray(sol.i_out[2, 1]), np.asarray(one.i_out), rtol=1e-5
    )


def test_power_matches_oracle():
    g, v = _random_tile(jax.random.PRNGKey(5), 10, 8)
    oracle = solve_dense_mna(g, v, CP)
    fast = solve_crossbar(g, v, CP)
    p_o = crossbar_power(g, v, oracle, CP)
    p_f = crossbar_power(g, v, fast, CP)
    np.testing.assert_allclose(float(p_f), float(p_o), rtol=1e-3)
    # Power must not exceed the ideal upper bound sum(G V^2) + driver loss.
    assert float(p_f) > 0


def test_energy_conservation():
    """Power delivered by sources == power dissipated in the network."""
    g, v = _random_tile(jax.random.PRNGKey(6), 8, 6)
    sol = solve_dense_mna(g, v, CP)
    # Source power: sum over rows of V_in * I_in with I_in through r_source.
    i_in = (v - sol.vr[:, 0]) * CP.g_source
    p_delivered = float(jnp.sum(v * i_in))
    p_dissipated = float(crossbar_power(g, v, sol, CP))
    # crossbar_power includes the source-resistor dissipation, and
    # delivered power counts it too (it is inside the network).
    np.testing.assert_allclose(p_delivered, p_dissipated, rtol=1e-3)


def test_suggest_iters_converges_512():
    """The suggested sweep count converges the worst case (512x512 MRAM)."""
    g, v = _random_tile(jax.random.PRNGKey(7), 512, 512)
    it = suggest_iters(512, 512)
    cp = CircuitParams(gs_iters=it)
    ref_cp = CircuitParams(gs_iters=2 * it)
    sol = solve_crossbar(g, v, cp)
    ref = solve_crossbar(g, v, ref_cp)
    rel = float(
        jnp.max(jnp.abs(sol.i_out - ref.i_out)) / jnp.max(jnp.abs(ref.i_out))
    )
    assert rel < 5e-3, rel


def test_pluggable_backends():
    from repro.core.solver import SolveOptions

    g, v = _random_tile(jax.random.PRNGKey(8), 9, 11)
    a = solve_crossbar(g, v, CP, options=SolveOptions(backend="scan"))
    b = solve_crossbar(
        g, v, CP, options=SolveOptions(backend="pallas", interpret=True)
    )
    c = solve_crossbar(
        g, v, CP, options=SolveOptions(backend=tridiag_scan)
    )
    np.testing.assert_allclose(
        np.asarray(a.i_out), np.asarray(b.i_out), rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(a.i_out), np.asarray(c.i_out), rtol=0, atol=0
    )
