"""ngspice differential-oracle harness (repro.spice.oracle).

The rawfile parser and deck instrumentation are unit-tested with canned
strings everywhere. The live differential tests — generated and
hand-built netlists run through a real `ngspice -b`, DC node voltages
and transient waveforms compared against the in-repo backends — require
the binary and skip cleanly when it is absent (CI's optional oracle job
apt-installs it).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from test_spice_lower import demo_g, wired_crossbar

from repro.core.solver import SolveOptions, solve_dense_mna
from repro.spice import lower_crossbar, lower_network, parse_netlist, solve_dc
from repro.spice.oracle import (
    NgspiceError,
    NgspiceResult,
    _instrument,
    find_ngspice,
    parse_raw,
    run_ngspice,
)

requires_ngspice = pytest.mark.skipif(
    find_ngspice() is None, reason="ngspice binary not installed"
)

BACKENDS = ("scan", "pallas", "fused")


def _opts(backend):
    return SolveOptions(backend=backend, interpret=True)


# ---------------------------------------------------------------------------
# Rawfile parsing (always runs).
# ---------------------------------------------------------------------------

OP_RAW = """Title: divider
Date: Thu Aug  7 12:00:00 2026
Plotname: Operating Point
Flags: real
No. Variables: 3
No. Points: 1
Variables:
\t0\tv(a)\tvoltage
\t1\tv(b)\tvoltage
\t2\ti(v1)\tcurrent
Values:
0\t7.5e-01
\t5e-01
\t-2.5e-04
"""

TRAN_RAW = """Title: ramp
Plotname: Transient Analysis
Flags: real
No. Variables: 2
No. Points: 3
Variables:
\t0\ttime\ttime
\t1\tv(out)\tvoltage
Values:
0\t0.0
\t0.0
1\t1e-09
\t4e-01
2\t2e-09\t5e-01
"""


def test_parse_raw_op():
    (plot,) = parse_raw(OP_RAW)
    assert plot.name == "Operating Point"
    assert plot.variables == ("v(a)", "v(b)", "i(v1)")
    assert plot.values.shape == (1, 3)
    assert plot.voltage("a") == pytest.approx(0.75)
    assert plot.voltage("B") == pytest.approx(0.5)  # case-insensitive
    assert plot.signal("v1")[0] == pytest.approx(-2.5e-4)
    with pytest.raises(KeyError, match="nosuch"):
        plot.signal("nosuch")


def test_parse_raw_tran_and_multiplot():
    plots = parse_raw(OP_RAW + TRAN_RAW)
    assert [p.name for p in plots] == ["Operating Point", "Transient Analysis"]
    tran = plots[1]
    np.testing.assert_allclose(tran.time(), [0.0, 1e-9, 2e-9])
    np.testing.assert_allclose(tran.signal("out"), [0.0, 0.4, 0.5])


def test_parse_raw_complex_values():
    text = OP_RAW.replace("Flags: real", "Flags: complex")
    text = text.replace("7.5e-01", "7.5e-01,0.0")
    (plot,) = parse_raw(text)
    assert plot.voltage("a") == pytest.approx(0.75)  # real part kept


@pytest.mark.parametrize(
    "mutate,msg",
    [
        (lambda t: t.replace("No. Variables: 3\n", ""), "header missing"),
        (lambda t: t.replace("\t5e-01\n", ""), "value tokens"),
        (lambda t: t.replace("Values:\n0\t", "Values:\n9\t"), "point index"),
        (lambda t: "just some text\n", "no plots"),
    ],
)
def test_parse_raw_errors(mutate, msg):
    with pytest.raises(NgspiceError, match=msg):
        parse_raw(mutate(OP_RAW))


def test_parse_raw_variable_count_mismatch():
    bad = OP_RAW.replace("\t2\ti(v1)\tcurrent\n", "")
    with pytest.raises(NgspiceError, match="header says"):
        parse_raw(bad)


def test_result_plot_selection():
    plots = tuple(parse_raw(OP_RAW + TRAN_RAW))
    res = NgspiceResult(plots=plots, log="")
    assert res.op().name == "Operating Point"
    assert res.tran().name == "Transient Analysis"
    with pytest.raises(KeyError, match="no 'noise' plot"):
        res.plot("noise")


def test_instrument_splices_before_end():
    deck = "* t\nR1 a 0 1\n.END\n"
    out = _instrument(deck, "out.raw")
    assert out.index(".control") < out.upper().index(".END")
    assert "write out.raw all" in out
    # Without .end the control block is appended and .end added.
    out2 = _instrument("* t\nR1 a 0 1\n", "x.raw")
    assert out2.rstrip().endswith(".end")


def test_run_ngspice_missing_binary(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_NGSPICE", str(tmp_path / "nonexistent"))
    with pytest.raises(NgspiceError, match="not found"):
        run_ngspice({"a.sp": "* t\n.end\n"})


def test_run_ngspice_main_inference_error():
    with pytest.raises(NgspiceError, match="cannot infer"):
        run_ngspice(
            {"a.sp": "* a\n", "b.sp": "* b\n"}, ngspice="/bin/true"
        )


# ---------------------------------------------------------------------------
# Live differential tests (need the binary).
# ---------------------------------------------------------------------------


@requires_ngspice
def test_ngspice_dc_crossbar_vs_backends():
    """DC node voltages: ngspice vs the generic nodal oracle (1e-6),
    the crossbar dense MNA (1e-5) and every iterative backend (1e-3)."""
    g = demo_g(4, 3, seed=31)
    v = np.array([0.2, 0.7, 0.45, 0.9])
    text = wired_crossbar(g, v) + ".op\n.end\n"
    circ = parse_netlist(text)
    res = run_ngspice({"tile.sp": text})
    op = res.op()

    ours = solve_dc(circ)
    for node, want in ours.voltages.items():
        if node == "0":
            continue
        assert op.voltage(node) == pytest.approx(want, rel=1e-6, abs=1e-12), node

    xb = lower_crossbar(circ)
    with jax.experimental.enable_x64():
        dense = xb.node_voltages(xb.solve_dense())
    for node, want in dense.items():
        assert op.voltage(node) == pytest.approx(want, rel=1e-5, abs=1e-9), node

    # TIA currents through the production backends: i_out = v_foot/r_tia.
    i_ng = np.array(
        [op.voltage(f"c{g.shape[0] - 1}_{j}") / xb.r_tia for j in range(3)]
    )
    for backend in BACKENDS:
        sol = xb.solve(options=_opts(backend), gs_iters=200)
        np.testing.assert_allclose(
            np.asarray(sol.i_out), i_ng, rtol=1e-3, atol=1e-9,
            err_msg=f"backend {backend}",
        )


@requires_ngspice
@pytest.mark.parametrize("backend", BACKENDS)
def test_ngspice_transient_waveform(backend):
    """Transient column-foot waveforms: ngspice vs the implicit
    integrator running on each backend."""
    from repro.transient.integrator import integrate_tiles
    from repro.transient.spec import TransientSpec

    m, n = 3, 2
    g = demo_g(m, n, seed=32)
    v = np.array([0.8, 0.4, 0.6])
    c_seg, c_driver, c_tia = 5e-16, 1e-15, 2e-15
    c_row = np.full((m, n), c_seg)
    c_row[:, 0] += c_driver
    c_col = np.full((m, n), c_seg)
    c_col[m - 1, :] += c_tia
    text = wired_crossbar(
        g, v, c_row=c_row, c_col=c_col, pwl_rows=tuple(range(m))
    )
    text += ".tran 2e-10 2e-08\n.end\n"

    res = run_ngspice({"tile.sp": text})
    tran = res.tran()
    t_ng = tran.time()

    circ = parse_netlist(text)
    xb = lower_crossbar(circ)
    assert set(xb.pwl) == set(range(m))
    spec = TransientSpec(t_stop=2e-8, n_steps=50, method="trap", t_rise=1e-9)
    dt = spec.t_stop / spec.n_steps
    out = integrate_tiles(
        jnp.asarray(xb.g, dtype=jnp.float32),
        jnp.asarray(xb.v_in, dtype=jnp.float32),
        xb.circuit_params(gs_iters=96),
        spec,
        dt,
        c_row=jnp.asarray(xb.c_row, dtype=jnp.float32),
        c_col=jnp.asarray(xb.c_col, dtype=jnp.float32),
        t_rise=1e-9,
        solve_options=_opts(backend),
        record=True,
    )
    wave = np.asarray(out.waveform)  # (steps, N) column-foot voltages
    t_ours = dt * np.arange(1, spec.n_steps + 1)
    scale = float(np.max(np.abs(wave))) or 1.0
    for j in range(n):
        foot = f"c{m - 1}_{j}"
        v_ng = np.interp(t_ours, t_ng, tran.signal(foot))
        # Same circuit, same method (trap), different steppers: agree to
        # a few percent of the waveform scale throughout the ramp and
        # tightly at the settled tail.
        np.testing.assert_allclose(
            wave[:, j], v_ng, atol=0.05 * scale, rtol=0.05,
            err_msg=f"waveform mismatch on {foot} ({backend})",
        )
        assert wave[-1, j] == pytest.approx(v_ng[-1], rel=2e-2, abs=1e-5)


@requires_ngspice
def test_ngspice_accepts_generated_netlist():
    """A full map_imac deck (subckts, PWL-free DC drives, behavioural
    neurons) runs in ngspice, and the neuron output voltages match the
    lowered network solved by the dense MNA oracle."""
    from repro.core.devices import MRAM
    from repro.core.imac import IMACConfig, build_plans
    from repro.core.mapping import map_network
    from repro.core.netlist import map_imac
    from repro.core.partition import tile_matrix

    key = jax.random.PRNGKey(33)
    params = [(jax.random.normal(key, (4, 3)), jnp.zeros((3,)))]
    cfg = IMACConfig(tech="MRAM", array_rows=8, array_cols=8)
    mapped = map_network(params, MRAM, v_unit=cfg.vdd)
    plans = build_plans([4, 3], cfg)
    sample = np.array([0.15, 0.9, 0.35, 0.6])
    files = map_imac(mapped, plans, cfg, sample=sample)

    res = run_ngspice(files)
    op = res.op()

    net = lower_network(files)
    la = net.layers[0]
    assert la.plan.n_tiles == 1  # 5x3 fits one 8x8 tile
    gp = np.asarray(tile_matrix(jnp.asarray(la.g_pos), la.plan))[0]
    gn = np.asarray(tile_matrix(jnp.asarray(la.g_neg), la.plan))[0]
    v_in = np.concatenate([sample * net.v_unit, [net.v_unit]])
    cp = net.to_config().circuit_params(la.plan.rows, la.plan.cols)
    with jax.experimental.enable_x64():
        i_p = np.asarray(solve_dense_mna(jnp.asarray(gp), jnp.asarray(v_in), cp).i_out)
        i_n = np.asarray(solve_dense_mna(jnp.asarray(gn), jnp.asarray(v_in), cp).i_out)
    z = (i_p - i_n) * la.neuron.sense_scale
    nrn = la.neuron
    if nrn.kind == "sigmoid":
        v_pred = nrn.vdd / (1.0 + np.exp(-z / nrn.z_volt))
    elif nrn.kind == "tanh":
        v_pred = nrn.vdd * np.tanh(z / nrn.z_volt)
    elif nrn.kind == "relu":
        v_pred = np.maximum(0.0, z)
    else:
        v_pred = z
    for j in range(3):
        got = op.voltage(f"x1_{j}")
        assert np.isfinite(got)
        assert got == pytest.approx(v_pred[j], rel=1e-2, abs=5e-3), f"x1_{j}"
