"""Transient co-simulation engine: dense-oracle differential tests,
settling-detection properties, netlist round-trip, batching equivalence
and the analytic-vs-waveform crossvalidation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.imac import IMACConfig
from repro.core.evaluate import test_imac as imac_eval  # alias: pytest must not collect it
from repro.core.evaluate import evaluate_batch, structure_key
from repro.core.solver import CircuitParams, mna_system, solve_dense_mna
from repro.transient import (
    TransientSpec,
    crossvalidate_settling,
    integrate_tiles,
    node_capacitances,
    run_transient,
    settle_time,
)


@pytest.fixture(scope="module")
def tiny_params():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    params = [
        (jax.random.normal(k1, (12, 8)) * 0.4, jnp.zeros((8,))),
        (jax.random.normal(k2, (8, 4)) * 0.4, jnp.zeros((4,))),
    ]
    x = jax.random.uniform(jax.random.PRNGKey(3), (16, 12))
    y = jnp.zeros((16,), jnp.int32)
    return params, x, y


# ------------------------------------------------------ dense oracle


def _dense_reference(g, v_in, cp, c_row, c_col, t_rise, times):
    """Exact solution of C dv/dt = rhs(t) - A v (float64, expm).

    The PWL ramp is piecewise-affine, so on each piece the exact
    propagator applies: with constant rhs b, v(t0+h) = v_inf +
    expm(-C^-1 A h) (v(t0) - v_inf). The ramp segment is sampled finely
    enough that treating b as constant per sub-step converges.
    """
    import scipy.linalg as sla

    a, rhs = mna_system(g, v_in, cp)
    a = np.asarray(a, np.float64)
    rhs = np.asarray(rhs, np.float64)
    c = np.concatenate(
        [np.asarray(c_row).ravel(), np.asarray(c_col).ravel()]
    ).astype(np.float64)
    ainv_c = np.linalg.solve(np.diag(c), a)

    def prop(h):
        return sla.expm(-ainv_c * h)

    out = []
    v = np.zeros_like(rhs)
    t_prev = 0.0
    for t in times:
        # Integrate [t_prev, t] in sub-steps, freezing the ramp per step.
        n_sub = 64
        h = (t - t_prev) / n_sub
        ph = prop(h)
        for s in range(n_sub):
            tm = t_prev + (s + 0.5) * h
            b = rhs * min(tm / t_rise, 1.0)
            v_inf = np.linalg.solve(a, b)
            v = v_inf + ph @ (v - v_inf)
        t_prev = t
        out.append(v.copy())
    return np.stack(out)


@pytest.mark.parametrize("method", ["be", "trap"])
def test_integrator_matches_dense_expm_oracle(method):
    """Small RC network vs the float64 expm reference."""
    m, n = 3, 2
    g = jax.random.uniform(jax.random.PRNGKey(1), (m, n), minval=1e-5, maxval=1e-3)
    v_in = jnp.linspace(0.2, 0.8, m)
    cp = CircuitParams(gs_iters=200, tol=0.0)
    spec = TransientSpec(
        t_stop=4e-9, n_steps=256, method=method, gs_iters=40,
        rtol=0.01, atol=1e-9,
    )
    # Large caps so the dynamics span the horizon (not ramp-limited).
    c_row, c_col = node_capacitances(m, n, 2e-13, 1e-12, 2e-12)
    t_rise = spec.resolved_t_rise()
    res = integrate_tiles(
        g, v_in, cp, spec, spec.dt,
        c_row=c_row, c_col=c_col, t_rise=t_rise, record=True,
    )
    wave = np.asarray(res.waveform)          # (steps, N) column-foot volts
    dt = spec.dt
    check_steps = [31, 63, 127, 255]
    times = [(k + 1) * dt for k in check_steps]
    ref = _dense_reference(g, v_in, cp, c_row, c_col, t_rise, times)
    ref_foot = ref[:, m * n:].reshape(len(times), m, n)[:, m - 1, :]
    got = wave[check_steps]
    scale = np.max(np.abs(ref_foot))
    err = np.max(np.abs(got - ref_foot)) / scale
    # BE is 1st order, trap 2nd: both well under 2% at 256 steps.
    assert err < 0.02, (method, err)
    # And the horizon state must sit on the DC operating point.
    oracle = solve_dense_mna(g, v_in, cp)
    np.testing.assert_allclose(
        np.asarray(res.i_out), np.asarray(oracle.i_out), rtol=1e-3
    )


def test_trap_converges_faster_than_be():
    """2nd-order trapezoidal beats backward Euler at equal step count."""
    m, n = 3, 2
    g = jax.random.uniform(jax.random.PRNGKey(2), (m, n), minval=1e-5, maxval=1e-3)
    v_in = jnp.linspace(0.1, 0.7, m)
    cp = CircuitParams(gs_iters=200, tol=0.0)
    c_row, c_col = node_capacitances(m, n, 2e-13, 1e-12, 2e-12)
    errs = {}
    for method in ("be", "trap"):
        spec = TransientSpec(t_stop=4e-9, n_steps=64, method=method, gs_iters=40)
        t_rise = spec.resolved_t_rise()
        res = integrate_tiles(
            g, v_in, cp, spec, spec.dt,
            c_row=c_row, c_col=c_col, t_rise=t_rise, record=True,
        )
        wave = np.asarray(res.waveform)
        times = [(k + 1) * spec.dt for k in (15, 31, 47)]
        ref = _dense_reference(g, v_in, cp, c_row, c_col, t_rise, times)
        ref_foot = ref[:, m * n:].reshape(len(times), m, n)[:, m - 1, :]
        errs[method] = float(np.max(np.abs(wave[[15, 31, 47]] - ref_foot)))
    assert errs["trap"] < errs["be"], errs


# ---------------------------------------------- settling detection


def test_monotone_rc_charge_settles_exactly_once():
    """A monotone RC charge must cross into the band once and stay: the
    out-of-band indicator is a prefix of Trues — never True again after
    the first False."""
    for seed in range(4):
        m, n = 2, 2
        g = jax.random.uniform(
            jax.random.PRNGKey(seed), (m, n), minval=1e-5, maxval=1e-3
        )
        v_in = jnp.full((m,), 0.6)
        cp = CircuitParams(gs_iters=120, tol=0.0)
        spec = TransientSpec(t_stop=6e-9, n_steps=128, method="trap", gs_iters=30)
        c_row, c_col = node_capacitances(m, n, 3e-13, 1e-12, 2e-12)
        res = integrate_tiles(
            g, v_in, cp, spec, spec.dt,
            c_row=c_row, c_col=c_col, t_rise=spec.resolved_t_rise(),
            record=True,
        )
        wave = np.asarray(res.waveform)                  # (steps, N)
        ss = np.asarray(res.vc_foot * 0 + res.i_out_ss / cp.g_tia)
        band = spec.rtol * np.max(np.abs(ss)) + spec.atol
        oob = np.any(np.abs(wave - ss) > band, axis=-1)  # (steps,)
        transitions = np.sum(oob[:-1].astype(int) - oob[1:].astype(int) != 0)
        assert transitions <= 1, (seed, np.where(oob)[0])
        # last_oob is consistent with the recorded waveform.
        last = int(res.last_oob)
        assert last == (int(np.max(np.where(oob)[0])) if oob.any() else -1)


def test_settle_time_mapping():
    dt = 1e-10
    last = jnp.asarray([-1, 0, 5, 63])
    t = np.asarray(settle_time(last, dt, 64))
    np.testing.assert_allclose(t, [1e-10, 2e-10, 7e-10, 64e-10], rtol=1e-6)


# -------------------------------------------------- netlist round-trip


def test_netlist_tran_pwl_roundtrip(tiny_params):
    from repro.core.imac import build_plans
    from repro.core.mapping import map_network
    from repro.core.netlist import map_imac, parse_transient_directives

    params, x, _ = tiny_params
    spec = TransientSpec(t_stop=10e-9, n_steps=32, method="be", t_rise=2e-10)
    cfg = IMACConfig(
        tech="MRAM", array_rows=8, array_cols=8, transient=spec
    )
    mapped = map_network(params, cfg.resolved_tech(), v_unit=cfg.vdd)
    plans = build_plans([12, 8, 4], cfg)
    sample = np.linspace(0.0, 1.0, 12)
    files = map_imac(mapped, plans, cfg, sample=sample)
    main = files["imac_main.sp"]
    d = parse_transient_directives(main)
    assert d["t_stop"] == pytest.approx(spec.t_stop)
    assert d["t_step"] == pytest.approx(spec.dt)
    assert d["method"] == "gear"  # be -> GEAR
    assert set(d["pwl"]) == set(range(12))
    for i, pts in d["pwl"].items():
        # (0, 0) -> (t_rise, v) -> (t_stop, v): ramp then hold.
        assert pts[0] == (0.0, 0.0)
        assert pts[1][0] == pytest.approx(spec.t_rise)
        assert pts[1][1] == pytest.approx(sample[i] * mapped[0].v_unit, abs=1e-6)
        assert pts[2][0] == pytest.approx(spec.t_stop)
    # Periphery caps are stated in every layer subcircuit, and the bias
    # rows ramp like every other drive (the integrator starts at 0 V).
    assert "Cdrv_" in files["layer0.sp"] and "Ctia_" in files["layer1.sp"]
    for line in main.splitlines():
        if line.startswith("Vbias_"):
            assert "PWL(" in line, line
    # Without a spec the main file keeps DC sources (no PWL).
    files_dc = map_imac(mapped, plans, dataclasses.replace(cfg, transient=None))
    d_dc = parse_transient_directives(files_dc["imac_main.sp"])
    assert d_dc["pwl"] == {} and d_dc["method"] is None


# ------------------------------------------------- engine + batching


def test_run_transient_batched_matches_per_config_loop(tiny_params):
    params, x, _ = tiny_params
    spec = TransientSpec(
        t_stop=10e-9, n_steps=24, gs_iters=6, n_probe=2, refine_passes=0
    )
    cfgs = [
        IMACConfig(
            tech="MRAM", array_rows=8, array_cols=8, r_source=60.0 + 30.0 * i
        )
        for i in range(3)
    ]
    batched = run_transient(params, cfgs, x, spec=spec)
    for i, c in enumerate(cfgs):
        solo = run_transient(params, [c], x, spec=spec)
        np.testing.assert_allclose(
            float(batched.latency[i]), float(solo.latency[0]), rtol=1e-5
        )
        np.testing.assert_allclose(
            float(batched.energy[i]), float(solo.energy[0]), rtol=1e-4
        )


def test_evaluate_batch_reports_waveform_latency(tiny_params):
    params, x, y = tiny_params
    spec = TransientSpec(t_stop=10e-9, n_steps=24, gs_iters=6, n_probe=1)
    cfg = IMACConfig(tech="MRAM", array_rows=8, array_cols=8, transient=spec)
    res = imac_eval(params, x, y, cfg, n_samples=8, chunk=8)
    assert res.latency_source == "transient"
    assert np.isfinite(res.latency) and res.latency > 0.0
    assert res.energy > 0.0
    assert res.latency_analytic > 0.0
    assert res.latency >= cfg.t_sampling
    # The analytic path still reports an energy estimate + its own tag.
    res_dc = imac_eval(
        params, x, y, dataclasses.replace(cfg, transient=None),
        n_samples=8, chunk=8,
    )
    assert res_dc.latency_source == "analytic"
    assert res_dc.energy == pytest.approx(res_dc.avg_power * res_dc.latency)
    # Accuracy semantics are untouched by the transient analysis.
    assert res.accuracy == pytest.approx(res_dc.accuracy)


def test_run_transient_rejects_incompatible_configs(tiny_params):
    params, x, _ = tiny_params
    a = IMACConfig(tech="MRAM", array_rows=8, array_cols=8)
    b = dataclasses.replace(a, array_rows=4, array_cols=4)
    with pytest.raises(ValueError, match="structurally-compatible"):
        run_transient(params, [a, b], x, spec=TransientSpec(n_steps=8))
    with pytest.raises(ValueError, match="structurally-compatible"):
        run_transient(
            params, [a, dataclasses.replace(a, vdd=0.6)], x,
            spec=TransientSpec(n_steps=8),
        )


def test_transient_requires_parasitics(tiny_params):
    params, x, y = tiny_params
    cfg = IMACConfig(
        tech="MRAM", array_rows=8, array_cols=8, parasitics=False,
        transient=TransientSpec(),
    )
    with pytest.raises(ValueError, match="parasitics"):
        evaluate_batch(params, x, y, [cfg], n_samples=4, chunk=4)


def test_structure_key_separates_transient_specs(tiny_params):
    params, _, _ = tiny_params
    topo = [12, 8, 4]
    a = IMACConfig(array_rows=8, array_cols=8)
    b = dataclasses.replace(a, transient=TransientSpec(n_steps=16))
    c = dataclasses.replace(a, transient=TransientSpec(n_steps=16))
    assert structure_key(topo, a) != structure_key(topo, b)
    assert structure_key(topo, b) == structure_key(topo, c)


def test_latency_memo_keys_by_value_not_identity(tiny_params):
    """Distinct-but-equal configs share the memo; distinct interconnects
    do not (the id() aliasing bug this replaces)."""
    params, x, y = tiny_params
    from repro.core.interconnect import Interconnect

    base = IMACConfig(tech="MRAM", array_rows=8, array_cols=8)
    slow = dataclasses.replace(
        base,
        interconnect=dataclasses.replace(Interconnect(), cap_per_m=2e-7),
    )
    out = evaluate_batch(params, x, y, [base, slow], n_samples=4, chunk=4)
    assert out[1].latency > out[0].latency  # 1000x cap: Elmore must grow


# ------------------------------------------------- crossvalidation


def test_crossvalidation_ordering_and_monotonicity(tiny_params):
    params, x, _ = tiny_params
    spec = TransientSpec(t_stop=10e-9, n_steps=32, gs_iters=6, n_probe=1)
    cfg = IMACConfig(tech="MRAM", array_rows=8, array_cols=8)
    recs = crossvalidate_settling(
        params, x, cfg, cap_scales=(1.0, 1000.0, 3000.0), spec=spec
    )
    measured = [r["measured"] for r in recs]
    analytic = [r["analytic"] for r in recs]
    assert all(np.isfinite(v) and v > 0 for v in measured)
    # Monotone nondecreasing in c_segment, matching the analytic ordering.
    assert measured == sorted(measured)
    assert analytic == sorted(analytic)
    # Large-cap settling is resolvably slower than small-cap.
    assert measured[-1] > measured[0]
    # Energy grows with the capacitance being charged.
    energies = [r["energy"] for r in recs]
    assert energies[-1] > energies[0]


# ------------------------------------------------- explore + variability


def test_sweep_timing_mode_and_transient_axes(tiny_params, tmp_path):
    from repro.explore.engine import run_sweep
    from repro.explore.pareto import TRANSIENT_OBJECTIVES, pareto_front
    from repro.explore.spec import SweepSpec

    params, x, y = tiny_params
    base = IMACConfig(tech="MRAM", array_rows=8, array_cols=8)
    spec = TransientSpec(t_stop=10e-9, n_steps=16, gs_iters=5, n_probe=1)
    sw = SweepSpec.grid(base, cap_scale=[1.0, 2000.0])
    res = run_sweep(
        params, x, y, sw, n_samples=4, chunk=4, timing=spec,
        cache=str(tmp_path),
    )
    assert [r.latency_source for r in res] == ["transient", "transient"]
    assert res[1].latency >= res[0].latency
    front = pareto_front(res, TRANSIENT_OBJECTIVES)
    assert front  # extraction works over energy
    # Warm rerun comes from the cache with the new fields intact.
    res2 = run_sweep(
        params, x, y, sw, n_samples=4, chunk=4, timing=spec,
        cache=str(tmp_path),
    )
    assert all(r.cached for r in res2)
    assert res2[0].energy == pytest.approx(res[0].energy)
    # TransientSpec axes materialize onto the config.
    sw2 = SweepSpec.grid(base, tran_steps=[16, 32], tran_method=["be"])
    pts = sw2.materialize()
    assert len(pts) == 2
    assert pts[0][1].transient.n_steps == 16
    assert pts[1][1].transient.method == "be"


def test_variability_reports_per_trial_transients(tiny_params):
    from repro.variability import VariabilitySpec, run_variability

    params, x, y = tiny_params
    spec = TransientSpec(t_stop=10e-9, n_steps=16, gs_iters=5, n_probe=1)
    cfg = IMACConfig(
        tech="PCM", array_rows=8, array_cols=8, transient=spec
    )
    rep = run_variability(
        params, x, y, cfg, VariabilitySpec(trials=3, sigma_rel=0.3),
        n_samples=4, chunk=4,
    )
    assert len(rep.per_trial_latency) == 3
    assert len(rep.per_trial_energy) == 3
    assert rep.latency_worst >= rep.latency > 0.0
    assert rep.energy_worst >= rep.energy_mean > 0.0
