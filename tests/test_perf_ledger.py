"""Tests for the continuous-performance tier: repro.obs.ledger /
regress / prof / report and the benchmarks.regress CLI gate."""
import json
import math

import pytest

from repro import obs
from repro.obs import ledger, prof, regress, report


@pytest.fixture(autouse=True)
def _clean_obs():
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


def _meta(**over):
    meta = {
        "git_sha": "abc123def456",
        "git_dirty": False,
        "python_version": "3.10.16",
        "jax_version": "0.4.37",
        "jax_backend": "cpu",
        "device_platform": "cpu",
        "device_count": 1,
    }
    meta.update(over)
    return meta


def _entry(us, bench="solver", row="solver/gs_8x8", ts=0.0, **over):
    e = ledger.make_entry(
        bench,
        [{"name": row, "us_per_call": us, "derived": "iters=48"}],
        meta=_meta(**over),
    )
    e["ts_unix"] = ts
    return e


# ---------------------------------------------------------------------------
# Ledger.
# ---------------------------------------------------------------------------


def test_ledger_append_load_round_trip(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    e1 = _entry(100.0, ts=1.0)
    e2 = _entry(110.0, ts=2.0)
    ledger.append(e1, path)
    ledger.append(e2, path)
    loaded = ledger.load(path)
    assert [x["run_id"] for x in loaded] == [e1["run_id"], e2["run_id"]]
    assert loaded[0]["rows"] == [
        {"name": "solver/gs_8x8", "us_per_call": 100.0, "derived": "iters=48"}
    ]
    assert loaded[0]["git_sha"] == "abc123def456"
    assert loaded[0]["schema"] == ledger.ENTRY_SCHEMA


def test_ledger_skips_corrupt_lines(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    ledger.append(_entry(100.0), path)
    with open(path, "a") as fh:
        fh.write('{"truncated": \n')
        fh.write("not json at all\n")
        fh.write('{"valid_json": "but not an entry"}\n')
    ledger.append(_entry(101.0), path)
    entries, skipped = ledger.load_report(path)
    assert len(entries) == 2
    assert skipped == 3


def test_ledger_load_missing_file_is_empty(tmp_path):
    assert ledger.load(str(tmp_path / "nope.jsonl")) == []


def test_ledger_entry_metadata_is_gathered_when_absent():
    e = ledger.make_entry("x", [("r", 1.0, "")])
    assert e["git_sha"]  # repo checkout: a real sha (or "unknown")
    assert "git_dirty" in e
    assert e["jax_backend"] != ""
    assert e["bench"] == "x"
    # One JSONL line, parseable.
    assert json.loads(json.dumps(e))["rows"][0]["name"] == "r"


def test_ledger_matching_filters_env_and_bench():
    cpu = _entry(100.0, ts=1.0)
    tpu = _entry(50.0, ts=2.0, device_platform="tpu", jax_backend="tpu")
    other = _entry(10.0, bench="sweep", ts=3.0)
    bad = _entry(999.0, ts=4.0)
    bad["ok"] = False
    entries = [cpu, tpu, other, bad]
    same = ledger.matching(entries, bench="solver", env_of=cpu)
    assert [e["run_id"] for e in same] == [cpu["run_id"]]
    assert ledger.row_values([cpu, tpu], "solver/gs_8x8") == [100.0, 50.0]


def test_engine_opt_in_requires_flag_and_env(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_OBS_LEDGER", raising=False)
    assert ledger.engine_opt_in() is None
    obs.enable()
    assert ledger.engine_opt_in() is None
    path = str(tmp_path / "engine.jsonl")
    monkeypatch.setenv("REPRO_OBS_LEDGER", path)
    assert ledger.engine_opt_in() == path
    entry = ledger.record_engine_run("run_sweep", 0.5, count=10, derived="d")
    assert entry is not None
    (loaded,) = ledger.load(path)
    assert loaded["bench"] == "engine.run_sweep"
    assert loaded["rows"][0]["us_per_call"] == pytest.approx(0.5e6 / 10)
    obs.disable()
    assert ledger.record_engine_run("run_sweep", 0.5) is None


# ---------------------------------------------------------------------------
# Regression verdicts.
# ---------------------------------------------------------------------------


def _history(values, **over):
    return [
        _entry(v, ts=float(i)) for i, v in enumerate(values)
    ] if not over else [
        _entry(v, ts=float(i), **over) for i, v in enumerate(values)
    ]


def test_regress_flags_2x_slowdown():
    hist = _history([100.0, 102.0, 98.0, 101.0])
    current = _entry(200.0, ts=10.0)
    (v,) = regress.compare(current, hist)
    assert v.status == "regression"
    assert v.baseline_us == pytest.approx(100.5)
    assert v.ratio == pytest.approx(200.0 / 100.5)
    assert v.gating
    assert regress.has_regressions([v])


def test_regress_identical_timings_pass():
    hist = _history([100.0, 100.0, 100.0])
    (v,) = regress.compare(_entry(100.0, ts=10.0), hist)
    assert v.status == "ok"
    assert not v.gating


def test_regress_improvement_and_new_rows():
    hist = _history([100.0, 100.0, 100.0])
    (v,) = regress.compare(_entry(40.0, ts=10.0), hist)
    assert v.status == "improved"
    (v2,) = regress.compare(
        _entry(40.0, row="solver/other_row", ts=10.0), hist
    )
    assert v2.status == "new"


def test_regress_noisy_history_widens_threshold():
    quiet = regress.noise_threshold([100.0, 100.0, 100.0, 100.0])
    noisy = regress.noise_threshold([60.0, 140.0, 80.0, 120.0])
    assert quiet == regress.MIN_RATIO
    assert noisy > quiet
    # A ratio inside the noisy spread is not flagged.
    hist = _history([60.0, 140.0, 80.0, 120.0, 100.0])
    (v,) = regress.compare(_entry(150.0, ts=10.0), hist)
    assert v.status in ("ok", "insufficient")


def test_regress_ignores_other_environment_history():
    tpu_hist = _history(
        [10.0, 10.0, 10.0], device_platform="tpu", jax_backend="tpu"
    )
    # CPU run 10x slower than the TPU history: not a regression — there
    # is no CPU history at all.
    (v,) = regress.compare(_entry(100.0, ts=10.0), tpu_hist)
    assert v.status == "new"


def test_regress_single_history_point_is_insufficient():
    hist = _history([100.0])
    (v,) = regress.compare(_entry(500.0, ts=10.0), hist)
    assert v.status == "insufficient"
    assert not v.gating


def test_regress_skips_derived_only_rows():
    hist = _history([100.0, 100.0])
    (v,) = regress.compare(_entry(0.0, ts=10.0), hist)
    assert v.status == "skipped"


def test_regress_current_never_its_own_baseline():
    e = _entry(100.0, ts=1.0)
    (v,) = regress.compare(e, [e])
    assert v.status == "new"


# ---------------------------------------------------------------------------
# CLI gate (the acceptance criterion).
# ---------------------------------------------------------------------------


def _cli():
    return pytest.importorskip(
        "benchmarks.regress", reason="benchmarks/ needs repo-root cwd"
    )


def test_cli_flags_injected_2x_slowdown_and_passes_on_rerun(tmp_path):
    cli = _cli()
    path = str(tmp_path / "ledger.jsonl")
    for i, us in enumerate([100.0, 101.0, 99.0, 100.5]):
        ledger.append(_entry(us, ts=float(i)), path)
    # Injected 2x slowdown → exit 1.
    ledger.append(_entry(200.0, ts=10.0), path)
    assert cli.main(["--ledger", path]) == 1
    # --report-only never gates.
    assert cli.main(["--ledger", path, "--report-only"]) == 0

    # Rerun with identical timings → exit 0 (the slowdown entry is part
    # of history now but the median baseline shrugs off one outlier).
    path2 = str(tmp_path / "ledger2.jsonl")
    for i, us in enumerate([100.0, 101.0, 99.0, 100.5]):
        ledger.append(_entry(us, ts=float(i)), path2)
    ledger.append(_entry(100.2, ts=10.0), path2)
    assert cli.main(["--ledger", path2]) == 0


def test_cli_enforce_after_bootstraps_silently(tmp_path):
    cli = _cli()
    path = str(tmp_path / "ledger.jsonl")
    ledger.append(_entry(100.0, ts=0.0), path)
    ledger.append(_entry(100.0, ts=1.0), path)
    ledger.append(_entry(400.0, ts=2.0), path)  # regression, depth 2
    assert cli.main(["--ledger", path, "--enforce-after", "3"]) == 0
    assert cli.main(["--ledger", path, "--enforce-after", "2"]) == 1


def test_cli_empty_ledger_is_ok(tmp_path):
    cli = _cli()
    assert cli.main(["--ledger", str(tmp_path / "none.jsonl")]) == 0


# ---------------------------------------------------------------------------
# Report dashboard.
# ---------------------------------------------------------------------------


def _snapshot_with_histogram():
    obs.enable()
    h = obs.histogram("solver_sweeps", buckets=obs.SWEEPS_BUCKETS)
    for v in (8, 8, 16, 16, 16, 32, 64):
        h.observe(v)
    snap = obs.snapshot()
    obs.disable()
    return snap


def test_report_shows_metadata_and_quantiles(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    e = ledger.make_entry(
        "solver",
        [{"name": "solver/gs_8x8", "us_per_call": 100.0, "derived": ""}],
        meta=_meta(),
        metrics=_snapshot_with_histogram(),
    )
    ledger.append(e, path)
    out = report.render(ledger.load(path))
    assert "abc123def456"[:12] in out
    assert "cpu" in out
    assert "solver/gs_8x8" in out
    assert "solver_sweeps" in out
    assert "p95=" in out  # at least one histogram quantile rendered
    md = report.render(ledger.load(path), markdown=True)
    assert "| `solver/gs_8x8` |" in md


def test_report_quantiles_fall_back_to_buckets():
    # Old snapshot shape: no precomputed "quantiles" block.
    series = {
        "buckets": [
            {"le": "1", "count": 0},
            {"le": "2", "count": 2},
            {"le": "4", "count": 4},
            {"le": "+Inf", "count": 4},
        ],
        "count": 4,
    }
    qs = report.series_quantiles(series)
    assert qs["p50"] == pytest.approx(2.0)
    assert 2.0 <= qs["p99"] <= 4.0


def test_report_empty_ledger():
    assert "empty" in report.render([])


def test_report_cli_main(tmp_path, capsys):
    path = str(tmp_path / "ledger.jsonl")
    ledger.append(_entry(123.4, ts=1.0), path)
    assert report.main(["--ledger", path]) == 0
    out = capsys.readouterr().out
    assert "solver/gs_8x8" in out and "123.4us" in out


# ---------------------------------------------------------------------------
# Profiling hooks.
# ---------------------------------------------------------------------------


def test_prof_cost_flag_env_and_override(monkeypatch):
    prof.reset_cost()
    monkeypatch.delenv("REPRO_OBS_COST", raising=False)
    assert not prof.cost_enabled()
    monkeypatch.setenv("REPRO_OBS_COST", "1")
    assert prof.cost_enabled()
    prof.disable_cost()
    assert not prof.cost_enabled()
    prof.enable_cost()
    assert prof.cost_enabled()
    prof.reset_cost()


def test_prof_hlo_cost_and_instrumented_join():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    obs.enable()
    prof.enable_cost()
    try:
        fn = jax.jit(lambda a: (a @ a).sum())
        x = jnp.ones((32, 32))
        cost = prof.hlo_cost(fn, x)
        # CPU XLA implements cost analysis; tolerate absence elsewhere.
        if cost is not None:
            assert cost.get("flops", 0) > 0
        wrapped = prof.instrument_jit(fn, "matmul")
        wrapped(x)  # compile call: records hlo_flops
        wrapped(x)  # steady state: records achieved_flops_per_s
        snap = obs.snapshot()
        assert "jit_seconds" in snap
        if cost is not None and cost.get("flops"):
            assert snap["hlo_flops"]["series"][0]["value"] > 0
            assert "achieved_flops_per_s" in snap
            assert prof.last_cost("matmul")["flops"] == cost["flops"]
    finally:
        prof.reset_cost()


def test_prof_instrument_jit_disabled_passthrough():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    fn = prof.instrument_jit(jax.jit(lambda a: a * 2), "double")
    assert float(fn(jnp.float32(2.0))) == 4.0
    assert obs.spans() == []


def test_prof_jax_profile_noop_without_dir(monkeypatch):
    monkeypatch.delenv("REPRO_OBS_JAX_PROFILE", raising=False)
    with prof.jax_profile() as d:
        assert d is None


def test_prof_jax_profile_captures(tmp_path):
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    import os

    obs.enable()
    logdir = str(tmp_path / "prof")
    with prof.jax_profile(logdir) as d:
        assert d == logdir
        jax.block_until_ready(jnp.ones(8) * 2)
    # The profiler wrote *something* under the logdir.
    found = [
        os.path.join(r, f) for r, _, fs in os.walk(logdir) for f in fs
    ]
    assert found, "jax.profiler.trace produced no files"
    assert any(s.name == "jax_profile" for s in obs.spans())


def test_prof_sample_memory_disabled_and_enabled():
    assert prof.sample_memory("map") is None  # disabled
    obs.enable()
    stats = prof.sample_memory("map")
    # CPU backends expose no memory_stats — None is the contract there;
    # when stats exist the gauges must have been registered.
    if stats is not None:
        snap = obs.snapshot()
        assert "device_bytes_in_use" in snap
    else:
        assert "device_bytes_in_use" not in obs.snapshot()


def test_peak_flops_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_OBS_PEAK_FLOPS", "1.5e12")
    assert prof.peak_flops() == pytest.approx(1.5e12)
    monkeypatch.setenv("REPRO_OBS_PEAK_FLOPS", "garbage")
    assert prof.peak_flops("cpu") is None
    monkeypatch.delenv("REPRO_OBS_PEAK_FLOPS")
    assert prof.peak_flops("tpu") == prof.PLATFORM_PEAK_FLOPS["tpu"]


# ---------------------------------------------------------------------------
# End-to-end: make_entry(metrics=snapshot) → report shows quantiles.
# ---------------------------------------------------------------------------


def test_quantile_round_trip_through_ledger_json(tmp_path):
    path = str(tmp_path / "ledger.jsonl")
    snap = _snapshot_with_histogram()
    e = ledger.make_entry("sweep", [("sweep/warm", 42.0, "")],
                          meta=_meta(), metrics=snap)
    ledger.append(e, path)
    (loaded,) = ledger.load(path)
    series = loaded["metrics"]["solver_sweeps"]["series"][0]
    qs = report.series_quantiles(series)
    assert qs["p50"] is not None and not math.isnan(qs["p50"])
    # p50 of (8,8,16,16,16,32,64) is 16; bucket-edge error bound: the
    # containing bucket also spans (8, 16].
    assert 8.0 <= qs["p50"] <= 16.0
