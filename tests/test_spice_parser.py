"""Unit tests for the SPICE tokenizer/parser/emitter (repro.spice)."""
import pytest

from repro.spice import (
    BehavioralSource,
    Capacitor,
    Circuit,
    Comment,
    Directive,
    Instance,
    ISource,
    ParseError,
    Resistor,
    Subckt,
    Title,
    VSource,
    emit,
    fmt,
    parse_files,
    parse_netlist,
    spice_number,
)


# ---------------------------------------------------------------------------
# spice_number
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "tok,want",
    [
        ("10k", 1e4),
        ("1n", 1e-9),
        ("3meg", 3e6),
        ("2.2u", 2.2e-6),
        ("5MIL", 5 * 25.4e-6),
        ("1.5T", 1.5e12),
        ("4g", 4e9),
        ("7p", 7e-12),
        ("9f", 9e-15),
        ("-3m", -3e-3),
        ("20ns", 2e-8),  # scale suffix + trailing unit
        ("0.5V", 0.5),  # bare unit, no scaling
        ("1e-3", 1e-3),
        ("+.25", 0.25),
        ("1E6", 1e6),
    ],
)
def test_spice_number(tok, want):
    assert spice_number(tok) == pytest.approx(want, rel=1e-12)


@pytest.mark.parametrize("tok", ["", "k10", "1..2", "ten", "1 0"])
def test_spice_number_rejects(tok):
    with pytest.raises(ValueError):
        spice_number(tok)


# ---------------------------------------------------------------------------
# logical lines: continuations, comments, title
# ---------------------------------------------------------------------------


def test_continuation_lines_joined():
    circ = parse_netlist("* t\nR1 a b\n+ 10k\n")
    (r,) = circ.elements(Resistor)
    assert (r.n1, r.n2, r.value) == ("a", "b", 1e4)


def test_continuation_without_prior_card():
    with pytest.raises(ParseError, match="continuation"):
        parse_netlist("* only a comment\n+ 10k\n")


def test_eol_comments_stripped():
    circ = parse_netlist("* t\nR1 a b 1k ; a comment\nR2 b 0 2k $ another\n")
    r1, r2 = circ.elements(Resistor)
    assert r1.value == 1e3 and r2.value == 2e3


def test_full_line_comments_preserved():
    text = "* header\nR1 a 0 1000\n* trailer\n"
    circ = parse_netlist(text)
    assert [c.text for c in circ.elements(Comment)] == [" header", " trailer"]
    assert emit(circ) == text


def test_title_line_autodetected():
    circ = parse_netlist("my divider circuit\nR1 a 0 1k\n")
    assert isinstance(circ.cards[0], Title)
    assert circ.cards[0].text == "my divider circuit"
    # A later unparseable line is an error, not a title.
    with pytest.raises(ParseError):
        parse_netlist("R1 a 0 1k\nnot a card\n")


def test_blank_lines_dropped():
    circ = parse_netlist("* t\n\nR1 a 0 1k\n\n\nR2 a 0 2k\n")
    assert len(circ.elements(Resistor)) == 2


# ---------------------------------------------------------------------------
# element cards
# ---------------------------------------------------------------------------


def test_source_forms():
    circ = parse_netlist(
        "* sources\n"
        "V1 a 0 DC 0.8\n"
        "V2 b 0 1.5\n"
        "V3 c 0 PWL(0 0 1n 0.5)\n"
        "V4 d 0 PWL (0 0 2n 0.25 4n 0.25)\n"
        "I1 e 0 DC 1m\n"
    )
    v1, v2, v3, v4 = circ.elements(VSource)
    assert v1.dc == 0.8 and v1.pwl is None
    assert v2.dc == 1.5  # bare value == DC
    assert v3.pwl == ((0.0, 0.0), (1e-9, 0.5)) and v3.final_value() == 0.5
    assert v4.pwl[-1] == (4e-9, 0.25)  # split "PWL" "(...)" tokens merge
    (i1,) = circ.elements(ISource)
    assert i1.dc == 1e-3


@pytest.mark.parametrize(
    "card,msg",
    [
        ("V1 a 0 SIN(0 1 1k)", "unsupported source function"),
        ("V1 a 0 PULSE(0 1 0 1n)", "unsupported source function"),
        ("V1 a 0", "too short"),
        ("V1 a 0 DC", "DC without a value"),
        ("V1 a 0 PWL(0 0 1n)", "pairs"),
        ("I1 a 0 PWL(0 0 1n 1m)", "PWL current sources"),
        ("R1 a 0", "too short"),
        ("R1 a b 1k junk", "unsupported trailing token"),
        ("M1 d g s b nmos", "unsupported element card"),
        ("E1 out 0 bad", "VALUE="),
        ("E1 out 0 VALUE=unbraced", "braced"),
        ("R1 a b ((1k", "unbalanced parentheses"),
    ],
)
def test_parse_errors(card, msg):
    with pytest.raises(ParseError, match=msg):
        parse_netlist(f"* t\n{card}\n")


def test_parse_error_reports_lineno():
    with pytest.raises(ParseError, match=r"line 3"):
        parse_netlist("* one\nR1 a 0 1k\nM1 d g s b nmos\n")


def test_trailing_params_ignored():
    circ = parse_netlist("* t\nR1 a b 1k TC1=0.001 W=1u\n")
    (r,) = circ.elements(Resistor)
    assert r.value == 1e3


def test_behavioral_source_with_spaces():
    circ = parse_netlist("* t\nEneur_0 out 0 VALUE={max(0, (v(a) + 1)*2)}\n")
    (e,) = circ.elements(BehavioralSource)
    assert e.expr == "max(0, (v(a) + 1)*2)"
    assert emit_line(circ, "Eneur_0") == "Eneur_0 out 0 VALUE={max(0, (v(a) + 1)*2)}"


def emit_line(circ: Circuit, name: str) -> str:
    for line in emit(circ).splitlines():
        if line.startswith(name + " "):
            return line
    raise AssertionError(f"{name} not emitted")


def test_instance_card():
    circ = parse_netlist("* t\nXlayer0 in_0 in_1 out_0 layer0\n")
    (x,) = circ.elements(Instance)
    assert x.nodes == ("in_0", "in_1", "out_0") and x.subckt == "layer0"


def test_capacitor_card():
    circ = parse_netlist("* t\nCload out 0 10f\n")
    (c,) = circ.elements(Capacitor)
    assert c.value == pytest.approx(1e-14, rel=1e-12)


# ---------------------------------------------------------------------------
# subcircuits + directives
# ---------------------------------------------------------------------------


SUB = """* demo
.SUBCKT div a b
R1 a mid 1000
R2 mid b 1000
.ENDS div
Xd in 0 div
V1 in 0 DC 1
.OP
.END
"""


def test_subckt_parsing():
    circ = parse_netlist(SUB)
    sub = circ.subckts["div"]
    assert sub.ports == ("a", "b")
    assert len(sub.elements(Resistor)) == 2
    # Subckt bodies don't leak into top-level element views.
    assert circ.elements(Resistor) == []
    assert emit(circ) == SUB


@pytest.mark.parametrize(
    "text,msg",
    [
        ("* t\n.SUBCKT a p\nR1 p 0 1k\n", "never closed"),
        ("* t\n.ENDS foo\n", r"\.ENDS without"),
        ("* t\n.SUBCKT a p\nR1 p 0 1k\n.ENDS b\n", "does not close"),
        ("* t\n.SUBCKT\n", r"\.SUBCKT without a name"),
    ],
)
def test_subckt_errors(text, msg):
    with pytest.raises(ParseError, match=msg):
        parse_netlist(text)


def test_nested_subckt_cards():
    circ = parse_netlist(
        "* t\n.SUBCKT outer p\n.SUBCKT inner q\nR1 q 0 1\n.ENDS inner\n"
        "Xi p inner\n.ENDS outer\n"
    )
    outer = circ.subckts["outer"]
    inner = [c for c in outer.cards if isinstance(c, Subckt)]
    assert len(inner) == 1 and inner[0].name == "inner"


def test_directive_accessors():
    circ = parse_netlist(
        "* t\n.OPTION POST\n.OPTION METHOD=GEAR\n.TRAN 1n 20ns\n"
        ".INCLUDE 'layer0.sp'\n.END\n"
    )
    assert circ.option("METHOD") == "GEAR"
    assert circ.option("POST") == ""
    assert circ.option("MISSING") is None
    assert circ.tran() == (1e-9, 2e-8)
    assert circ.includes() == ["layer0.sp"]
    assert circ.directive("END") == Directive(name="END")
    assert circ.directive("NOSUCH") is None


def test_tran_absent():
    assert parse_netlist("* t\nR1 a 0 1k\n").tran() is None


# ---------------------------------------------------------------------------
# multi-file / .INCLUDE resolution
# ---------------------------------------------------------------------------


def test_parse_files_resolves_includes():
    files = {
        "imac_main.sp": "* main\n.INCLUDE 'sub.sp'\nV1 in 0 DC 1\n.END\n",
        "sub.sp": "* sub\nR1 in 0 1k\n",
    }
    circ = parse_files(files)
    assert len(circ.elements(Resistor)) == 1
    assert circ.includes() == []  # spliced, not kept


def test_parse_files_main_inference():
    with pytest.raises(ParseError, match="cannot infer"):
        parse_files({"a.sp": "* a\n", "b.sp": "* b\n"})
    # A single file needs no main.
    circ = parse_files({"only.sp": "* x\nR1 a 0 1\n"})
    assert len(circ.elements(Resistor)) == 1


def test_parse_files_missing_include():
    with pytest.raises(ParseError, match="not found"):
        parse_files({"imac_main.sp": "* m\n.INCLUDE 'gone.sp'\n"})


def test_parse_files_cycle():
    files = {
        "imac_main.sp": "* m\n.INCLUDE 'a.sp'\n",
        "a.sp": "* a\n.INCLUDE 'imac_main.sp'\n",
    }
    with pytest.raises(ParseError, match="circular"):
        parse_files(files)


# ---------------------------------------------------------------------------
# canonicalization
# ---------------------------------------------------------------------------


MESSY = """my title line
r1   a    b   10K   ; series
+ TC1=0.01
V1 a 0
+ PWL (0 0
+ 1n 0.5)
.subckt buf x y
Rb x y 1meg
.ends
Xb b out buf
.end
"""


def test_third_party_netlist_canonicalizes():
    """One round trip canonicalizes; further trips are byte-stable."""
    once = emit(parse_netlist(MESSY))
    twice = emit(parse_netlist(once))
    assert once == twice
    assert "R1 a b 10000" not in once  # %.6g: value prints as 10000
    assert "r1 a b 10000" in once
    assert "PWL(0 0 1e-09 0.5)" in once


def test_fmt_round_trip_stability():
    for x in (13.8182, 1e-9, 2.5e-10, 0.123456789, 97531.2468):
        assert fmt(spice_number(fmt(x))) == fmt(x)
